"""focuslint analyzer tests: one fixture violation per rule family
(plus a clean file), asserted through the JSON report, and regression
coverage for suppressions and the real ClusterStore.attach exemption."""
import json
import os

import pytest

from repro.analysis.runner import run_analysis

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(tmp_path, files, **kw):
    """Write {relpath: source} under tmp_path, lint, return parsed JSON."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    report = run_analysis([str(tmp_path)], **kw)
    return json.loads(report.to_json(show_suppressed=True))


def rules_of(doc, fname=None):
    return sorted({f["rule"] for f in doc["findings"]
                   if fname is None or f["path"].endswith(fname)})


# -- rule family 1: host-sync & retrace hazards --------------------------------

def test_host_sync_inside_traced_function(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def traced(x):\n"
        "    return np.asarray(x) + 1\n")})
    assert rules_of(doc) == ["host-sync"]
    assert doc["findings"][0]["line"] == 5


def test_host_sync_in_dispatcher_on_device_value(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * 2\n"
        "def hot(x):\n"
        "    y = f(x)\n"
        "    return int(y)\n")})
    assert rules_of(doc) == ["host-sync"]
    assert doc["findings"][0]["line"] == 7


def test_host_coercion_of_host_value_not_flagged(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "import jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * 2\n"
        "def hot(x, meta):\n"
        "    y = f(x)\n"
        "    n = int(meta['count'])\n"       # host dict: no finding
        "    return y, n\n")})
    assert doc["findings"] == []


def test_retrace_hazard_static_arg(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "def g(k, x):\n"
        "    return x[:k]\n"
        "gj = jax.jit(g, static_argnums=(0,))\n"
        "def caller(x):\n"
        "    return gj(int(x.sum()), x)\n")})
    assert "retrace-hazard" in rules_of(doc)


# -- rule family 2: donation-after-use -----------------------------------------

def test_donated_read_after_call(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "def step(a, b):\n"
        "    return a + b\n"
        "stepj = jax.jit(step, donate_argnums=(0,))\n"
        "def run(a, b):\n"
        "    out = stepj(a, b)\n"
        "    return a.sum() + out\n")})
    assert rules_of(doc) == ["donated-read"]
    assert doc["findings"][0]["line"] == 7


def test_donated_arg_reassigned_is_clean(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "def step(a, b):\n"
        "    return a + b\n"
        "stepj = jax.jit(step, donate_argnums=(0,))\n"
        "def run(a, b):\n"
        "    a = stepj(a, b)\n"               # rebinds the donated name
        "    return a.sum()\n")})
    assert doc["findings"] == []


# -- rule family 3: kernel contract --------------------------------------------

_KERNEL = (
    "from jax.experimental import pallas as pl\n"
    "def _body(x_ref, o_ref):\n"
    "    o_ref[...] = x_ref[...]\n"
    "def mykern(x):\n"
    "    return pl.pallas_call(_body, out_shape=x)(x)\n")


def test_kernel_without_oracle_wrapper_or_test(tmp_path):
    doc = lint(tmp_path, {"kernels/mykern.py": _KERNEL})
    assert rules_of(doc) == ["kernel-oracle", "kernel-test",
                             "kernel-wrapper"]


def test_pallas_call_outside_kernels_is_error(tmp_path):
    doc = lint(tmp_path, {"other.py": _KERNEL})
    assert "pallas-outside-kernels" in rules_of(doc)


# -- rule family 4: cache-version ----------------------------------------------

_STORE = (
    "class Store:\n"
    "    def bad(self, rows, vals):\n"
    "        self.centroids[rows] = vals\n"
    "    def good(self, rows, vals):\n"
    "        self.centroids[rows] = vals\n"
    "        self.versions[rows] += 1\n")


def test_cache_version_unbumped_mutation(tmp_path):
    doc = lint(tmp_path, {"store.py": _STORE})
    assert rules_of(doc) == ["cache-version"]
    assert doc["findings"][0]["line"] == 3          # bad(), not good()


# -- clean file ----------------------------------------------------------------

def test_clean_file_has_no_findings(tmp_path):
    doc = lint(tmp_path, {"clean.py": (
        "import jax\n"
        "import jax.numpy as jnp\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return jnp.tanh(x)\n"
        "def host_only(a):\n"
        "    return np.asarray(a) + 1\n"     # no device value involved
        "def hot(x):\n"
        "    return f(x)\n")})
    assert doc["findings"] == []
    assert doc["n_findings"] == 0


# -- suppressions --------------------------------------------------------------

def test_suppression_with_justification(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * 2\n"
        "def hot(x):\n"
        "    y = f(x)\n"
        "    # focuslint: disable=host-sync -- test boundary\n"
        "    return int(y)\n")})
    assert doc["findings"] == []
    assert doc["n_suppressed"] == 1
    assert doc["suppressed"][0]["justification"] == "test boundary"


def test_bare_suppression_is_itself_a_finding(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * 2\n"
        "def hot(x):\n"
        "    y = f(x)\n"
        "    return int(y)  # focuslint: disable=host-sync\n")})
    assert rules_of(doc) == ["bare-suppression"]
    assert doc["n_suppressed"] == 1


def test_function_scope_suppression_on_def_line(tmp_path):
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * 2\n"
        "def hot(x):  # focuslint: disable=host-sync -- whole fn\n"
        "    y = f(x)\n"
        "    z = int(y)\n"
        "    return float(y) + z\n")})
    assert doc["findings"] == []
    assert doc["n_suppressed"] == 2


def test_select_filters_rules(tmp_path):
    doc = lint(tmp_path, {"store.py": _STORE, "kern.py": _KERNEL},
               select=["cache-version"])
    assert rules_of(doc) == ["cache-version"]


def test_sharded_fetch_without_suppression_is_flagged(tmp_path):
    """The sharded fold boundary (DESIGN.md §13): a dispatcher doing a
    device_get of stacked outputs is flagged unless suppressed — an extra
    sync sneaking into the sharded fetch path cannot land silently — and
    the message names the one sanctioned boundary."""
    doc = lint(tmp_path, {"mod.py": (
        "import jax\n"
        "@jax.jit\n"
        "def megastep(x):\n"
        "    return x * 2, x > 0\n"
        "def pump_one(x):\n"
        "    j, matched = megastep(x)\n"
        "    j_h, m_h = jax.device_get((j, matched))\n"
        "    return j_h, m_h\n")})
    assert rules_of(doc) == ["host-sync"]
    assert "fold boundary" in doc["findings"][0]["message"]
    assert doc["findings"][0]["line"] == 7


# -- the repo itself -----------------------------------------------------------

def test_sharded_pipeline_sync_budget_is_pinned():
    """Regression fixture (ISSUE 9): core/pipeline.py carries exactly the
    designed set of sanctioned host syncs. A new ``device_get`` in the
    sharded (or single-stream) path must either fail the CI lint gate or
    consciously bump this pin with a justified suppression."""
    path = os.path.join(REPO_ROOT, "src", "repro", "core", "pipeline.py")
    report = run_analysis([path])
    doc = json.loads(report.to_json(show_suppressed=True))
    assert not [f for f in doc["findings"] if f["rule"] == "host-sync"]
    syncs = [f for f in doc["suppressed"] if f["rule"] == "host-sync"]
    # 5 single-stream (staged boundary x2, (j,matched) fetch, bound-gated
    # n, rare evict) + 3 sharded ((j,matched) stack fetch, fold-rows
    # fetch, bound-gated (S,) n); the evict/reset slot pulls sit in
    # non-dispatcher functions, outside the hot path this rule guards
    assert len(syncs) == 8, sorted(f["line"] for f in syncs)
    boundary = [f for f in syncs if "fold boundary" in f["justification"]
                or "designed" in f["justification"]]
    assert len(boundary) >= 2      # both fold-boundary fetches named


def test_repo_archive_rank_sync_budget_is_pinned():
    """Regression fixture (ISSUE 10): the lazy v4 shard path in
    core/archive.py carries exactly ONE sanctioned host sync — the
    once-per-shard fetch of the dequant_topk rank ids, cached for the
    shard's resident lifetime. A second device fetch on the archive rank
    path must fail the lint gate or consciously bump this pin."""
    src = os.path.join(REPO_ROOT, "src", "repro")
    # the kernels package must be in the analysis set: _rank_ids is hot
    # only because it reaches the jitted ops.dequant_topk wrapper
    report = run_analysis([os.path.join(src, "core", "archive.py"),
                           os.path.join(src, "kernels")])
    doc = json.loads(report.to_json(show_suppressed=True))
    assert not [f for f in doc["findings"] if f["rule"] == "host-sync"]
    syncs = [f for f in doc["suppressed"] if f["rule"] == "host-sync"]
    assert len(syncs) == 1, sorted(f["line"] for f in syncs)
    assert "once-per-shard" in syncs[0]["justification"]


def test_repo_attach_exemption_is_suppressed():
    """ClusterStore.attach's count-only mutation is the one sanctioned
    cache-version exemption — suppressed with a recorded rationale."""
    path = os.path.join(REPO_ROOT, "src", "repro", "core", "index.py")
    report = run_analysis([path])
    doc = json.loads(report.to_json(show_suppressed=True))
    attach = [f for f in doc["suppressed"]
              if f["rule"] == "cache-version"]
    assert len(attach) == 1
    assert "intentional exemption" in attach[0]["justification"]
    assert not [f for f in doc["findings"]
                if f["rule"] == "cache-version"]


@pytest.mark.slow
def test_repo_is_clean():
    """The CI gate invariant: the whole tree lints clean."""
    paths = [os.path.join(REPO_ROOT, d)
             for d in ("src", "benchmarks", "tests")]
    report = run_analysis(paths)
    assert report.active == [], report.to_text()
