"""Query-path unit coverage: GT-chunk shape bucketing and the vectorized
``gt_frames_by_class``."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.index import TopKIndex
from repro.core.query import gt_frames_by_class, pad_to_bucket, query


def _legacy_gt_frames_by_class(gt_labels, frames):
    """The dict-era per-object loop, kept as the property-test oracle."""
    out = {}
    for lab, f in zip(gt_labels, frames):
        out.setdefault(int(lab), set()).add(int(f))
    return {c: np.array(sorted(s), np.int64) for c, s in out.items()}


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 200))
def test_gt_frames_by_class_matches_legacy_loop(seed, n):
    r = np.random.default_rng(seed)
    labels = r.integers(0, 7, n)
    frames = r.integers(0, 40, n)
    got = gt_frames_by_class(labels, frames)
    want = _legacy_gt_frames_by_class(labels, frames)
    assert set(got) == set(want)
    for c in want:
        np.testing.assert_array_equal(got[c], want[c])
        assert got[c].dtype == np.int64


def test_gt_frames_by_class_empty():
    assert gt_frames_by_class(np.array([]), np.array([])) == {}


def test_pad_to_bucket_shapes():
    crops = np.ones((5, 4, 4, 3), np.float32)
    padded = pad_to_bucket(crops, 64)
    assert padded.shape == (64, 4, 4, 3)
    np.testing.assert_array_equal(padded[:5], crops)
    np.testing.assert_array_equal(padded[5:], 0)
    assert pad_to_bucket(np.ones((64, 2)), 64).shape == (64, 2)
    assert pad_to_bucket(np.ones((65, 2)), 64).shape == (128, 2)


def test_query_pads_ragged_chunk_but_counts_real_crops():
    """The jitted GT-CNN must only ever see bucket-multiple batch shapes,
    while n_gt_invocations keeps counting real crops only."""
    r = np.random.default_rng(0)
    n_classes, n = 5, 37               # 37 candidates: ragged vs any bucket
    index = TopKIndex(K=n_classes, n_local_classes=n_classes)
    probs = np.full((n, n_classes), 1.0 / n_classes, np.float32)
    crops = r.random((n, 4, 4, 3)).astype(np.float32)
    crops[:, 0, 0, 0] = 2.0
    index.add_batch(np.arange(n), r.normal(0, 1, (n, 8)).astype(np.float32),
                    probs, np.arange(n), np.arange(n), crops=crops)

    seen_shapes = []

    def gt_apply(batch):
        seen_shapes.append(len(batch))
        return np.rint(batch[:, 0, 0, 0]).astype(np.int64)

    res = query(index, 2, gt_apply, 1e9, batch_size=16, batch_pad=8)
    assert res.n_candidate_clusters == n
    assert res.n_gt_invocations == n             # real crops only
    assert res.gt_flops == n * 1e9
    assert all(s % 8 == 0 for s in seen_shapes)  # bucketed device batches
    assert len(res.matched_clusters) == n        # zero-pad rows sliced off
