"""QueryEngine: batched multi-query serving with GT-label caching.

Covers the engine/sequential equivalence property, precise cache
invalidation under interleaved ingest, incremental rank maintenance, and
the Kx edge-case regressions (Kx=0, negative Kx).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.engine import QueryEngine
from repro.core.index import TopKIndex
from repro.core.query import query

GT_FLOPS = 1e9


def _mk_index(seed, n_objects=600, n_classes=8, n_modes=40, feat_dim=16,
              K=3, batch=128):
    """Synthetic index; crop pixel (0,0,0) encodes the true class so a
    trivial exact GT-CNN stub exists."""
    r = np.random.default_rng(seed)
    mode_cls = r.integers(0, n_classes, n_modes)
    pick = r.integers(0, n_modes, n_objects)
    feats = r.normal(0, 1, (n_objects, feat_dim)).astype(np.float32)
    probs = r.random((n_objects, n_classes)).astype(np.float32) * 0.4
    probs[np.arange(n_objects), mode_cls[pick]] += 1.0
    probs /= probs.sum(1, keepdims=True)
    crops = r.random((n_objects, 4, 4, 3)).astype(np.float32)
    crops[:, 0, 0, 0] = mode_cls[pick].astype(np.float32)
    frames = np.repeat(np.arange((n_objects + 3) // 4), 4)[:n_objects]
    index = TopKIndex(K=K, n_local_classes=n_classes)
    for s in range(0, n_objects, batch):
        sl = slice(s, s + batch)
        index.add_batch(pick[sl], feats[sl], probs[sl],
                        np.arange(n_objects)[sl], frames[sl],
                        crops=crops[sl])
    return index


def _gt_apply(batch):
    return np.rint(batch[:, 0, 0, 0]).astype(np.int64)


# ---------------------------------------------------------------------------
# equivalence property: query_many == sequential query() per class
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([None, 1, 2, 3]))
def test_query_many_matches_sequential_query(seed, Kx):
    index = _mk_index(seed)
    classes = list(range(8))
    seq = [query(index, x, _gt_apply, GT_FLOPS, Kx=Kx) for x in classes]
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    results, batch = engine.query_many(classes, Kx)
    for s, e in zip(seq, results):
        assert s.queried_class == e.queried_class
        assert s.matched_clusters == e.matched_clusters
        assert s.n_candidate_clusters == e.n_candidate_clusters
        np.testing.assert_array_equal(s.frames, e.frames)
    # union dedup: the engine never classifies more than the unique
    # candidates, and never more than the sequential total
    assert batch.n_gt_invocations == batch.n_unique_candidates
    assert batch.n_gt_invocations <= sum(s.n_gt_invocations for s in seq)
    # per-query attribution sums to the batch total
    assert sum(e.n_gt_invocations for e in results) == batch.n_gt_invocations


def test_warm_cache_runs_zero_gt_invocations():
    index = _mk_index(1)
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    _, cold = engine.query_many(list(range(8)))
    assert cold.n_gt_invocations > 0
    warm_results, warm = engine.query_many(list(range(8)))
    assert warm.n_gt_invocations == 0
    assert warm.n_cache_hits == warm.n_unique_candidates
    # lower Kx reuses the same cache (candidate sets shrink, §5)
    _, warm_kx = engine.query_many(list(range(8)), Kx=1)
    assert warm_kx.n_gt_invocations == 0
    # lifetime stats accumulated across the three calls
    assert engine.stats.n_queries == 24
    assert engine.stats.n_gt_invocations == cold.n_gt_invocations


def test_cache_invalidation_on_centroid_move():
    """Ingest after query: exactly the moved clusters are re-verified."""
    index = _mk_index(2)
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    engine.query_many(list(range(8)))                     # fill the cache
    _, warm = engine.query_many(list(range(8)))
    assert warm.n_gt_invocations == 0

    # fold one object into an existing cluster -> its version bumps
    s = index.store
    cid = int(s.row_cids[0])
    row = s.row_of(cid)
    ver_before = int(s.versions[row])
    crop = s.rep_crops[row][None].copy()
    index.add_batch(np.array([cid]), s.centroids[row][None].copy(),
                    s.mean_probs[row][None].copy(),
                    np.array([10_000]), np.array([10_000]), crops=crop)
    assert int(s.versions[row]) == ver_before + 1
    assert engine.cached_label(cid) is None               # stale now

    _, after = engine.query_many(list(range(8)))
    assert after.n_gt_invocations == 1                    # only the moved one
    assert after.n_cache_hits == after.n_unique_candidates - 1


def test_attach_does_not_invalidate_cache():
    """attach adds members without moving centroids -> verdicts stay."""
    index = _mk_index(3)
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    engine.query_many(list(range(8)))
    cid = int(index.store.row_cids[0])
    index.attach(np.array([cid]), np.array([20_000]), np.array([20_000]))
    _, warm = engine.query_many(list(range(8)))
    assert warm.n_gt_invocations == 0


def test_oracle_mode_matches_first_member_labels():
    index = _mk_index(4)
    gt_labels = np.zeros(600, np.int64)
    r = np.random.default_rng(4)
    gt_labels[:] = r.integers(0, 8, 600)
    engine = QueryEngine(index, oracle_labels=gt_labels,
                         gt_flops_per_image=GT_FLOPS)
    results, _ = engine.query_many(list(range(8)))
    for cls, res in zip(range(8), results):
        cids = index.lookup(cls)
        firsts = index.first_members(cids)
        expect = [int(c) for c, f in zip(cids, firsts)
                  if gt_labels[f] == cls]
        assert res.matched_clusters == expect


def test_engine_requires_exactly_one_labeler():
    index = _mk_index(5)
    with pytest.raises(ValueError):
        QueryEngine(index)
    with pytest.raises(ValueError):
        QueryEngine(index, gt_apply=_gt_apply,
                    oracle_labels=np.zeros(600, np.int64))


def test_single_query_convenience_uses_cache():
    index = _mk_index(6)
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    res1 = engine.query(0)
    res2 = engine.query(0)
    assert res2.n_gt_invocations == 0
    np.testing.assert_array_equal(res1.frames, res2.frames)


# ---------------------------------------------------------------------------
# Kx edge cases (regression: Kx=0 used to mean "use default K")
# ---------------------------------------------------------------------------

def test_lookup_kx_zero_returns_no_clusters():
    index = _mk_index(7)
    assert index.lookup(0, Kx=0) == []
    res = query(index, 0, _gt_apply, GT_FLOPS, Kx=0)
    assert res.n_candidate_clusters == 0 and len(res.frames) == 0
    engine = QueryEngine(index, gt_apply=_gt_apply)
    results, batch = engine.query_many([0, 1], Kx=0)
    assert batch.n_unique_candidates == 0
    assert all(len(r.frames) == 0 for r in results)


def test_lookup_negative_kx_raises():
    index = _mk_index(8)
    with pytest.raises(ValueError):
        index.lookup(0, Kx=-1)
    with pytest.raises(ValueError):
        query(index, 0, _gt_apply, GT_FLOPS, Kx=-3)


def test_lookup_kx_above_k_raises():
    """Regression: ``Kx > K`` used to be silently clamped to K, returning
    an empty/short candidate list with no signal even when the class sat
    at a rank between K and Kx. Rank info beyond K was never stored, so
    the only honest answer is an error."""
    index = _mk_index(9, K=2)
    with pytest.raises(ValueError, match="exceeds the ingest-time K"):
        index.lookup(0, Kx=4)
    engine = QueryEngine(index, gt_apply=_gt_apply)
    with pytest.raises(ValueError, match="exceeds the ingest-time K"):
        engine.query_many([0, 1], Kx=4)
    with pytest.raises(ValueError, match="exceeds the ingest-time K"):
        query(index, 0, _gt_apply, GT_FLOPS, Kx=3)
    # the boundary itself is fine
    assert index.lookup(0, Kx=2) == index.lookup(0)


def test_cached_label_unknown_cid_returns_none():
    """Regression: probing the cache for a cid the index has never seen
    must return None, not raise through the cid->row map."""
    index = _mk_index(10)
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    assert engine.cached_label(123456) is None     # before any query
    engine.query_many(list(range(8)))
    assert engine.cached_label(123456) is None     # and after
    known = int(index.store.row_cids[0])
    assert engine.cached_label(known) == _gt_apply(
        index.store.rep_crops[0][None])[0]


# ---------------------------------------------------------------------------
# incremental rank maintenance
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_incremental_ranks_match_full_rebuild(seed):
    """Interleaved ingest/lookup: the incrementally maintained rank matrix
    equals a from-scratch _build after every batch."""
    r = np.random.default_rng(seed)
    n_classes, feat_dim = 6, 8
    index = TopKIndex(K=2, n_local_classes=n_classes)
    next_obj = 0
    for step in range(6):
        b = int(r.integers(1, 30))
        cids = r.integers(0, 15, b)
        feats = r.normal(0, 1, (b, feat_dim)).astype(np.float32)
        probs = r.random((b, n_classes)).astype(np.float32)
        probs /= probs.sum(1, keepdims=True)
        index.add_batch(cids, feats, probs,
                        np.arange(next_obj, next_obj + b),
                        np.arange(next_obj, next_obj + b))
        next_obj += b
        index.lookup(int(r.integers(0, n_classes)))   # force materialization
        incremental = index._ranks.copy()
        index._ranks = None
        index._build()
        np.testing.assert_array_equal(incremental, index._ranks)


def test_kx_bool_rejected():
    """Regression: ``bool`` is a subclass of ``int``, so ``Kx=True`` used
    to slip through the scalar check and silently query with Kx=1 (and
    ``False`` with Kx=0) — almost always a flag passed into the wrong
    argument slot. Both scalar and per-query bools must raise."""
    from repro.core.engine import normalize_kx

    index = _mk_index(10)
    engine = QueryEngine(index, gt_apply=_gt_apply)
    with pytest.raises(TypeError, match="bool"):
        engine.query_many([0, 1], Kx=True)
    with pytest.raises(TypeError, match="bool"):
        engine.query_many([0, 1], Kx=False)
    with pytest.raises(TypeError, match="bool"):
        engine.query_many([0, 1], Kx=[1, False])
    with pytest.raises(TypeError, match="bool"):
        normalize_kx(np.True_, 2)
    # plain ints and numpy ints still broadcast fine
    assert normalize_kx(np.int64(2), 3) == [2, 2, 2]
    assert normalize_kx(None, 2) == [None, None]
