"""Parameter selection (§4.4): sweep, Pareto boundary, policy selection."""
import numpy as np
import pytest

from repro.core.params import (Candidate, ConfigEval, pareto_boundary,
                               select, sweep)


def _ev(mid, K, T, p, r, ing, q):
    return ConfigEval(Candidate(mid, K, T), precision=p, recall=r,
                      ingest_flops=ing, query_flops=q, n_clusters=10,
                      viable=(p >= 0.95 and r >= 0.95))


def test_pareto_removes_dominated():
    evals = [
        _ev("a", 2, 1.0, 0.99, 0.99, 10, 10),
        _ev("b", 2, 1.0, 0.99, 0.99, 12, 12),   # dominated by a
        _ev("c", 2, 1.0, 0.99, 0.99, 5, 20),
        _ev("d", 2, 1.0, 0.99, 0.99, 20, 5),
        _ev("e", 2, 1.0, 0.5, 0.99, 1, 1),      # not viable
    ]
    front = pareto_boundary(evals)
    ids = {e.candidate.model_id for e in front}
    assert ids == {"a", "c", "d"}


def test_select_policies():
    evals = [
        _ev("bal", 2, 1.0, 0.99, 0.99, 10, 10),
        _ev("ing", 2, 1.0, 0.99, 0.99, 2, 40),
        _ev("qry", 2, 1.0, 0.99, 0.99, 40, 2),
    ]
    assert select(evals, "balance").candidate.model_id == "bal"
    assert select(evals, "opt_ingest").candidate.model_id == "ing"
    assert select(evals, "opt_query").candidate.model_id == "qry"


def test_select_none_when_no_viable():
    evals = [_ev("a", 2, 1.0, 0.5, 0.5, 1, 1)]
    assert select(evals, "balance") is None


def test_sweep_end_to_end_monotonic_recall_in_K():
    """Recall is non-decreasing in K (paper Fig. 5)."""
    from repro.data import get_stream
    r = np.random.default_rng(0)
    vs = get_stream("bend", duration_s=40, fps=10)
    crops, frames, _, labels = vs.objects_array()
    if len(crops) < 30:
        pytest.skip("stream too sparse")
    n_classes = 8
    classes = np.unique(labels)
    cls_of = {c: i for i, c in enumerate(classes)}
    local = np.array([cls_of[c] for c in labels])

    def noisy_apply(crops_in):
        # stand-in cheap model: correct class gets moderate prob + noise
        idx = [np.flatnonzero((crops == c).all(axis=(1, 2, 3)))[0]
               for c in crops_in]
        probs = r.random((len(crops_in), n_classes)).astype(np.float32)
        probs[np.arange(len(idx)), local[idx]] += 0.8
        probs /= probs.sum(1, keepdims=True)
        feats = np.stack([crops[i].mean(axis=2).ravel()[:32] for i in idx])
        return probs, feats.astype(np.float32)

    evals = sweep(crops, frames, local, {"m": (noisy_apply, 1e6)},
                  Ks=[1, 2, 4, 8], Ts=[0.5], gt_flops=1e9,
                  precision_target=0.9, recall_target=0.9)
    by_k = {e.candidate.K: e.recall for e in evals}
    ks = sorted(by_k)
    rec = [by_k[k] for k in ks]
    assert all(rec[i] <= rec[i + 1] + 1e-9 for i in range(len(rec) - 1))
    # query cost grows with K (more candidate clusters)
    by_k_cost = {e.candidate.K: e.query_flops for e in evals}
    cost = [by_k_cost[k] for k in ks]
    assert all(cost[i] <= cost[i + 1] + 1e-9 for i in range(len(cost) - 1))


# ---------------------------------------------------------------------------
# adaptive frame sampler (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _sampler(**kw):
    from repro.core.params import AdaptiveSampler, SamplerConfig
    return AdaptiveSampler(SamplerConfig(**kw))


def test_sampler_additive_increase_on_redundancy():
    s = _sampler(max_stride=5)
    for want in (2, 3, 4, 5, 5):            # +1 per window, capped at max
        assert s.observe(n_ingested=10, n_skipped=90) == want


def test_sampler_multiplicative_decrease_on_fresh_content():
    s = _sampler(max_stride=30)
    for _ in range(11):
        s.observe(10, 90)
    assert s.stride == 12
    assert s.observe(90, 10) == 6           # halves, not -1
    assert s.observe(90, 10) == 3
    assert s.observe(90, 10) == 1
    assert s.observe(90, 10) == 1           # floored at min_stride


def test_sampler_hysteresis_band_holds():
    s = _sampler()
    s.observe(10, 90)
    assert s.stride == 2
    for _ in range(5):                      # dup_rate inside (low, high)
        assert s.observe(35, 65) == 2
    assert s.observe(0, 0) == 2             # empty window: hold


def test_sampler_recall_gate_collapses_stride():
    s = _sampler(recall_floor=0.97)
    for _ in range(6):
        s.observe(10, 90)
    assert s.stride == 7
    # a passing probe does not interfere with the AIMD step
    assert s.observe(10, 90, recall=0.99) == 8
    # a failing probe collapses immediately, ignoring the duplicate rate
    assert s.observe(10, 90, recall=0.96) == 1


def test_sampler_rejects_bad_bounds():
    from repro.core.params import AdaptiveSampler, SamplerConfig
    with pytest.raises(ValueError):
        AdaptiveSampler(SamplerConfig(min_stride=0))
    with pytest.raises(ValueError):
        AdaptiveSampler(SamplerConfig(min_stride=5, max_stride=2))
    with pytest.raises(ValueError):
        AdaptiveSampler(SamplerConfig(dup_low=0.9, dup_high=0.5))


def _windowed_stream_dup(stride, d0=0.9, tau=12.0):
    """Observable duplicate rate of a temporally-correlated stream at a
    given stride: consecutive *sampled* frames are S apart, so the
    tracker/gate only sees duplicates while S stays inside the stream's
    correlation window (linear falloff, zero beyond tau)."""
    return d0 * max(0.0, 1.0 - (stride - 1) / tau)


def test_sampler_converges_on_content_signal():
    """The fixed accounting (stride-filtered objects excluded from the
    duplicate rate) converges to a steady stride inside the hysteresis
    band instead of ratcheting to max_stride."""
    s = _sampler(max_stride=30)
    seen = []
    for _ in range(40):
        dup = _windowed_stream_dup(s.stride)
        n_total = 120
        n_skipped = int(round(n_total * dup))
        # what the stride itself removed — reported, never counted
        n_sampled_out = n_total * (s.stride - 1)
        s.observe(n_total - n_skipped, n_skipped,
                  n_sampled_out=n_sampled_out)
        seen.append(s.stride)
    # settled: the last windows sit at one stride, inside the band
    steady = seen[-1]
    assert seen[-10:] == [steady] * 10
    assert steady < s.cfg.max_stride
    cfg = s.cfg
    assert cfg.dup_low <= _windowed_stream_dup(steady) <= cfg.dup_high


def test_sampler_buggy_accounting_ratchets_to_max():
    """The failure mode the fix removes: folding stride-filtered objects
    into ``n_skipped`` makes the duplicate rate >= (S-1)/S regardless of
    content, so the same stream drives the stride to max_stride — the
    controller feeding on its own output."""
    s = _sampler(max_stride=30)
    for _ in range(40):
        dup = _windowed_stream_dup(s.stride)
        n_total = 120
        n_skipped = int(round(n_total * dup))
        n_sampled_out = n_total * (s.stride - 1)
        # the old call site: stride skips counted as content redundancy
        s.observe(n_total - n_skipped, n_skipped + n_sampled_out)
    assert s.stride == s.cfg.max_stride
