"""Clustering unit + property tests (paper §4.2 semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import clustering as C


def _feats(n, d, seed=0, spread=5.0, n_modes=3):
    r = np.random.default_rng(seed)
    modes = r.normal(0, spread, (n_modes, d))
    pick = r.integers(0, n_modes, n)
    return (modes[pick] + r.normal(0, 0.1, (n, d))).astype(np.float32), pick


def test_first_object_creates_cluster():
    st_ = C.init_state(8, 4)
    st_, ids = C.cluster_scan(st_, np.ones((1, 4), np.float32), 1.0)
    assert int(st_.n) == 1 and int(ids[0]) == 0


def test_near_objects_share_cluster_far_objects_split():
    st_ = C.init_state(16, 4)
    f = np.array([[0, 0, 0, 0], [0.1, 0, 0, 0], [10, 10, 10, 10]],
                 np.float32)
    st_, ids = C.cluster_scan(st_, f, threshold=1.0)
    ids = np.asarray(ids)
    assert ids[0] == ids[1] != ids[2]
    assert int(st_.n) == 2


def test_centroid_is_running_mean():
    st_ = C.init_state(4, 2)
    f = np.array([[0, 0], [1, 0], [2, 0]], np.float32)
    st_, ids = C.cluster_scan(st_, f, threshold=10.0)
    assert int(st_.n) == 1
    np.testing.assert_allclose(np.asarray(st_.centroids[0]), [1.0, 0.0],
                               atol=1e-6)
    assert int(st_.counts[0]) == 3


def test_batched_matches_scan_when_no_new_clusters():
    """Two-phase variant is exactly sequential when objects join existing
    clusters (the common video case)."""
    f, _ = _feats(64, 16, seed=1)
    st0 = C.init_state(64, 16)
    st0, _ = C.cluster_scan(st0, f[:16], 1.5)      # warm up table
    s_a, ids_a = C.cluster_scan(st0, f[16:], 1.5)
    s_b, ids_b = C.cluster_batched(st0, f[16:], 1.5)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_allclose(np.asarray(s_a.centroids),
                               np.asarray(s_b.centroids), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(2, 16), st.floats(0.3, 4.0))
def test_cluster_scan_invariants(n, d, threshold):
    f, _ = _feats(n, d, seed=n * d)
    state = C.init_state(64, d)
    state, ids = C.cluster_scan(state, f, threshold)
    ids = np.asarray(ids)
    n_clusters = int(state.n)
    counts = np.asarray(state.counts)
    # every object assigned to a live cluster
    assert ((ids >= 0) & (ids < n_clusters)).all()
    # counts sum to n and match assignment histogram
    assert counts[:n_clusters].sum() == n
    hist = np.bincount(ids, minlength=n_clusters)
    np.testing.assert_array_equal(hist[:n_clusters], counts[:n_clusters])
    # O(M·n): cluster count bounded by M and n
    assert n_clusters <= min(64, n)


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 30))
def test_tight_threshold_yields_singletons(n):
    f = np.random.default_rng(n).normal(0, 10, (n, 8)).astype(np.float32)
    state = C.init_state(n, 8)
    state, ids = C.cluster_scan(state, f, threshold=1e-4)
    assert int(state.n) == n                      # all singletons
    np.testing.assert_array_equal(np.asarray(ids), np.arange(n))


def test_eviction_compacts_and_remaps():
    f, _ = _feats(40, 8, seed=3, n_modes=6)
    state = C.init_state(16, 8)
    state, _ = C.cluster_scan(state, f, 1.0)
    n_before = int(state.n)
    new_state, evicted, remap = C.evict_smallest(state, frac=0.5)
    n_after = int(new_state.n)
    assert n_after == n_before - len(evicted)
    # remap covers survivors, evicted slots map to -1
    for slot in evicted:
        assert remap[slot] == -1
    live = [s for s in range(n_before) if s not in set(evicted.tolist())]
    for s in live:
        ns = remap[s]
        assert ns >= 0
        np.testing.assert_allclose(np.asarray(new_state.centroids[ns]),
                                   np.asarray(state.centroids[s]))


# ---------------------------------------------------------------------------
# cluster_fused ≡ cluster_scan (the fast-path equivalence contract)
# ---------------------------------------------------------------------------

def _assert_state_eq(sa, sb, atol=1e-4):
    assert int(sa.n) == int(sb.n)
    np.testing.assert_array_equal(np.asarray(sa.counts),
                                  np.asarray(sb.counts))
    np.testing.assert_allclose(np.asarray(sa.centroids),
                               np.asarray(sb.centroids), atol=atol)


def _run_both(state, f, T):
    sa, ia = C.cluster_scan(state, f, T)
    sb, ib = C.cluster_fused(state, f, T)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    _assert_state_eq(sa, sb)
    return sa


def test_fused_equals_scan_all_match():
    """Warm table, tight modes, loose threshold: every object folds."""
    f, _ = _feats(96, 16, seed=7, spread=8.0)
    st0 = C.init_state(64, 16)
    st0, _ = C.cluster_scan(st0, f[:32], 2.0)
    sa, ia = C.cluster_scan(st0, f[32:], 2.0)
    sb, ib = C.cluster_fused(st0, f[32:], 2.0)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    _assert_state_eq(sa, sb)
    assert int(sa.n) == int(st0.n)            # genuinely all-match


def test_fused_equals_scan_none_match():
    """Empty table / tiny threshold: the whole batch takes the slow path."""
    f = np.random.default_rng(11).normal(0, 10, (40, 8)).astype(np.float32)
    _run_both(C.init_state(64, 8), f, 1e-3)


def test_fused_equals_scan_mixed():
    """Some objects fold, some open new clusters within the batch."""
    f, _ = _feats(150, 16, seed=5, spread=10.0, n_modes=8)
    st0 = C.init_state(128, 16)
    st0, _ = C.cluster_scan(st0, f[:30], 1.5)
    _run_both(st0, f[30:], 1.5)


def test_fused_equals_scan_empty_and_single():
    st0 = C.init_state(16, 4)
    s, ids = C.cluster_fused(st0, np.zeros((0, 4), np.float32), 1.0)
    assert ids.shape == (0,) and int(s.n) == 0
    _run_both(st0, np.ones((1, 4), np.float32), 1.0)


def test_fused_equals_scan_crossing_high_water():
    """Batch drives the table from nearly-empty past the eviction
    high-water mark (driver evicts AFTER the batch; within the batch the
    full-table joins-nearest rule must match scan)."""
    M = 16
    r = np.random.default_rng(13)
    # 24 far-apart points -> fills all 16 slots mid-batch, then the
    # remaining objects exercise the full-table nearest-join rule
    f = (r.normal(0, 1, (24, 8)) + np.arange(24)[:, None] * 50.0) \
        .astype(np.float32)
    st0 = C.init_state(M, 8)
    sa = _run_both(st0, f, 1.0)
    assert int(sa.n) == M                     # crossed the cap


def test_fused_equals_batched_video_stream():
    """Multi-batch video-style stream: fused and batched agree batch by
    batch once warmed (the regime both are specified for)."""
    f, _ = _feats(400, 16, seed=21, spread=12.0, n_modes=6)
    sa = C.init_state(64, 16)
    sb = C.init_state(64, 16)
    sa, _ = C.cluster_scan(sa, f[:64], 1.5)
    sb, _ = C.cluster_scan(sb, f[:64], 1.5)
    for start in range(64, 400, 64):
        chunk = f[start:start + 64]
        sa, ia = C.cluster_batched(sa, chunk, 1.5)
        sb, ib = C.cluster_fused(sb, chunk, 1.5)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    _assert_state_eq(sa, sb)


def test_buffer_full_joins_nearest():
    state = C.init_state(2, 2)
    f = np.array([[0, 0], [10, 10], [5, 5]], np.float32)
    state, ids = C.cluster_scan(state, f, threshold=0.1)
    assert int(state.n) == 2          # bounded at M
    assert int(ids[2]) in (0, 1)      # third joins nearest despite distance
