"""Per-kernel correctness: sweep shapes/dtypes, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# centroid_assign
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,M,D", [
    (1, 1, 8), (7, 13, 32), (64, 64, 128), (130, 257, 64), (256, 50, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_centroid_assign_matches_ref(B, M, D, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(B * M + D))
    f = jax.random.normal(k1, (B, D), dtype)
    c = jax.random.normal(k2, (M, D), dtype)
    d2, j = ops.centroid_assign(f, c)
    d2r, jr = ref.centroid_assign_ref(f, c)
    np.testing.assert_array_equal(np.asarray(j), np.asarray(jr))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2r),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-3)


@pytest.mark.parametrize("bb,bm", [(8, 8), (32, 16), (128, 128)])
def test_centroid_assign_block_shapes(bb, bm):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    f = jax.random.normal(k1, (100, 96))
    c = jax.random.normal(k2, (77, 96))
    d2, j = ops.centroid_assign(f, c, bb=bb, bm=bm)
    d2r, jr = ref.centroid_assign_ref(f, c)
    np.testing.assert_array_equal(np.asarray(j), np.asarray(jr))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2r), atol=1e-4)


@pytest.mark.parametrize("B,M,D,T", [
    (7, 13, 32, 7.0), (64, 64, 128, 14.0), (130, 257, 64, 10.0),
])
def test_centroid_assign_fused_threshold_matches_ref(B, M, D, T):
    """The kernel-emitted matched mask == host-side d2 <= T**2 compare."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(B * M + D))
    f = jax.random.normal(k1, (B, D))
    c = jax.random.normal(k2, (M, D))
    d2, j, m = ops.centroid_assign(f, c, threshold=T)
    d2r, jr, mr = ref.centroid_assign_ref(f, c, threshold=T)
    np.testing.assert_array_equal(np.asarray(j), np.asarray(jr))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2r), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))
    assert np.asarray(m).dtype == np.bool_
    # threshold must actually discriminate in this draw
    assert 0 < np.asarray(m).sum() < B


def test_centroid_assign_threshold_none_keeps_two_outputs():
    f = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    c = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    out = ops.centroid_assign(f, c)
    assert len(out) == 2


def test_centroid_assign_identical_rows():
    """Distance to an exact-duplicate centroid must be ~0 at the dup index."""
    f = jnp.tile(jnp.arange(32, dtype=jnp.float32)[None], (4, 1))
    c = jnp.stack([jnp.arange(32, dtype=jnp.float32) + 5,
                   jnp.arange(32, dtype=jnp.float32)])
    d2, j = ops.centroid_assign(f, c)
    assert (np.asarray(j) == 1).all()
    np.testing.assert_allclose(np.asarray(d2), 0.0, atol=1e-4)


# ---------------------------------------------------------------------------
# topk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,C,k", [
    (1, 10, 1), (4, 1000, 7), (9, 1000, 60), (130, 1000, 200), (32, 128, 128),
])
def test_topk_matches_ref(B, C, k):
    lg = jax.random.normal(jax.random.PRNGKey(B + C + k), (B, C))
    v, i = ops.topk(lg, k)
    vr, ir = ref.topk_ref(lg, k)
    # exact: both kernel and oracle copy the f32 inputs, no arithmetic
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


def test_topk_with_ties():
    lg = jnp.zeros((3, 50))
    v, i = ops.topk(lg, 5)
    # ties broken by lowest index, values all equal
    np.testing.assert_array_equal(np.asarray(i),
                                  np.tile(np.arange(5), (3, 1)))


@pytest.mark.parametrize("B", [1, 2, 5, 7])
def test_topk_tiny_batches_below_tile_floor(B):
    """B < 8: the row tile clamps to the 8-row VPU floor, the batch is
    padded up with -inf rows, and outputs are trimmed back to [:B]."""
    lg = jax.random.normal(jax.random.PRNGKey(B), (B, 37))
    v, i = ops.topk(lg, 3)
    vr, ir = ref.topk_ref(lg, 3)
    assert v.shape == (B, 3) and i.shape == (B, 3)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


def test_topk_k_equals_C_is_full_sort():
    lg = jax.random.normal(jax.random.PRNGKey(9), (5, 16))
    v, i = ops.topk(lg, 16)
    vr, ir = ref.topk_ref(lg, 16)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    # every column index appears exactly once per row (C-pad never leaks)
    np.testing.assert_array_equal(np.sort(np.asarray(i), axis=1),
                                  np.tile(np.arange(16), (5, 1)))


def test_topk_k_out_of_range_raises():
    lg = jax.random.normal(jax.random.PRNGKey(0), (4, 10))
    with pytest.raises(ValueError):
        ops.topk(lg, 11)          # k > C: only C classes exist to rank
    with pytest.raises(ValueError):
        ops.topk(lg, 0)


def test_topk_empty_batch():
    v, i = ops.topk(jnp.zeros((0, 12)), 4)
    assert v.shape == (0, 4) and i.shape == (0, 4)


def test_topk_oversized_bb_clamps_to_batch():
    """bb far larger than B degrades to one tile — results identical to a
    small explicit tile."""
    lg = jax.random.normal(jax.random.PRNGKey(4), (3, 40))
    v_big, i_big = ops.topk(lg, 5, bb=4096)
    v_small, i_small = ops.topk(lg, 5, bb=8)
    np.testing.assert_array_equal(np.asarray(i_big), np.asarray(i_small))
    np.testing.assert_allclose(np.asarray(v_big), np.asarray(v_small))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(2, 300), st.data())
def test_topk_property(B, C, data):
    k = data.draw(st.integers(1, C))
    lg = jax.random.normal(jax.random.PRNGKey(B * 31 + C), (B, C))
    v, i = ops.topk(lg, k)
    v, i = np.asarray(v), np.asarray(i)
    # descending order, indices valid, values match logits at indices
    assert (np.diff(v, axis=1) <= 1e-6).all()
    assert ((i >= 0) & (i < C)).all()
    np.testing.assert_allclose(np.take_along_axis(np.asarray(lg), i, 1), v,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,dh,causal", [
    (16, 16, True), (64, 32, True), (64, 32, False), (128, 64, True),
    (50, 16, True), (96, 128, False),
])
def test_flash_attention_matches_ref(S, dh, causal):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + dh), 3)
    shape = (2, S, 3, dh)
    q = jax.random.normal(k1, shape)
    k = jax.random.normal(k2, shape)
    v = jax.random.normal(k3, shape)
    out = ops.flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bk", [(16, 16), (32, 8), (8, 32), (128, 128)])
def test_flash_attention_block_sweep(bq, bk):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 64, 2, 32))
    k = jax.random.normal(k2, (1, 64, 2, 32))
    v = jax.random.normal(k3, (1, 64, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_attention_bf16():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (2, 32, 2, 32), jnp.bfloat16)
    k = jax.random.normal(k2, (2, 32, 2, 32), jnp.bfloat16)
    v = jax.random.normal(k3, (2, 32, 2, 32), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, bq=16, bk=16)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=3e-2)


def test_flash_attention_matches_model_attention():
    """The kernel plugs into multihead_attention (attn_impl="flash")."""
    from repro.models import layers as L
    rng = jax.random.PRNGKey(3)
    p = L.attn_init(rng, 64, 4, 4, jnp.float32)
    x = jax.random.normal(rng, (2, 32, 64))
    out_e = L.multihead_attention(p, x, n_heads=4, n_kv_heads=4, causal=True,
                                  attn_impl="einsum")
    out_f = L.multihead_attention(p, x, n_heads=4, n_kv_heads=4, causal=True,
                                  attn_impl="flash")
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_f),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# pixel_match
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Na,Nb,D", [
    (1, 1, 8), (7, 13, 48), (37, 19, 300), (64, 64, 192), (130, 257, 96),
])
def test_pixel_match_matches_ref(Na, Nb, D):
    k1, k2 = jax.random.split(jax.random.PRNGKey(Na * Nb + D))
    a = jax.random.uniform(k1, (Na, D))
    b = jax.random.uniform(k2, (Nb, D))
    m, d = ops.pixel_match(a, b, 0.2)
    mr, dr = ref.pixel_match_ref(a, b, 0.2)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), atol=1e-6)


@pytest.mark.parametrize("ba,bn", [(8, 8), (32, 16), (16, 64), (128, 128)])
def test_pixel_match_block_shapes(ba, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.uniform(k1, (100, 96))
    b = jax.random.uniform(k2, (77, 96))
    m, d = ops.pixel_match(a, b, 0.25, ba=ba, bn=bn)
    mr, dr = ref.pixel_match_ref(a, b, 0.25)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), atol=1e-6)


def test_pixel_match_exact_duplicate_wins():
    rng = np.random.default_rng(0)
    a = rng.random((9, 64)).astype(np.float32)
    b = rng.random((5, 64)).astype(np.float32)
    b[3] = a[6]                              # exact duplicate
    m, d = ops.pixel_match(a, b, 1e-6)
    assert int(np.asarray(m)[6]) == 3
    assert float(np.asarray(d)[6]) == 0.0


def test_pixel_match_threshold_is_strict():
    """A min diff exactly AT the threshold must not match (host
    pixel_difference contract: < threshold, not <=)."""
    a = np.zeros((1, 16), np.float32)
    b = np.full((1, 16), 0.5, np.float32)    # mean abs diff exactly 0.5
    m, _ = ops.pixel_match(a, b, 0.5)
    assert int(np.asarray(m)[0]) == -1
    m, _ = ops.pixel_match(a, b, np.nextafter(np.float32(0.5),
                                              np.float32(1.0)))
    assert int(np.asarray(m)[0]) == 0


def test_pixel_match_tie_breaks_to_lowest_index():
    a = np.full((3, 8), 0.25, np.float32)
    b = np.stack([np.full(8, 0.5, np.float32)] * 4)   # all refs equidistant
    m, _ = ops.pixel_match(a, b, 1.0)
    np.testing.assert_array_equal(np.asarray(m), 0)


def test_pixel_match_empty_inputs():
    m, d = ops.pixel_match(np.zeros((0, 8), np.float32),
                           np.ones((3, 8), np.float32), 0.1)
    assert m.shape == (0,) and d.shape == (0,)
    m, d = ops.pixel_match(np.ones((3, 8), np.float32),
                           np.zeros((0, 8), np.float32), 0.1)
    assert (np.asarray(m) == -1).all()
    assert np.isinf(np.asarray(d)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 30), st.integers(1, 30), st.data())
def test_pixel_match_property(Na, Nb, data):
    D = data.draw(st.sampled_from([8, 33, 100]))
    thr = data.draw(st.floats(0.01, 0.5))
    k1, k2 = jax.random.split(jax.random.PRNGKey(Na * 31 + Nb))
    a = jax.random.uniform(k1, (Na, D))
    b = jax.random.uniform(k2, (Nb, D))
    m, d = ops.pixel_match(a, b, thr)
    mr, dr = ref.pixel_match_ref(a, b, thr)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), atol=1e-6)


# ---------------------------------------------------------------------------
# motion_gate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("H,W,tile", [
    (8, 8, 8), (64, 64, 8), (70, 51, 8), (128, 128, 16), (33, 95, 8),
    (16, 24, 4),
])
def test_motion_gate_matches_ref(H, W, tile):
    k1, k2 = jax.random.split(jax.random.PRNGKey(H * W + tile))
    f = jax.random.uniform(k1, (H, W, 3))
    bg = jax.random.uniform(k2, (H, W, 3))
    nb, t, h = ops.motion_gate(f, bg, 0.05, 0.08, tile=tile)
    nbr, tr, hr = ref.motion_gate_ref(f, bg, 0.05, 0.08, tile)
    assert nb.shape == (H, W, 3)
    assert t.shape == (H // tile, W // tile)
    np.testing.assert_allclose(np.asarray(nb), np.asarray(nbr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
    assert np.asarray(h).dtype == np.bool_


@pytest.mark.parametrize("bh", [8, 16, 64, 256])
def test_motion_gate_row_block_sweep(bh):
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    f = jax.random.uniform(k1, (100, 40, 3))
    bg = jax.random.uniform(k2, (100, 40, 3))
    nb, t, h = ops.motion_gate(f, bg, 0.1, 0.05, tile=8, bh=bh)
    nbr, tr, hr = ref.motion_gate_ref(f, bg, 0.1, 0.05, 8)
    np.testing.assert_allclose(np.asarray(nb), np.asarray(nbr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))


def test_motion_gate_smaller_than_one_tile():
    """ty == 0 or tx == 0: empty tile grid, background still updates."""
    f = np.full((4, 20, 3), 1.0, np.float32)
    bg = np.zeros((4, 20, 3), np.float32)
    nb, t, h = ops.motion_gate(f, bg, 0.5, 0.01, tile=8)
    assert t.shape == (0, 2) and h.shape == (0, 2)
    np.testing.assert_allclose(np.asarray(nb), 0.5, atol=1e-7)
    nb, t, h = ops.motion_gate(f[:, :4], bg[:, :4], 0.5, 0.01, tile=8)
    assert t.shape == (0, 0) and h.shape == (0, 0)


def test_motion_gate_static_frame_is_cold():
    """frame == bg -> zero diff everywhere, no hot tiles, bg unchanged."""
    f = np.random.default_rng(0).random((48, 48, 3)).astype(np.float32)
    nb, t, h = ops.motion_gate(f, f, 0.05, 0.0, tile=8)
    np.testing.assert_allclose(np.asarray(nb), f, atol=1e-7)
    np.testing.assert_allclose(np.asarray(t), 0.0, atol=1e-7)
    assert not np.asarray(h).any()   # strict >: exactly-zero is not hot


def test_motion_gate_threshold_is_strict():
    f = np.full((8, 8, 3), 0.5, np.float32)
    bg = np.zeros((8, 8, 3), np.float32)     # every tile mean is exactly 0.5
    _, _, h = ops.motion_gate(f, bg, 0.0, 0.5, tile=8)
    assert not np.asarray(h).any()
    _, _, h = ops.motion_gate(f, bg, 0.0, 0.4999, tile=8)
    assert np.asarray(h).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 80), st.integers(1, 80), st.data())
def test_motion_gate_property(H, W, data):
    tile = data.draw(st.sampled_from([4, 8, 16]))
    alpha = data.draw(st.floats(0.0, 1.0))
    thr = data.draw(st.floats(0.0, 0.3))
    k1, k2 = jax.random.split(jax.random.PRNGKey(H * 97 + W))
    f = jax.random.uniform(k1, (H, W, 3))
    bg = jax.random.uniform(k2, (H, W, 3))
    nb, t, h = ops.motion_gate(f, bg, alpha, thr, tile=tile)
    nbr, tr, hr = ref.motion_gate_ref(f, bg, alpha, thr, tile)
    np.testing.assert_allclose(np.asarray(nb), np.asarray(nbr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))


# ---------------------------------------------------------------------------
# dequant_topk
# ---------------------------------------------------------------------------

def _quant_rows(M, C, dtype, seed):
    r = np.random.default_rng(seed)
    if dtype == np.uint8:
        q = r.integers(0, 256, (M, C)).astype(np.uint8)
    else:
        q = r.integers(-127, 128, (M, C)).astype(np.int8)
    scales = r.uniform(0.1, 2.0, M).astype(np.float32)
    return q, scales


@pytest.mark.parametrize("M,C,k", [
    (1, 1, 1), (7, 5, 3), (33, 16, 4), (64, 128, 128), (129, 200, 7),
    (130, 257, 60),
])
@pytest.mark.parametrize("dtype", [np.uint8, np.int8])
def test_dequant_topk_matches_ref(M, C, k, dtype):
    """Exact: kernel and oracle apply the identical f32 scale chain, so
    values match bitwise across non-multiple-of-block shapes."""
    q, scales = _quant_rows(M, C, dtype, M * C + k)
    v, i = ops.dequant_topk(q, scales, k, global_scale=1.0 / 255.0)
    vr, ir = ref.dequant_topk_ref(q, scales, k, global_scale=1.0 / 255.0)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


@pytest.mark.parametrize("bm", [8, 16, 128, 4096])
def test_dequant_topk_block_sweep(bm):
    q, scales = _quant_rows(100, 96, np.int8, 0)
    v, i = ops.dequant_topk(q, scales, 5, bm=bm)
    vr, ir = ref.dequant_topk_ref(q, scales, 5)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


def test_dequant_topk_ties_break_to_lowest_index():
    """Quantization collapses nearby probs into exact ties; rank order
    must still be deterministic (lowest column index first) to match the
    host-side _rank_rows and lax.top_k."""
    q = np.full((3, 50), 7, np.uint8)
    scales = np.ones(3, np.float32)
    v, i = ops.dequant_topk(q, scales, 5)
    np.testing.assert_array_equal(np.asarray(i),
                                  np.tile(np.arange(5), (3, 1)))
    np.testing.assert_array_equal(np.asarray(v), 7.0)


def test_dequant_topk_per_row_scale_applied():
    """Same quantized codes, different row scales -> scaled values; the
    ranking (within a row) is scale-invariant for positive scales."""
    q = np.tile(np.array([10, 30, 20], np.uint8), (2, 1))
    scales = np.array([1.0, 0.5], np.float32)
    v, i = ops.dequant_topk(q, scales, 3)
    np.testing.assert_array_equal(np.asarray(i),
                                  np.tile([1, 2, 0], (2, 1)))
    np.testing.assert_array_equal(np.asarray(v),
                                  [[30.0, 20.0, 10.0], [15.0, 10.0, 5.0]])


def test_dequant_topk_k_equals_C_never_leaks_pad():
    """C is padded to the 128-lane multiple with dtype-min; with k == C
    every real column must appear exactly once per row."""
    q, scales = _quant_rows(5, 16, np.int8, 9)
    v, i = ops.dequant_topk(q, scales, 16)
    vr, ir = ref.dequant_topk_ref(q, scales, 16)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_array_equal(np.sort(np.asarray(i), axis=1),
                                  np.tile(np.arange(16), (5, 1)))


def test_dequant_topk_uint8_zero_rows_with_pad():
    """All-zero uint8 rows tie with the column pad value (0); the pad
    columns sit at the highest indices so lowest-index ties keep them
    out for every k <= C."""
    q = np.zeros((4, 100), np.uint8)          # C=100 pads to 128
    scales = np.ones(4, np.float32)
    v, i = ops.dequant_topk(q, scales, 100)
    assert (np.asarray(i) < 100).all()
    np.testing.assert_array_equal(np.asarray(v), 0.0)


def test_dequant_topk_empty_rows():
    v, i = ops.dequant_topk(np.zeros((0, 12), np.uint8),
                            np.zeros(0, np.float32), 4)
    assert v.shape == (0, 4) and i.shape == (0, 4)
    assert v.dtype == np.float32 and i.dtype == np.int32


def test_dequant_topk_rejects_bad_inputs():
    q, scales = _quant_rows(4, 10, np.uint8, 1)
    with pytest.raises(ValueError):
        ops.dequant_topk(q, scales, 11)       # k > C
    with pytest.raises(ValueError):
        ops.dequant_topk(q, scales, 0)
    with pytest.raises(ValueError):
        ops.dequant_topk(q.astype(np.float32), scales, 3)   # use topk
    with pytest.raises(ValueError):
        ops.dequant_topk(q, scales[:2], 3)    # scales shape mismatch


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(2, 300), st.data())
def test_dequant_topk_property(M, C, data):
    k = data.draw(st.integers(1, C))
    dtype = data.draw(st.sampled_from([np.uint8, np.int8]))
    gs = data.draw(st.sampled_from([1.0, 1.0 / 255.0, 1.0 / 127.0]))
    q, scales = _quant_rows(M, C, dtype, M * 31 + C)
    v, i = ops.dequant_topk(q, scales, k, global_scale=gs)
    vr, ir = ref.dequant_topk_ref(q, scales, k, global_scale=gs)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
