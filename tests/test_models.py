"""Per-arch smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + finite values."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import (DiTConfig, EffNetConfig, LMConfig,
                                 ViTConfig, reduced)
from repro.configs import ARCH_IDS, get_arch
from repro.models import dit, efficientnet, transformer, vit

RNG = jax.random.PRNGKey(0)


def _smoke_lm(cfg: LMConfig):
    p = transformer.init(RNG, cfg)
    toks = jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size)
    logits, aux = transformer.forward(p, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # one train step: loss decreases over a couple of sgd steps
    def loss(p):
        return transformer.loss_fn(p, toks, toks, cfg)[0]
    l0, g = jax.value_and_grad(loss)(p)
    p2 = jax.tree.map(lambda w, gg: (w.astype(jnp.float32)
                                     - 0.3 * gg).astype(w.dtype), p, g)
    l1 = loss(p2)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0)
    # decode one token against a cache
    cache = transformer.init_cache(cfg, 2, 32)
    lg, cache2 = transformer.decode_step(p, cache, toks[:, :1], jnp.int32(4),
                                         cfg)
    assert lg.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())


def _smoke_vit(cfg: ViTConfig):
    p = vit.init(RNG, cfg)
    img = jax.random.normal(RNG, (2, cfg.img_res, cfg.img_res, 3))
    logits = vit.forward(p, img, cfg)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
    feats = vit.forward(p, img, cfg, features_only=True)
    assert feats.shape == (2, cfg.d_model)
    loss, m = vit.loss_fn(p, img, jnp.array([0, 1]), cfg)
    assert np.isfinite(float(loss))


def _smoke_dit(cfg: DiTConfig):
    p = dit.init(RNG, cfg)
    res = cfg.img_res // cfg.vae_factor
    lat = jax.random.normal(RNG, (2, res, res, cfg.latent_channels))
    y = jnp.array([0, 1])
    noise, sigma = dit.forward(p, lat, jnp.array([5, 900]), y, cfg)
    assert noise.shape == lat.shape and sigma.shape == lat.shape
    assert bool(jnp.isfinite(noise).all())
    loss, _ = dit.loss_fn(p, lat, y, RNG, cfg)
    assert np.isfinite(float(loss))
    out = dit.sample(p, RNG, y, cfg, img_res=cfg.img_res, n_steps=2)
    assert out.shape == lat.shape
    assert bool(jnp.isfinite(out).all())


def _smoke_effnet(cfg: EffNetConfig):
    p, s = efficientnet.init(RNG, cfg)
    img = jax.random.normal(RNG, (2, cfg.img_res, cfg.img_res, 3))
    logits, s2 = efficientnet.forward(p, s, img, cfg, train=True)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
    # BN state actually updates
    changed = jax.tree.map(
        lambda a, b: bool((np.asarray(a) != np.asarray(b)).any()),
        s["stem"], s2["stem"])
    assert any(jax.tree.leaves(changed))
    logits_eval, _ = efficientnet.forward(p, s2, img, cfg, train=False)
    assert bool(jnp.isfinite(logits_eval).all())


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke(arch_id):
    cfg = get_arch(arch_id)
    small = reduced(cfg)
    if isinstance(cfg, LMConfig):
        _smoke_lm(small)
    elif isinstance(cfg, ViTConfig):
        _smoke_vit(small)
    elif isinstance(cfg, DiTConfig):
        _smoke_dit(small)
    elif isinstance(cfg, EffNetConfig):
        _smoke_effnet(small)
    else:
        pytest.fail(f"unknown family {type(cfg)}")


def test_full_configs_match_literature():
    """Full (non-reduced) param counts are in the right ballpark."""
    expected = {
        "dbrx-132b": 132e9, "granite-34b": 34e9, "olmo-1b": 1.2e9,
        "vit-l16": 307e6, "deit-b": 87e6, "vit-s16": 22e6,
        "dit-b2": 130e6, "dit-s2": 33e6, "efficientnet-b7": 66e6,
    }
    for arch, n in expected.items():
        got = get_arch(arch).n_params()
        assert abs(got - n) / n < 0.15, f"{arch}: {got:.3g} vs {n:.3g}"


def test_moe_smoke_is_actually_moe():
    cfg = reduced(get_arch("dbrx-132b"))
    assert cfg.moe and cfg.n_experts >= 2
    p = transformer.init(RNG, cfg)
    assert "moe" in jax.tree_util.tree_flatten_with_path(p)[0][3][0][0].key \
        or "moe" in str(jax.tree_util.tree_structure(p))


def test_window_attention_variant():
    cfg = dataclasses.replace(reduced(get_arch("granite-34b")),
                              attention="window", window=8)
    p = transformer.init(RNG, cfg)
    toks = jax.random.randint(RNG, (1, 32), 0, cfg.vocab_size)
    logits, _ = transformer.forward(p, toks, cfg)
    assert bool(jnp.isfinite(logits).all())
    # window attention differs from full attention beyond the window
    full = dataclasses.replace(cfg, attention="full", window=0)
    lf, _ = transformer.forward(p, toks, full)
    assert not np.allclose(np.asarray(logits), np.asarray(lf))


def test_vit_resolution_transfer():
    """cls_384 finetune cell: pos-emb interpolation to a new resolution."""
    cfg = reduced(get_arch("vit-l16"))
    p = vit.init(RNG, cfg)
    img = jax.random.normal(RNG, (1, cfg.img_res * 2, cfg.img_res * 2, 3))
    logits = vit.forward(p, img, cfg)
    assert logits.shape == (1, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
