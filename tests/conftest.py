"""Test config. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only the dry-run creates 512 placeholder devices (in its own process)."""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
