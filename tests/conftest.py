"""Test config. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only the dry-run creates 512 placeholder devices (in its own process).

Also installs a minimal ``hypothesis`` fallback when the real package is
absent (this container has no network): ``@given`` runs each test over a
small deterministic sample of the strategy space instead of a search. The
real hypothesis is used automatically whenever it is importable.
"""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import inspect
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng):
            return self._draw(rng)

    class _DataObject:
        """Stand-in for hypothesis's ``data()`` interactive draw object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randint(0, len(seq) - 1)])

    def _data():
        s = _Strategy(lambda rng: _DataObject(rng))
        s._is_data = True
        return s

    def _given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 10))
                for i in range(n):
                    rng = random.Random(0xF0C05 + i * 7919)
                    drawn = [s.example(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # hide the drawn params from pytest's fixture resolution
            # (real hypothesis does the same via its own wrapper signature)
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _settings(max_examples=10, **kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.booleans = _booleans
    st_mod.sampled_from = _sampled_from
    st_mod.data = _data
    hyp.strategies = st_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


@pytest.fixture
def rng():
    return np.random.default_rng(0)
