"""Test config. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only the dry-run creates 512 placeholder devices (in its own process).

Also installs a minimal ``hypothesis`` fallback when the real package is
absent (this container has no network): ``@given`` runs each test over a
small deterministic sample of the strategy space instead of a search. The
real hypothesis is used automatically whenever it is importable.
"""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import inspect
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng):
            return self._draw(rng)

    class _DataObject:
        """Stand-in for hypothesis's ``data()`` interactive draw object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randint(0, len(seq) - 1)])

    def _data():
        s = _Strategy(lambda rng: _DataObject(rng))
        s._is_data = True
        return s

    def _given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 10))
                for i in range(n):
                    rng = random.Random(0xF0C05 + i * 7919)
                    drawn = [s.example(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # hide the drawn params from pytest's fixture resolution
            # (real hypothesis does the same via its own wrapper signature)
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _settings(max_examples=10, **kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.booleans = _booleans
    st_mod.sampled_from = _sampled_from
    st_mod.data = _data
    hyp.strategies = st_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# shared ingest-equivalence helpers (tests/test_streaming.py and
# tests/test_pipeline.py pin the same byte-identity invariant — one copy
# of the stream generator and the byte-compare, so a save-format change
# cannot silently diverge the two harnesses)
# ---------------------------------------------------------------------------

def make_stream(seed, n=500, n_frames=None, dup_rate=0.35):
    """Video-shaped stream: sorted frames, mode-patterned crops (so
    clustering groups them), near-identical consecutive-frame duplicates
    (so pixel differencing fires)."""
    r = np.random.default_rng(seed)
    n_frames = n_frames or max(n // 5, 2)
    modes = r.random((20, 6, 6, 3)).astype(np.float32)
    pick = r.integers(0, 20, n)
    crops = np.clip(modes[pick] + r.normal(0, 0.05, (n, 6, 6, 3)), 0, 1
                    ).astype(np.float32)
    frames = np.sort(r.integers(0, n_frames, n))
    for i in range(1, n):
        if frames[i] == frames[i - 1] + 1 and r.random() < dup_rate:
            crops[i] = np.clip(
                crops[i - 1] + r.normal(0, 1e-3, crops[i].shape), 0, 1
            ).astype(np.float32)
    return crops, frames


def index_save_bytes(index, tag=None):
    """Byte-identity comparison unit (delegates to the one canonical
    implementation, ``TopKIndex.save_bytes``); ``tag`` is accepted for
    call-site readability only."""
    return index.save_bytes()


def make_chunks(rng_draw, n, max_chunks=12):
    """Random chunk split of an n-object stream (hypothesis draw helper):
    both equivalence harnesses must cut streams the same way, or their
    byte-identity properties silently exercise different partitions."""
    from hypothesis import strategies as st
    k = rng_draw(st.integers(1, max_chunks))
    if k == 1 or n < 2:
        return [n]
    cuts = sorted({rng_draw(st.integers(1, n - 1)) for _ in range(k - 1)})
    bounds = [0] + cuts + [n]
    return [b - a for a, b in zip(bounds, bounds[1:])]
