"""QueryService: multi-tenant continuous batching, admission control,
SLO accounting, and ingest/query backpressure.

Core property (the safety net for every future serving refactor):
**service equivalence** — N tenants' interleaved requests through the
continuous batcher return byte-identical frame sets to sequential
``query_many`` per tenant (each tenant on its own engine), including
across an archive shard rollover mid-flight — while the shared engine
issues strictly fewer GT-CNN invocations than the per-tenant total.
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.archive import ArchiveQueryEngine, ShardCatalog
from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig, ingest
from repro.core.streaming import StreamingIngestor
from repro.serve import QueryService, ServiceConfig

FEAT_DIM = 12
N_CLASSES = 5
GT_FLOPS = 1e9


def _cheap(batch):
    flat = batch.reshape(len(batch), -1)
    feats = (flat[:, :FEAT_DIM] * 10.0).astype(np.float32)
    probs = np.abs(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES]) + 1e-3
    return (probs / probs.sum(1, keepdims=True)).astype(np.float32), feats


def _gt_apply(batch):
    return np.rint(batch[:, 0, 0, 2] * 8).astype(np.int64) % N_CLASSES


def _stream(seed, n=300):
    r = np.random.default_rng(seed)
    modes = r.random((20, 6, 6, 3)).astype(np.float32)
    pick = r.integers(0, 20, n)
    crops = np.clip(modes[pick] + r.normal(0, 0.05, (n, 6, 6, 3)), 0, 1
                    ).astype(np.float32)
    frames = np.sort(r.integers(0, max(n // 5, 2), n))
    return crops, frames


CFG = IngestConfig(K=3, threshold=1.5, max_clusters=64, batch_size=32)


def _mk_engine(seed, n=300):
    crops, frames = _stream(seed, n)
    index, _ = ingest(crops, frames, _cheap, 1.0, CFG,
                      n_local_classes=N_CLASSES)
    return index


def _frames_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# service equivalence: batched == sequential per tenant
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.data())
def test_batched_service_equals_sequential_per_tenant(data):
    """Random tenants, random per-request class subsets and Kx, random
    batch-cycle size: every response's frame sets are byte-identical to
    the same request served alone on a per-tenant engine, and the shared
    engine classifies strictly fewer crops than the per-tenant engines
    combined (cross-tenant dedup)."""
    seed = data.draw(st.integers(0, 10_000), label="seed")
    n_tenants = data.draw(st.integers(2, 4), label="n_tenants")
    max_batch = data.draw(st.sampled_from([1, 2, 32]), label="max_batch")
    index = _mk_engine(seed)
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    service = QueryService(engine,
                           ServiceConfig(max_batch_requests=max_batch))

    # interleaved submissions: each tenant sends 2 requests with its own
    # class subset / Kx; overlap across tenants is what batching dedupes
    plans = []                       # (tenant, classes, Kx)
    for t in range(n_tenants):
        for _ in range(2):
            n_cls = data.draw(st.integers(1, 4), label="n_cls")
            classes = [data.draw(st.integers(0, N_CLASSES - 1))
                       for _ in range(n_cls)]
            Kx = data.draw(st.sampled_from([None, 1, 2]), label="Kx")
            plans.append((f"tenant{t}", classes, Kx))
    for tenant, classes, Kx in plans:
        assert service.submit(tenant, classes, Kx=Kx) is not None
    responses = service.run_until_idle()
    assert len(responses) == len(plans)
    assert service.stats.n_merged_calls == -(-len(plans) // max_batch)

    # reference: one fresh engine per tenant, requests replayed in order
    ref_engines = {f"tenant{t}": QueryEngine(index, gt_apply=_gt_apply,
                                             gt_flops_per_image=GT_FLOPS)
                   for t in range(n_tenants)}
    for resp, (tenant, classes, Kx) in zip(responses, plans):
        assert resp.request.tenant == tenant
        ref_results, _ = ref_engines[tenant].query_many(classes, Kx)
        assert len(resp.results) == len(ref_results)
        for got, ref in zip(resp.results, ref_results):
            assert got.queried_class == ref.queried_class
            assert got.matched_clusters == ref.matched_clusters
            _frames_equal(got.frames, ref.frames)

    # shared engine never pays more GT than the per-tenant engines; with
    # random (possibly disjoint) workloads strictness isn't guaranteed —
    # the deterministic overlap test below pins the strict case
    seq_gt = sum(e.stats.n_gt_invocations for e in ref_engines.values())
    assert engine.stats.n_gt_invocations <= seq_gt


def test_overlapping_tenants_strictly_fewer_gt_calls():
    """Three tenants asking for the same classes: the batcher dedupes the
    (class, Kx) pairs, so the shared engine verifies each candidate
    cluster once while per-tenant engines each pay for their own copy."""
    index = _mk_engine(2)
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    service = QueryService(engine)
    classes = list(range(N_CLASSES))
    for t in range(3):
        service.submit(f"tenant{t}", classes)
    service.run_until_idle()
    assert service.stats.n_shared_queries == 2 * N_CLASSES

    seq_gt = 0
    for _ in range(3):
        ref = QueryEngine(index, gt_apply=_gt_apply,
                          gt_flops_per_image=GT_FLOPS)
        ref.query_many(classes)
        seq_gt += ref.stats.n_gt_invocations
    assert engine.stats.n_gt_invocations > 0
    assert engine.stats.n_gt_invocations < seq_gt


def test_service_equivalence_across_shard_rollover():
    """Mixed query+ingest schedule through an ``ArchiveQueryEngine``:
    shards seal mid-flight between batch cycles, and every response stays
    byte-identical to per-tenant sequential ``query_many`` replayed at
    the same schedule points on an identical second archive."""
    crops, frames = _stream(3, n=360)
    bounds = np.linspace(0, len(crops), 7).astype(int)
    tenants = ["tenant0", "tenant1", "tenant2"]
    workloads = {"tenant0": [0, 1, 2], "tenant1": [1, 2, 3],
                 "tenant2": [2, 3, 4]}

    with tempfile.TemporaryDirectory() as d:
        cat_a = ShardCatalog.open(os.path.join(d, "a"))
        ing_a = StreamingIngestor(_cheap, 1.0, CFG,
                                  n_local_classes=N_CLASSES,
                                  catalog=cat_a, shard_objects=100)
        eng_a = ArchiveQueryEngine(cat_a, gt_apply=_gt_apply,
                                   gt_flops_per_image=GT_FLOPS,
                                   capacity=2, ingestor=ing_a)
        # ingest-priority: each offered chunk ingests before the cycle's
        # merged batch, so the reference schedule below is exact;
        # max_batch_requests=2 forces two cycles per 3-tenant round
        service = QueryService(
            eng_a, ServiceConfig(policy="ingest", max_batch_requests=2),
            ingestor=ing_a)

        cat_b = ShardCatalog.open(os.path.join(d, "b"))
        ing_b = StreamingIngestor(_cheap, 1.0, CFG,
                                  n_local_classes=N_CLASSES,
                                  catalog=cat_b, shard_objects=100)
        ref_engines = {t: ArchiveQueryEngine(cat_b, gt_apply=_gt_apply,
                                             gt_flops_per_image=GT_FLOPS,
                                             capacity=2, ingestor=ing_b)
                       for t in tenants}

        sealed_during_rounds = 0
        for lo, hi in zip(bounds, bounds[1:]):
            service.offer_ingest(crops[lo:hi], frames[lo:hi])
            for t in tenants:
                assert service.submit(t, workloads[t]) is not None
            n_shards_before = len(cat_a)
            responses = service.run_until_idle()
            sealed_during_rounds += len(cat_a) - n_shards_before
            assert len(responses) == len(tenants)

            # replay the same point on archive B: chunk first (the
            # ingest-priority cycle order), then each tenant alone
            ing_b.feed(crops[lo:hi], frames[lo:hi])
            ing_b.flush()
            by_tenant = {r.request.tenant: r for r in responses}
            for t in tenants:
                ref_results, _ = ref_engines[t].query_many(workloads[t])
                got = by_tenant[t].results
                assert len(got) == len(ref_results)
                for g, ref in zip(got, ref_results):
                    assert g.queried_class == ref.queried_class
                    assert g.matched == ref.matched
                    _frames_equal(g.frames, ref.frames)
        assert sealed_during_rounds >= 2     # rollover really happened
        assert len(cat_a) == len(cat_b)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_rejects_when_queue_full():
    engine = QueryEngine(_mk_engine(4), gt_apply=_gt_apply)
    service = QueryService(engine, ServiceConfig(max_queue_depth=2))
    assert service.submit("a", [0]) is not None
    assert service.submit("b", [1]) is not None
    assert service.submit("c", [2]) is None          # shed
    assert service.stats.n_rejected == 1
    assert service.tenant_stats("c").n_rejected == 1
    assert service.tenant_stats("c").n_submitted == 1
    responses = service.run_until_idle()
    assert len(responses) == 2                       # shed request never ran
    assert service.submit("c", [2]) is not None      # queue drained


def test_admission_per_tenant_inflight_cap():
    engine = QueryEngine(_mk_engine(5), gt_apply=_gt_apply)
    service = QueryService(engine,
                           ServiceConfig(max_inflight_per_tenant=1))
    assert service.submit("a", [0]) is not None
    assert service.submit("a", [1]) is None          # over the cap
    assert service.submit("b", [1]) is not None      # other tenants fine
    service.run_until_idle()
    assert service.submit("a", [1]) is not None      # cap released


def test_submit_validates_kx_before_admission():
    """A malformed request is rejected at submit — it must never poison a
    merged batch cycle (regression companion to the bool-Kx engine fix)."""
    engine = QueryEngine(_mk_engine(6), gt_apply=_gt_apply)
    service = QueryService(engine)
    with pytest.raises(TypeError):
        service.submit("a", [0, 1], Kx=True)
    with pytest.raises(ValueError):
        service.submit("a", [0, 1], Kx=[1])          # length mismatch
    assert service.pending_queries == 0


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def _mk_streaming_service(policy, **cfg_kw):
    ing = StreamingIngestor(_cheap, 1.0, CFG, n_local_classes=N_CLASSES)
    engine = QueryEngine(ing.index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    service = QueryService(engine, ServiceConfig(policy=policy, **cfg_kw),
                           ingestor=ing)
    return ing, engine, service


def test_query_priority_defers_ingest_until_idle():
    ing, engine, service = _mk_streaming_service("query")
    crops, frames = _stream(7, n=120)
    service.offer_ingest(crops[:60], frames[:60])
    service.offer_ingest(crops[60:], frames[60:])
    service.submit("a", [0, 1])
    responses = service.step()
    # queries ran, both chunks deferred: nothing was fed to the ingestor
    assert len(responses) == 1
    assert ing.stats.n_objects == 0
    assert service.stats.n_ingest_deferred == 2
    assert service.pending_ingest == 2
    service.step()                       # idle cycle: one chunk ingests
    assert service.stats.n_ingest_chunks == 1
    assert ing.stats.n_objects == 60
    service.run_until_idle()
    assert service.pending_ingest == 0
    assert ing.stats.n_objects == 120


def test_query_priority_sheds_oldest_chunk_on_backlog_overflow():
    ing, engine, service = _mk_streaming_service(
        "query", max_ingest_backlog=2)
    crops, frames = _stream(8, n=150)
    thirds = [(crops[i:i + 50], frames[i:i + 50]) for i in (0, 50, 100)]
    service.submit("a", [0])             # queries pin the backlog
    assert service.offer_ingest(*thirds[0])
    assert service.offer_ingest(*thirds[1])
    assert not service.offer_ingest(*thirds[2])      # overflow: shed oldest
    assert service.stats.n_ingest_shed_chunks == 1
    assert service.stats.n_ingest_shed_objects == 50
    assert service.pending_ingest == 2
    service.run_until_idle()
    # the oldest chunk is gone; the two freshest ingested in order
    assert ing.stats.n_objects == 100
    assert service.stats.n_ingest_chunks == 2


def test_ingest_priority_ingests_before_the_batch():
    ing, engine, service = _mk_streaming_service("ingest")
    crops, frames = _stream(9, n=80)
    service.offer_ingest(crops, frames)
    service.submit("a", list(range(N_CLASSES)))
    responses = service.step()
    assert service.stats.n_ingest_chunks == 1
    assert ing.stats.n_objects == 80
    assert len(responses) == 1
    # the cycle's answers see the chunk: identical to feed-then-query
    ing2 = StreamingIngestor(_cheap, 1.0, CFG, n_local_classes=N_CLASSES)
    ing2.feed(crops, frames)
    ing2.flush()
    ref, _ = QueryEngine(ing2.index, gt_apply=_gt_apply).query_many(
        list(range(N_CLASSES)))
    for got, want in zip(responses[0].results, ref):
        _frames_equal(got.frames, want.frames)


def test_prefetch_moves_gt_off_the_query_path():
    ing, engine, service = _mk_streaming_service("ingest")
    crops, frames = _stream(10, n=80)
    service.offer_ingest(crops, frames)
    service.submit("a", list(range(N_CLASSES)))
    responses = service.run_until_idle()
    assert service.stats.n_prefetch_gt > 0
    # every candidate the batch touched was already cached by prefetch
    assert service.last_batch.n_gt_invocations == 0
    assert all(r.n_gt_invocations == 0
               for resp in responses for r in resp.results)


# ---------------------------------------------------------------------------
# SLO accounting
# ---------------------------------------------------------------------------

def test_deadline_accounting_with_injected_clock():
    t = [0.0]
    engine = QueryEngine(_mk_engine(11), gt_apply=_gt_apply)
    service = QueryService(engine, clock=lambda: t[0])
    service.submit("a", [0], deadline_s=0.5)
    service.submit("b", [0], deadline_s=5.0)
    t[0] = 1.0                           # both complete at t=1.0
    responses = service.run_until_idle()
    by_tenant = {r.request.tenant: r for r in responses}
    assert by_tenant["a"].deadline_missed
    assert not by_tenant["b"].deadline_missed
    assert by_tenant["a"].latency_s == pytest.approx(1.0)
    ts = service.tenant_stats("a")
    assert ts.n_deadline_missed == 1 and ts.n_completed == 1
    assert ts.p50_s == pytest.approx(1.0)
    assert ts.p99_s == pytest.approx(1.0)
    assert service.slo.percentile_s(50.0) == pytest.approx(1.0)


def test_default_deadline_from_config():
    t = [0.0]
    engine = QueryEngine(_mk_engine(12), gt_apply=_gt_apply)
    service = QueryService(
        engine, ServiceConfig(default_deadline_s=0.25),
        clock=lambda: t[0])
    service.submit("a", [0])
    t[0] = 0.5
    (resp,) = service.run_until_idle()
    assert resp.deadline_missed
    # rejected requests never enter the latency distribution
    assert service.tenant_stats("a").latencies_s == [resp.latency_s]


def test_empty_tracker_percentiles_are_nan():
    engine = QueryEngine(_mk_engine(13), gt_apply=_gt_apply)
    service = QueryService(engine)
    assert np.isnan(service.slo.percentile_s(99.0))
    assert np.isnan(service.tenant_stats("ghost").p50_s)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_service_config_validation():
    with pytest.raises(ValueError):
        ServiceConfig(policy="balanced")
    with pytest.raises(ValueError):
        ServiceConfig(max_queue_depth=0)
    with pytest.raises(ValueError):
        ServiceConfig(max_batch_requests=0)
    with pytest.raises(ValueError):
        ServiceConfig(max_ingest_backlog=0)
    with pytest.raises(ValueError):
        ServiceConfig(max_inflight_per_tenant=0)
    with pytest.raises(ValueError):
        ServiceConfig(ingest_chunks_per_cycle=0)


def test_offer_ingest_without_ingestor_raises():
    engine = QueryEngine(_mk_engine(14), gt_apply=_gt_apply)
    service = QueryService(engine)
    with pytest.raises(ValueError):
        service.offer_ingest(np.zeros((1, 6, 6, 3), np.float32),
                             np.zeros(1, np.int64))
