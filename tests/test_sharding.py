"""Sharding rules unit tests (axis-name level, trivial 1-device mesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import param_shardings, spec_for_param
from repro.distributed.sharding import act_spec


@pytest.fixture(scope="module")
def mesh():
    # single CPU device, axes of size 1: rules still resolve axis names
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_attention_weight_specs(mesh):
    s = spec_for_param("layers/attn/wq", (4, 64, 64), mesh, stacked=True)
    assert s == P(None, "data", "model")
    s = spec_for_param("layers/attn/wo", (4, 64, 64), mesh, stacked=True)
    assert s == P(None, "model", "data")


def test_moe_expert_parallel_spec(mesh):
    s = spec_for_param("layers/moe/wi", (4, 8, 64, 128), mesh, stacked=True)
    assert s == P(None, "model", "data", None)
    s = spec_for_param("layers/moe/wo", (4, 8, 128, 64), mesh, stacked=True)
    assert s == P(None, "model", None, "data")


def test_embedding_and_head(mesh):
    assert spec_for_param("tok_embed", (1000, 64), mesh) == P("model", "data")
    assert spec_for_param("head/w", (64, 1000), mesh) == P("data", "model")


def test_norms_replicated(mesh):
    assert spec_for_param("layers/ln1/scale", (4, 64), mesh,
                          stacked=True) == P(None, None)
    assert spec_for_param("final_ln/bias", (64,), mesh) == P(None)


def test_indivisible_dims_fall_back_replicated():
    from repro.launch.mesh import make_mesh
    mesh2 = make_mesh((1, 1), ("data", "model"))
    # odd vocab not divisible by axis of size 1 is still "divisible";
    # simulate indivisibility via a fake axis size by checking rule shape
    s = spec_for_param("layers/attn/wq", (4, 63, 65), mesh2, stacked=True)
    assert s == P(None, "data", "model")   # size-1 axes always divide


def test_param_shardings_tree(mesh):
    from repro.common.config import LMConfig, reduced
    from repro.configs import get_arch
    from repro.models import transformer
    cfg = reduced(get_arch("olmo-1b"))
    shapes = jax.eval_shape(
        lambda: transformer.init(jax.random.PRNGKey(0), cfg))
    tree = param_shardings(shapes, mesh)
    flat = jax.tree.leaves(tree)
    assert len(flat) == len(jax.tree.leaves(shapes))
    # stacked layer weights keep leading None
    wq_spec = tree["layers"]["attn"]["wq"].spec
    assert wq_spec[0] is None


def test_act_specs(mesh):
    assert act_spec(mesh, "hidden") == P(("data",), None, None) or \
        act_spec(mesh, "hidden") == P("data", None, None)
    assert act_spec(mesh, "logits")[-1] == "model"
    assert act_spec(mesh, "kv_cache")[1] == "model"
    with pytest.raises(ValueError):
        act_spec(mesh, "nope")
