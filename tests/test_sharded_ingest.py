"""Sharded multi-stream ingest equivalence harness (DESIGN.md §13).

Core property: every stream driven through a ``ShardedIngestPipeline``
(one sharded megastep per stacked step, cluster tables device-resident
per stream slot) saves a *byte-identical index* — and identical stats
counters — to that stream's single-device ``StreamingIngestor`` run,
across random chunk splits, eviction boundaries, and archive shard
rollovers. Plus: deterministic stream → device placement stable across
``feed()`` chunkings, and ``make_ingest_mesh`` validation.

The multi-device cases need ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` exported BEFORE the first jax import (the dedicated
``sharded-ingest`` CI step does this); under the plain tier-1 run they
skip and the 1-device-mesh cases still pin the full identity chain.
"""
import os
import tempfile

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import index_save_bytes as _save_bytes
from conftest import make_chunks as _chunks
from conftest import make_stream as _stream
from repro.core.archive import ShardCatalog
from repro.core.ingest import IngestConfig
from repro.core.pipeline import IngestPipeline, ShardedIngestPipeline
from repro.core.streaming import (MultiStreamRunner, StreamPlacement,
                                  StreamingIngestor, make_sharded_runner)
from repro.launch.mesh import make_ingest_mesh

FEAT_DIM = 12
N_CLASSES = 5

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count "
           "(sharded-ingest CI step)")


def _cheap_fn(crops):
    """Jax-traceable, per-example-pure cheap-CNN stand-in (same stub as
    tests/test_pipeline.py)."""
    flat = crops.reshape(crops.shape[0], -1)
    feats = flat[:, :FEAT_DIM] * 10.0
    probs = jax.nn.softmax(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES] * 5.0,
                           axis=-1)
    return probs, feats


def _counters(stats):
    return (stats.n_objects, stats.n_cnn_invocations, stats.n_pixel_dedup,
            stats.n_evictions)


_CFG = dict(K=2, threshold=1.5, max_clusters=24, high_water=0.8,
            evict_frac=0.5)


def _reference(name_streams, cfg, chunkings):
    """Per-stream single-device fused-pipeline runs over the same chunk
    splits — the byte-identity baseline."""
    out = {}
    for nm, (crops, frames) in name_streams.items():
        ref = StreamingIngestor(None, 1e9, cfg,
                                pipeline=IngestPipeline(_cheap_fn, cfg))
        o = 0
        for k in chunkings[nm]:
            ref.feed(crops[o:o + k], frames[o:o + k])
            ref.flush()
            o += k
        out[nm] = ref.finish()
    return out


def _run_sharded(mesh, name_streams, cfg, chunkings, interleave=True):
    runner = make_sharded_runner(_cheap_fn, mesh, list(name_streams),
                                 cfg=cfg, cheap_flops_per_image=1e9)
    offs = {nm: 0 for nm in name_streams}
    rounds = max(len(c) for c in chunkings.values())
    for rnd in range(rounds):
        feeds = {}
        for nm, (crops, frames) in name_streams.items():
            if rnd >= len(chunkings[nm]):
                continue
            k = chunkings[nm][rnd]
            o = offs[nm]
            feeds[nm] = (crops[o:o + k], frames[o:o + k])
            offs[nm] = o + k
        if interleave:
            runner.feed(feeds)
            runner.flush()
        else:
            for nm, fd in feeds.items():
                runner.feed({nm: fd})
    return runner, runner.finish()


# ---------------------------------------------------------------------------
# the equivalence property: sharded == per-stream single-device
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.data())
def test_sharded_1device_mesh_equals_single_device(data):
    """1-device mesh (runs under plain tier-1): stacked sharded steps over
    2 streams save byte-identically to each stream's own single-device
    fused-pipeline run, over random chunk splits with evictions."""
    cfg = IngestConfig(batch_size=data.draw(st.sampled_from([32, 64]),
                                            label="batch"), **_CFG)
    streams, chunkings = {}, {}
    for i, nm in enumerate(["cam0", "cam1"]):
        seed = data.draw(st.integers(0, 10_000), label=f"seed{i}")
        n = data.draw(st.integers(0, 300), label=f"n{i}")
        streams[nm] = _stream(seed, n)
        chunkings[nm] = _chunks(data.draw, n)
    mesh = make_ingest_mesh(1)
    runner, out = _run_sharded(mesh, streams, cfg, chunkings)
    ref = _reference(streams, cfg, chunkings)
    for nm in streams:
        assert _save_bytes(out[nm][0], "sharded") == \
            _save_bytes(ref[nm][0], "single"), nm
        assert _counters(out[nm][1]) == _counters(ref[nm][1]), nm


@multi_device
@settings(max_examples=4, deadline=None)
@given(st.data())
def test_sharded_multi_device_equals_single_device(data):
    """THE tentpole property (ISSUE 9): sharded(4 streams, 2 devices) ==
    per-stream single-device, byte-identical per stream, including
    eviction boundaries, over random streams and chunk splits."""
    cfg = IngestConfig(batch_size=32, **_CFG)
    streams, chunkings = {}, {}
    for i, nm in enumerate(["cam0", "cam1", "cam2", "cam3"]):
        seed = data.draw(st.integers(0, 10_000), label=f"seed{i}")
        n = data.draw(st.integers(0, 250), label=f"n{i}")
        streams[nm] = _stream(seed, n)
        chunkings[nm] = _chunks(data.draw, n, max_chunks=6)
    mesh = make_ingest_mesh(2)
    runner, out = _run_sharded(mesh, streams, cfg, chunkings)
    assert runner.placement.assignment() == {
        "cam0": 0, "cam1": 1, "cam2": 0, "cam3": 1}
    ref = _reference(streams, cfg, chunkings)
    for nm in streams:
        assert _save_bytes(out[nm][0], "sharded") == \
            _save_bytes(ref[nm][0], "single"), nm
        assert _counters(out[nm][1]) == _counters(ref[nm][1]), nm


@multi_device
def test_sharded_rollover_shards_byte_identical():
    """Archive rollover mid-run on a 2-device mesh: every sealed shard
    file (and its manifest entry) matches the single-device rollover run
    byte for byte — seals fire per stream while other streams keep
    ingesting through the same stacked pipeline."""
    cfg = IngestConfig(batch_size=32, **_CFG)
    names = ["cam0", "cam1", "cam2", "cam3"]
    streams = {nm: _stream(7 * i + 1, 300) for i, nm in enumerate(names)}
    mesh = make_ingest_mesh(2)
    with tempfile.TemporaryDirectory() as d:
        cats = {nm: ShardCatalog.open(os.path.join(d, "sh_" + nm))
                for nm in names}
        runner = make_sharded_runner(
            _cheap_fn, mesh, names, cfg=cfg, cheap_flops_per_image=1e9,
            ingestor_kwargs={nm: dict(catalog=cats[nm], shard_objects=110)
                             for nm in names})
        for s in range(0, 300, 77):
            runner.feed({nm: (streams[nm][0][s:s + 77],
                              streams[nm][1][s:s + 77])
                         for nm in names})
        runner.finish()
        for nm in names:
            cat_r = ShardCatalog.open(os.path.join(d, "ref_" + nm))
            ref = StreamingIngestor(None, 1e9, cfg, catalog=cat_r,
                                    shard_objects=110,
                                    pipeline=IngestPipeline(_cheap_fn, cfg))
            for s in range(0, 300, 77):
                ref.feed(streams[nm][0][s:s + 77],
                         streams[nm][1][s:s + 77])
            ref.finish()
            assert len(cats[nm].shards) == len(cat_r.shards) > 1, nm
            from repro.core.index import saved_file_bytes
            for ms, mr in zip(cats[nm].shards, cat_r.shards):
                assert saved_file_bytes(
                    os.path.join(cats[nm].root, ms.path)) \
                    == saved_file_bytes(
                        os.path.join(cat_r.root, mr.path)), \
                    (nm, ms.shard_id)


def test_sharded_1device_rollover_byte_identical():
    """Rollover identity on the 1-device mesh so tier-1 pins the seal /
    reset-slot path without forced host devices."""
    cfg = IngestConfig(batch_size=32, **_CFG)
    crops, frames = _stream(3, 280)
    mesh = make_ingest_mesh(1)
    with tempfile.TemporaryDirectory() as d:
        cat_s = ShardCatalog.open(os.path.join(d, "sharded"))
        runner = make_sharded_runner(
            _cheap_fn, mesh, ["cam0"], cfg=cfg, cheap_flops_per_image=1e9,
            ingestor_kwargs={"cam0": dict(catalog=cat_s,
                                          shard_objects=100)})
        for s in range(0, 280, 90):
            runner.feed({"cam0": (crops[s:s + 90], frames[s:s + 90])})
        runner.finish()
        cat_r = ShardCatalog.open(os.path.join(d, "ref"))
        ref = StreamingIngestor(None, 1e9, cfg, catalog=cat_r,
                                shard_objects=100,
                                pipeline=IngestPipeline(_cheap_fn, cfg))
        for s in range(0, 280, 90):
            ref.feed(crops[s:s + 90], frames[s:s + 90])
        ref.finish()
        assert len(cat_s.shards) == len(cat_r.shards) > 1
        from repro.core.index import saved_file_bytes
        for ms, mr in zip(cat_s.shards, cat_r.shards):
            assert saved_file_bytes(os.path.join(cat_s.root, ms.path)) \
                == saved_file_bytes(os.path.join(cat_r.root, mr.path)), \
                ms.shard_id


# ---------------------------------------------------------------------------
# placement determinism (ISSUE 9 satellite: stable across feed chunkings)
# ---------------------------------------------------------------------------

def test_placement_round_robin_layout():
    pl = StreamPlacement(["a", "b", "c", "d", "e"], 2)
    assert pl.assignment() == {"a": 0, "b": 1, "c": 0, "d": 1, "e": 0}
    # device-major blocks, padded to a common width with None
    assert pl.slots == ["a", "c", "e", "b", "d", None]
    assert pl.n_slots == 6 and pl.width == 3
    assert pl.slot_of("b") == 3 and pl.device_of("b") == 1
    # pure function of (names, n_devices): reconstruction is identical
    assert StreamPlacement(["a", "b", "c", "d", "e"], 2).slots == pl.slots


def test_placement_validation():
    with pytest.raises(ValueError, match="at least one"):
        StreamPlacement([], 2)
    with pytest.raises(ValueError, match="duplicate"):
        StreamPlacement(["a", "a"], 2)
    with pytest.raises(ValueError, match="n_devices"):
        StreamPlacement(["a"], 0)


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_assignment_stable_across_feed_chunkings(data):
    """Regression (ISSUE 9): stream → device assignment — and every
    stream's final bytes — are a function of the stream set alone, not of
    how ``feed()`` calls were chunked or interleaved."""
    cfg = IngestConfig(batch_size=32, **_CFG)
    names = ["cam0", "cam1", "cam2"]
    streams = {nm: _stream(11 + i, 180) for i, nm in enumerate(names)}
    mesh = make_ingest_mesh(1)
    chunk_a = {nm: _chunks(data.draw, 180, max_chunks=5) for nm in names}
    chunk_b = {nm: _chunks(data.draw, 180, max_chunks=5) for nm in names}
    run_a, out_a = _run_sharded(mesh, streams, cfg, chunk_a,
                                interleave=True)
    run_b, out_b = _run_sharded(mesh, streams, cfg, chunk_b,
                                interleave=False)
    assert run_a.placement.assignment() == run_b.placement.assignment()
    assert run_a.placement.slots == run_b.placement.slots
    for nm in names:
        assert _save_bytes(out_a[nm][0], "a") == \
            _save_bytes(out_b[nm][0], "b"), nm


# ---------------------------------------------------------------------------
# mesh factory + pipeline validation
# ---------------------------------------------------------------------------

def test_make_ingest_mesh_validates_device_count():
    with pytest.raises(ValueError, match="n_devices must be >= 1"):
        make_ingest_mesh(0)
    too_many = jax.device_count() + 1
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_ingest_mesh(too_many)
    mesh = make_ingest_mesh(1)
    assert mesh.axis_names == ("data",) and mesh.size == 1


def test_make_ingest_mesh_import_has_no_device_side_effects():
    """The module contract: importing launch.mesh must not touch jax
    device state (no jax calls at module scope beyond the import)."""
    import ast
    import inspect

    from repro.launch import mesh as mesh_mod
    tree = ast.parse(inspect.getsource(mesh_mod))
    for node in tree.body:
        assert not isinstance(node, (ast.Expr, ast.Assign)) or \
            not any(isinstance(n, ast.Call)
                    for n in ast.walk(node)), ast.dump(node)


def test_sharded_pipeline_rejects_mismatched_cfg():
    cfg_a = IngestConfig(batch_size=32, **_CFG)
    cfg_b = IngestConfig(batch_size=64, **_CFG)
    mesh = make_ingest_mesh(1)
    shared = ShardedIngestPipeline(_cheap_fn, mesh, ["a", "b"], cfg=cfg_a)
    StreamingIngestor(None, 1e9, cfg_a, pipeline=shared.handle("a"))
    with pytest.raises(ValueError, match="one\\s+IngestConfig"):
        StreamingIngestor(None, 1e9, cfg_b, pipeline=shared.handle("b"))


def test_sharded_pipeline_slot_layout_validation():
    mesh = make_ingest_mesh(1)
    with pytest.raises(ValueError, match="multiple"):
        ShardedIngestPipeline(_cheap_fn, mesh, [])
    with pytest.raises(ValueError, match="duplicate"):
        ShardedIngestPipeline(_cheap_fn, mesh, ["a", "a"])
    with pytest.raises(ValueError, match="mesh"):
        ShardedIngestPipeline(_cheap_fn, None, ["a"])


def test_runner_rejects_foreign_pipeline_binding():
    cfg = IngestConfig(batch_size=32, **_CFG)
    mesh = make_ingest_mesh(1)
    shared = ShardedIngestPipeline(_cheap_fn, mesh, ["a", "b"], cfg=cfg)
    other = ShardedIngestPipeline(_cheap_fn, mesh, ["a"], cfg=cfg)
    ing = StreamingIngestor(None, 1e9, cfg, pipeline=other.handle("a"))
    with pytest.raises(ValueError, match="not bound to this"):
        MultiStreamRunner({"a": ing}, pipeline=shared)
    with pytest.raises(ValueError, match="exactly one"):
        MultiStreamRunner({"a": ing})


def test_sharded_one_dispatch_per_stacked_step():
    """Dispatch amortization — the point of the refactor: a stacked step
    over S streams issues ONE megastep dispatch (+ at most one shared
    tail) instead of S separate chains."""
    cfg = IngestConfig(batch_size=32, **_CFG)
    names = ["cam0", "cam1", "cam2"]
    streams = {nm: _stream(21 + i, 96) for i, nm in enumerate(names)}
    mesh = make_ingest_mesh(1)
    runner = make_sharded_runner(_cheap_fn, mesh, names, cfg=cfg,
                                 cheap_flops_per_image=1e9)
    runner.feed({nm: streams[nm] for nm in names})
    runner.finish()
    st_ = runner.pipeline.stats
    assert st_.n_steps * 2 >= st_.n_dispatches   # <= 2 dispatches/step
    assert st_.n_batches > st_.n_steps           # stacking actually shared
