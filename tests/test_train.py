"""Training substrate: optimizer, loop, checkpoint/restart, compression,
elastic helpers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt
from repro.train import compression as comp
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StepTimer
from repro.train.train_loop import TrainConfig, train


def _quadratic_problem():
    """loss = |X w - y|^2 with y in the column span (optimum loss = 0)."""
    x = jnp.array([[1.0, 2.0], [3.0, 1.0], [0.5, -1.0]])
    y = x @ jnp.array([[1.0], [-1.0]])       # w* = (1, -1)

    def loss_fn(params, batch, rng):
        pred = x @ params["w"]
        l = jnp.mean((pred - y) ** 2)
        return l, {"l": l}

    params = {"w": jnp.zeros((2, 1))}
    return loss_fn, params


def _iter(batches=None):
    while True:
        yield {"dummy": jnp.zeros((4, 1))}


def test_adamw_converges():
    loss_fn, params = _quadratic_problem()
    cfg = opt.OptConfig(lr=0.1, warmup_steps=5, total_steps=200,
                        weight_decay=0.0)
    params2, hist = train(loss_fn, params, _iter(),
                          cfg, TrainConfig(steps=200, log_every=50))
    assert hist[-1]["loss"] < 1e-3


def test_lr_schedule_shapes():
    cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        schedule="cosine", min_lr_frac=0.1)
    lrs = [float(opt.lr_at(cfg, s)) for s in range(100)]
    assert lrs[0] < lrs[9]                      # warmup
    assert max(lrs) == pytest.approx(1.0, rel=1e-3)
    assert lrs[-1] < 0.2                        # decayed
    assert min(lrs[10:]) >= 0.099               # min_lr floor


def test_grad_clip():
    params = {"w": jnp.zeros((2,))}
    grads = {"w": jnp.array([3e4, 4e4])}
    state = opt.init(params)
    cfg = opt.OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                        total_steps=10, weight_decay=0.0)
    _, _, m = opt.update(params, grads, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(5e4, rel=1e-3)


def test_grad_accumulation_equivalence():
    """n_microbatches=2 must match a single big batch (linear model)."""
    x = jnp.arange(8.0).reshape(8, 1)

    def loss_fn(params, batch, rng):
        l = jnp.mean((batch["x"] * params["w"] - 1.0) ** 2)
        return l, {}

    params = {"w": jnp.ones((1,))}
    ocfg = opt.OptConfig(lr=0.01, warmup_steps=0, total_steps=10,
                         weight_decay=0.0, clip_norm=0.0)
    from repro.train.train_loop import make_train_step
    s1 = make_train_step(loss_fn, ocfg, TrainConfig(n_microbatches=1),
                         donate=False)
    s2 = make_train_step(loss_fn, ocfg, TrainConfig(n_microbatches=2),
                         donate=False)
    st = opt.init(params)
    rng = jax.random.PRNGKey(0)
    p1, *_ = s1(params, st, 0, {"x": x}, rng)
    # focuslint: disable=donated-read -- both steps were built with
    # donate=False, so make_train_step's conditional donation is off
    p2, *_ = s2(params, st, 0, {"x": x}, rng)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": np.arange(6).reshape(2, 3), "b": [np.ones(4), np.zeros(2)]}
    ckpt.save(7, tree, extra={"foo": 1})
    step, tree2, extra = ckpt.restore()
    assert step == 7 and extra["foo"] == 1
    np.testing.assert_array_equal(tree2["a"], tree["a"])
    np.testing.assert_array_equal(tree2["b"][0], tree["b"][0])


def test_checkpoint_prune_keeps_newest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"x": np.array([s])})
    assert ckpt.all_steps() == [3, 4]


def test_train_resume_from_checkpoint(tmp_path):
    loss_fn, params = _quadratic_problem()
    ocfg = opt.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                         weight_decay=0.0)
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    # run 50 steps then "crash"
    p_mid, _ = train(loss_fn, params, _iter(), ocfg,
                     TrainConfig(steps=50, log_every=25), ckpt=ckpt)
    assert ckpt.latest_step() == 50
    # resume to 100 — picks up params + opt state + iterator offset
    p_end, hist = train(loss_fn, params, _iter(), ocfg,
                        TrainConfig(steps=100, log_every=25), ckpt=ckpt,
                        resume=True)
    assert hist[-1]["loss"] < 1e-3
    assert hist[0]["step"] > 50      # actually resumed, not restarted


def test_compression_bf16_roundtrip():
    g = {"w": jnp.array([1.0, 1e-3, 300.0])}
    out = comp.cast_bf16(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=1e-2)


def test_compression_int8_error_feedback_unbiased():
    """With error feedback, repeated compression of a constant gradient
    averages to the true value (residual carries the bias)."""
    g = {"w": jnp.full((32,), 0.01234)}
    ef = comp.init_ef_state(g)
    total = np.zeros(32)
    n = 50
    for _ in range(n):
        deq, ef = comp.apply_ef(g, ef)
        total += np.asarray(deq["w"])
    np.testing.assert_allclose(total / n, 0.01234, rtol=2e-2)


def test_step_timer_straggler_detection():
    t = StepTimer(alpha=0.5, straggler_factor=2.0)
    for dt in (1.0, 1.0, 1.0, 5.0, 1.0):
        t.observe(dt)
    assert t.n_stragglers == 1


def test_preemption_checkpoint(tmp_path):
    """Simulated SIGTERM mid-training -> checkpoint written + clean return."""
    loss_fn, params = _quadratic_problem()
    ocfg = opt.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                         weight_decay=0.0)
    ckpt = CheckpointManager(str(tmp_path), async_save=False)

    calls = {"n": 0}

    def hook(m):
        calls["n"] += 1

    import repro.train.train_loop as tl

    class FakePreempt:
        def __init__(self, *a, **k):
            self.steps = 0

        @property
        def triggered(self):
            self.steps += 1
            return self.steps > 10

    orig = tl.PreemptionHandler
    tl.PreemptionHandler = FakePreempt
    try:
        train(loss_fn, params, _iter(), ocfg,
              TrainConfig(steps=100, log_every=10), ckpt=ckpt)
    finally:
        tl.PreemptionHandler = orig
    step, tree, extra = ckpt.restore()
    assert extra.get("preempted") is True
    assert 0 < step < 100
