"""End-to-end behaviour tests for the Focus system (paper Fig. 4 pipeline).

Uses a synthetic stream with exact generator labels; the "GT-CNN" oracle is
the generator label itself (the paper defines ground truth AS the GT-CNN
output, so any consistent oracle exercises the same machinery). The cheap
ingest CNN is actually *trained* (specialized) on the stream — this is the
full ingest -> top-K index -> cluster -> query loop, no stubs.
"""
import numpy as np
import pytest

from repro.common.config import CheapCNNConfig
from repro.core import (IngestConfig, dominant_classes, gt_frames_by_class,
                        ingest, precision_recall, query)
from repro.core.specialize import specialize
from repro.data import get_stream


@pytest.fixture(scope="module")
def pipeline():
    vs = get_stream("lausanne", duration_s=60, fps=10)
    crops, frames, tracks, labels = vs.objects_array()
    assert len(crops) > 50
    base = CheapCNNConfig("cheap", input_res=32, n_blocks=4, width=32,
                          feature_dim=128)
    sm = specialize(crops, labels, Ls=5, base_cfg=base, steps=150)
    apply_fn = sm.make_apply()
    cfg = IngestConfig(K=2, threshold=0.8, max_clusters=512)
    index, stats = ingest(crops, frames, apply_fn, base.flops_per_image(),
                          cfg, class_map=sm.class_map)
    return dict(crops=crops, frames=frames, labels=labels, index=index,
                stats=stats, sm=sm, base=base)


def _gt_oracle(labels, crops_all):
    """GT-CNN stand-in: exact oracle keyed by crop identity."""
    from repro.data.video import _class_proto
    protos = {}

    def gt_apply(crops):
        out = []
        for c in crops:
            best, bd = -1, 1e9
            for cls in np.unique(labels):
                if cls not in protos:
                    protos[cls] = _class_proto(int(cls), c.shape[0])
                d = float(np.abs(c - protos[cls]).mean())
                if d < bd:
                    best, bd = int(cls), d
            out.append(best)
        return np.array(out)

    return gt_apply


def test_ingest_builds_nonempty_index(pipeline):
    idx, stats = pipeline["index"], pipeline["stats"]
    assert idx.n_clusters > 0
    assert idx.n_objects == len(pipeline["crops"])
    assert stats.n_cnn_invocations <= len(pipeline["crops"])
    assert stats.cheap_flops > 0


def test_clustering_reduces_gt_work(pipeline):
    """The whole point: centroids << objects (redundancy elimination)."""
    idx = pipeline["index"]
    assert idx.n_clusters < 0.5 * idx.n_objects


def test_query_meets_accuracy_targets(pipeline):
    idx = pipeline["index"]
    labels, frames = pipeline["labels"], pipeline["frames"]
    gt_apply = _gt_oracle(labels, pipeline["crops"])
    gtf = gt_frames_by_class(labels, frames)
    dom = dominant_classes(labels)[:4]
    ps, rs = [], []
    for x in dom:
        res = query(idx, x, gt_apply, gt_flops_per_image=1e9)
        p, r = precision_recall(res.frames, gtf.get(x, np.array([])))
        ps.append(p)
        rs.append(r)
        # query cost accounting is consistent
        assert res.n_gt_invocations == res.n_candidate_clusters
        assert res.gt_flops == res.n_gt_invocations * 1e9
    assert np.mean(ps) >= 0.9, f"precision {ps}"
    assert np.mean(rs) >= 0.9, f"recall {rs}"


def test_query_cheaper_than_query_all(pipeline):
    """Query-time GT work must be far below Query-all (paper Fig. 7)."""
    idx = pipeline["index"]
    labels = pipeline["labels"]
    gt_apply = _gt_oracle(labels, pipeline["crops"])
    x = dominant_classes(labels)[0]
    res = query(idx, x, gt_apply, gt_flops_per_image=1e9)
    assert res.n_gt_invocations < 0.5 * len(pipeline["crops"])


def test_ingest_cheaper_than_ingest_all(pipeline):
    """Cheap-CNN ingest FLOPs far below GT-CNN-on-everything."""
    from repro.configs import get_arch
    from repro.launch.dryrun import model_flops  # not needed; use analytic
    stats = pipeline["stats"]
    gt_flops_per_image = 1e9     # ~ViT-L class of model on a 32px crop scale
    ingest_all = len(pipeline["crops"]) * gt_flops_per_image
    assert stats.cheap_flops < 0.25 * ingest_all


def test_querying_other_class_works(pipeline):
    """§4.3: a class outside the specialized set routes through OTHER."""
    idx = pipeline["index"]
    labels = pipeline["labels"]
    sm = pipeline["sm"]
    rare = [c for c in np.unique(labels)
            if c not in set(sm.class_map.global_ids.tolist())]
    if not rare:
        pytest.skip("no OTHER-class objects in this stream")
    gt_apply = _gt_oracle(labels, pipeline["crops"])
    res = query(idx, int(rare[0]), gt_apply, gt_flops_per_image=1e9)
    gtf = gt_frames_by_class(labels, pipeline["frames"])
    p, r = precision_recall(res.frames, gtf[int(rare[0])])
    assert r >= 0.5     # recall through the OTHER route
