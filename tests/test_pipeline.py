"""Fused ingest megastep equivalence harness (DESIGN.md §9).

Core property: a ``StreamingIngestor`` driven by the device-resident
``IngestPipeline`` (one-dispatch cheap-CNN → top-K → cluster megastep,
double-buffered) saves a *byte-identical index on disk* — and identical
``IngestStats`` counters — to the host-staged ``cheap_apply`` path over
the same stream, across random chunk splits, eviction boundaries, and
shard rollovers. Plus: the ≤ 2 dispatches-per-batch budget, the
``(batch_bucket, input_res)`` compile cache, and the megastep's fused
top-K outputs.
"""
import os
import tempfile

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import index_save_bytes as _save_bytes
from conftest import make_chunks as _chunks
from conftest import make_stream as _stream
from repro.core.archive import ShardCatalog
from repro.core.ingest import IngestConfig, ingest
from repro.core.pipeline import (IngestPipeline, batch_bucket,
                                 staged_cheap_apply)
from repro.core.streaming import MultiStreamRunner, StreamingIngestor

FEAT_DIM = 12
N_CLASSES = 5


def _cheap_fn(crops):
    """Jax-traceable, per-example-pure cheap-CNN stand-in: feats/probs are
    functions of the crop pixels alone (so bucket padding cannot leak
    across rows)."""
    flat = crops.reshape(crops.shape[0], -1)
    feats = flat[:, :FEAT_DIM] * 10.0
    probs = jax.nn.softmax(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES] * 5.0,
                           axis=-1)
    return probs, feats


def _counters(stats):
    return (stats.n_objects, stats.n_cnn_invocations, stats.n_pixel_dedup,
            stats.n_evictions)


# ---------------------------------------------------------------------------
# the equivalence property (pipeline == staged == one-shot, byte for byte)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.data())
def test_pipeline_equals_staged_byte_identical(data):
    """Random stream, random chunk split, eviction-heavy config: the
    fused-megastep ingestor saves byte-identically to the host-staged
    ingestor fed the same chunks — and to one-shot ``ingest()`` — with
    identical stats counters."""
    seed = data.draw(st.integers(0, 10_000), label="seed")
    n = data.draw(st.integers(0, 400), label="n")
    batch_size = data.draw(st.sampled_from([32, 64, 100]), label="batch")
    crops, frames = _stream(seed, n)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=24,
                       batch_size=batch_size, high_water=0.8,
                       evict_frac=0.5)

    one_index, one_stats = ingest(crops, frames,
                                  staged_cheap_apply(_cheap_fn, cfg),
                                  1e9, cfg)

    staged = StreamingIngestor(staged_cheap_apply(_cheap_fn, cfg), 1e9, cfg)
    piped = StreamingIngestor(None, 1e9, cfg,
                              pipeline=IngestPipeline(_cheap_fn, cfg))
    for size in _chunks(data.draw, n):
        taken, crops = crops[:size], crops[size:]
        tf, frames = frames[:size], frames[size:]
        staged.feed(taken, tf)
        staged.flush()
        piped.feed(taken, tf)
        piped.flush()                 # publication barrier mid-stream
    staged_index, staged_stats = staged.finish()
    pipe_index, pipe_stats = piped.finish()

    assert _save_bytes(pipe_index, "p") == _save_bytes(staged_index, "h")
    assert _save_bytes(pipe_index, "p") == _save_bytes(one_index, "o")
    assert _counters(pipe_stats) == _counters(staged_stats)
    assert _counters(pipe_stats) == _counters(one_stats)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([60, 110]))
def test_pipeline_rollover_shards_byte_identical(seed, shard_objects):
    """Shard rollover through the pipeline: every sealed shard file (and
    the catalog manifest) is byte-identical to the staged rollover run."""
    crops, frames = _stream(seed, 300)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=24, batch_size=48,
                       high_water=0.8, evict_frac=0.5)
    with tempfile.TemporaryDirectory() as d:
        cat_s = ShardCatalog.open(os.path.join(d, "staged"))
        ing_s = StreamingIngestor(staged_cheap_apply(_cheap_fn, cfg), 1e9,
                                  cfg, catalog=cat_s,
                                  shard_objects=shard_objects)
        cat_p = ShardCatalog.open(os.path.join(d, "piped"))
        ing_p = StreamingIngestor(None, 1e9, cfg, catalog=cat_p,
                                  shard_objects=shard_objects,
                                  pipeline=IngestPipeline(_cheap_fn, cfg))
        for s in range(0, len(crops), 77):
            ing_s.feed(crops[s:s + 77], frames[s:s + 77])
            ing_p.feed(crops[s:s + 77], frames[s:s + 77])
        ing_s.finish()
        ing_p.finish()
        assert len(cat_s.shards) == len(cat_p.shards) > 1
        from repro.core.index import saved_file_bytes
        for ms, mp in zip(cat_s.shards, cat_p.shards):
            assert saved_file_bytes(os.path.join(cat_s.root, ms.path)) \
                == saved_file_bytes(os.path.join(cat_p.root, mp.path)), \
                ms.shard_id


# ---------------------------------------------------------------------------
# dispatch budget, compile cache, fused top-K outputs
# ---------------------------------------------------------------------------

def test_pipeline_dispatch_budget_and_compile_cache():
    """The fused path issues at most 2 device dispatches per batch
    (megastep + optional unmatched tail), and ragged tail batches land in
    bucketed compile-cache keys — full batches all hit one key."""
    crops, frames = _stream(7, 500)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=64, batch_size=60,
                       pixel_diff=False)
    pipe = IngestPipeline(_cheap_fn, cfg)
    ing = StreamingIngestor(None, 1e9, cfg, pipeline=pipe)
    ing.feed(crops, frames)
    ing.finish()
    assert pipe.stats.n_batches == 9          # 8 full + 1 tail (20 rows)
    assert pipe.stats.n_dispatches <= 2 * pipe.stats.n_batches
    assert pipe.stats.dispatches_per_batch <= 2.0
    assert pipe.stats.n_objects == 500
    # compile cache: one key for the 8 full batches, one tail bucket (32)
    assert pipe.stats.compile_misses == 2
    assert pipe.stats.compile_hits == 7


def test_batch_bucket_shapes():
    assert batch_bucket(512, 512) == 512      # full batch: exact
    assert batch_bucket(700, 512) == 700      # oversize external batch
    for n, want in [(1, 8), (8, 8), (9, 16), (52, 64), (300, 512)]:
        assert batch_bucket(n, 512) == want
    assert batch_bucket(70, 100) == 100       # tail bucket capped at batch


def test_pipeline_topk_sink_matches_probs():
    """The megastep's fused Pallas top-K outputs agree with the batch's
    probabilities: descending values that index into each row's probs."""
    got = []
    crops, frames = _stream(3, 200)
    cfg = IngestConfig(K=3, threshold=1.5, max_clusters=64, batch_size=64,
                       pixel_diff=False)
    pipe = IngestPipeline(_cheap_fn, cfg,
                          topk_sink=lambda o, v, i: got.append((o, v, i)))
    ing = StreamingIngestor(None, 1e9, cfg, pipeline=pipe)
    ing.feed(crops, frames)
    index, _ = ing.finish()
    probs = np.asarray(jax.jit(_cheap_fn)(crops)[0])
    seen = 0
    for objs, vals, idxs in got:
        assert vals.shape == (len(objs), cfg.K)
        assert (np.diff(vals, axis=1) <= 1e-6).all()
        np.testing.assert_allclose(
            np.take_along_axis(probs[objs], idxs, 1), vals, atol=1e-6)
        seen += len(objs)
    assert seen == 200
    # the with-topk megastep graph (compiled only when a sink consumes
    # it) must still fold byte-identically to the staged path
    staged = StreamingIngestor(staged_cheap_apply(_cheap_fn, cfg), 1e9, cfg)
    staged.feed(crops, frames)
    staged_index, _ = staged.finish()
    assert _save_bytes(index, "p") == _save_bytes(staged_index, "s")


# ---------------------------------------------------------------------------
# contract errors
# ---------------------------------------------------------------------------

def test_ingestor_rejects_both_cheap_apply_and_pipeline():
    cfg = IngestConfig(batch_size=8)
    with pytest.raises(ValueError):
        StreamingIngestor(staged_cheap_apply(_cheap_fn, cfg), 1e9, cfg,
                          pipeline=IngestPipeline(_cheap_fn, cfg))


def test_rejected_constructor_does_not_consume_pipeline():
    """A StreamingIngestor constructor that raises (here: shard args
    without a catalog) must not leave the pipeline bound — the caller
    retries with a corrected constructor and the same pipeline."""
    cfg = IngestConfig(batch_size=8)
    pipe = IngestPipeline(_cheap_fn, cfg)
    with pytest.raises(ValueError):
        StreamingIngestor(None, 1e9, cfg, shard_objects=100, pipeline=pipe)
    StreamingIngestor(None, 1e9, cfg, pipeline=pipe)     # retry works


def test_pipeline_rejects_second_ingestor():
    cfg = IngestConfig(batch_size=8)
    pipe = IngestPipeline(_cheap_fn, cfg)
    StreamingIngestor(None, 1e9, cfg, pipeline=pipe)
    with pytest.raises(ValueError):
        StreamingIngestor(None, 1e9, cfg, pipeline=pipe)


def test_runner_rejects_pipeline_driven_ingestors():
    cfg = IngestConfig(batch_size=8)
    ing = StreamingIngestor(None, 1e9, cfg,
                            pipeline=IngestPipeline(_cheap_fn, cfg))
    with pytest.raises(ValueError):
        MultiStreamRunner({"a": ing}, _cheap_fn)


def test_pipeline_explicit_topk_wider_than_classes_raises():
    """cfg.K wider than the class width is clamped (TopKIndex semantics),
    but an explicit topk_k beyond it is a config error, matching
    ops.topk."""
    crops, frames = _stream(2, 50)
    cfg = IngestConfig(K=2, threshold=1.5, batch_size=16, pixel_diff=False)
    ing = StreamingIngestor(
        None, 1e9, cfg,
        pipeline=IngestPipeline(_cheap_fn, cfg, topk_k=N_CLASSES + 1))
    with pytest.raises(ValueError):
        ing.feed(crops, frames)
    # the clamped default path ingests fine with K > C
    wide = IngestConfig(K=N_CLASSES + 3, threshold=1.5, batch_size=16,
                        pixel_diff=False)
    ing2 = StreamingIngestor(None, 1e9, wide,
                             pipeline=IngestPipeline(_cheap_fn, wide))
    ing2.feed(crops, frames)
    index, _ = ing2.finish()
    assert index.n_objects == 50


def test_pipeline_rejects_mismatched_cfg():
    """A pipeline built with its own cfg must match the ingestor's —
    otherwise the megastep would cluster with one threshold/table size
    while the host folds with another."""
    pipe = IngestPipeline(_cheap_fn, IngestConfig(batch_size=8,
                                                  threshold=0.5))
    with pytest.raises(ValueError):
        StreamingIngestor(None, 1e9, IngestConfig(batch_size=8,
                                                  threshold=0.9),
                          pipeline=pipe)


def test_pipeline_rejects_non_fused_clustering():
    """The megastep hard-codes fused clustering semantics; a scan/batched
    config must be rejected loudly, not silently diverge from staged."""
    for variant in ("scan", "batched"):
        cfg = IngestConfig(batch_size=8, clustering=variant)
        with pytest.raises(ValueError):
            IngestPipeline(_cheap_fn, cfg)
        with pytest.raises(ValueError):
            StreamingIngestor(None, 1e9, cfg,
                              pipeline=IngestPipeline(_cheap_fn))


def test_unbound_pipeline_submit_raises():
    pipe = IngestPipeline(_cheap_fn, IngestConfig(batch_size=8))
    with pytest.raises(RuntimeError):
        pipe.submit(np.zeros((4, 6, 6, 3), np.float32),
                    np.arange(4), np.zeros(4, np.int64))
