"""Ingest-driver regressions: eviction remap consistency, transitive
pixel-track chaining, and the empty-stream class-map fix."""
import numpy as np
import pytest

from repro.core.index import ClassMap, TopKIndex
from repro.core.ingest import IngestConfig, ingest, pixel_tracks
from repro.core.streaming import StreamingIngestor

FEAT_DIM = 12
N_CLASSES = 5


def _cheap(batch):
    flat = batch.reshape(len(batch), -1)
    feats = (flat[:, :FEAT_DIM] * 10.0).astype(np.float32)
    probs = np.abs(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES]) + 1e-3
    return (probs / probs.sum(1, keepdims=True)).astype(np.float32), feats


# ---------------------------------------------------------------------------
# eviction remap correctness across repeated evict_smallest cycles
# ---------------------------------------------------------------------------

def test_repeated_evictions_keep_slot_cid_consistent():
    """Drive many evict_smallest cycles; the live slot -> cid map must stay
    a bijection onto real index clusters whose centroids/counts agree with
    the clustering state (previously only implicitly covered)."""
    r = np.random.default_rng(0)
    n, n_modes = 900, 120
    modes = r.random((n_modes, 6, 6, 3)).astype(np.float32)
    crops = np.clip(modes[r.integers(0, n_modes, n)]
                    + r.normal(0, 0.01, (n, 6, 6, 3)), 0, 1
                    ).astype(np.float32)
    frames = np.arange(n) // 4
    cfg = IngestConfig(K=2, threshold=0.8, max_clusters=16, batch_size=64,
                       pixel_diff=False, high_water=0.8, evict_frac=0.5)
    ing = StreamingIngestor(_cheap, 1e9, cfg)

    def check():
        state, slot_cid = ing._state, ing._slot_cid
        if state is None:
            return
        n_live = int(state.n)
        live_cids = slot_cid[:n_live]
        assert (live_cids >= 0).all()          # every live slot is mapped
        assert len(np.unique(live_cids)) == n_live       # bijection
        assert (slot_cid[n_live:] == -1).all()           # dead slots unmapped
        rows = ing.index.store.rows_of(live_cids)        # all cids exist
        np.testing.assert_array_equal(
            np.asarray(state.counts)[:n_live],
            ing.index.store.fold_counts[rows])
        np.testing.assert_allclose(
            np.asarray(state.centroids)[:n_live],
            ing.index.store.centroids[rows], atol=2e-3)

    for start in range(0, n, 128):
        ing.feed(crops[start:start + 128], frames[start:start + 128])
        check()
    index, stats = ing.finish()
    check()
    # at least two full eviction cycles actually ran
    per_cycle = max(1, int(int(cfg.high_water * cfg.max_clusters)
                           * cfg.evict_frac))
    assert stats.n_evictions >= 2 * per_cycle
    assert index.n_objects == n                # nothing lost to remapping


def test_eviction_does_not_orphan_duplicate_attachment():
    """Pixel-diff duplicates of roots whose cluster was evicted must still
    attach to that (now index-only) cluster — slot eviction removes a
    cluster from the live table, not from the index."""
    r = np.random.default_rng(1)
    n, n_modes = 600, 80
    modes = r.random((n_modes, 6, 6, 3)).astype(np.float32)
    crops = np.clip(modes[r.integers(0, n_modes, n)]
                    + r.normal(0, 0.01, (n, 6, 6, 3)), 0, 1
                    ).astype(np.float32)
    frames = np.sort(r.integers(0, 150, n))
    for i in range(1, n):
        if frames[i] == frames[i - 1] + 1 and r.random() < 0.4:
            crops[i] = np.clip(crops[i - 1]
                               + r.normal(0, 1e-3, crops[i].shape),
                               0, 1).astype(np.float32)
    cfg = IngestConfig(K=2, threshold=0.8, max_clusters=12, batch_size=48,
                       high_water=0.8, evict_frac=0.5)
    index, stats = ingest(crops, frames, _cheap, 1e9, cfg)
    assert stats.n_evictions > 0 and stats.n_pixel_dedup > 0
    assert index.n_objects == n


# ---------------------------------------------------------------------------
# pixel-track transitive chaining
# ---------------------------------------------------------------------------

def _track_crops(k, seed=0):
    """k near-identical crops, one per consecutive frame."""
    r = np.random.default_rng(seed)
    base = r.random((6, 6, 3)).astype(np.float32)
    crops = np.stack([
        np.clip(base + r.normal(0, 1e-4, base.shape), 0, 1).astype(np.float32)
        for _ in range(k)])
    return crops, np.arange(k)


def test_pixel_tracks_chain_transitively_across_three_frames():
    """An object persisting over >= 3 consecutive frames must chain all
    later sightings to the *first* sighting's root, not pairwise."""
    crops, frames = _track_crops(4)
    roots = pixel_tracks(crops, frames, threshold=0.02)
    np.testing.assert_array_equal(roots, [0, 0, 0, 0])


def test_pixel_tracks_break_on_frame_gap():
    crops, frames = _track_crops(3)
    frames = np.array([0, 1, 3])        # gap: frame 3 has no frame-2 match
    roots = pixel_tracks(crops, frames, threshold=0.02)
    np.testing.assert_array_equal(roots, [0, 0, 2])


def test_streaming_tracker_chains_across_chunk_boundaries():
    """The same >= 3-frame chain, split one frame per feed() chunk: every
    duplicate still lands in the root's cluster."""
    crops, frames = _track_crops(4, seed=2)
    cfg = IngestConfig(K=2, threshold=0.8, max_clusters=8, batch_size=4)
    ing = StreamingIngestor(_cheap, 1e9, cfg)
    for i in range(len(crops)):
        ing.feed(crops[i:i + 1], frames[i:i + 1])
    index, stats = ing.finish()
    assert stats.n_pixel_dedup == 3
    assert index.n_clusters == 1
    cid = int(index.store.row_cids[0])
    assert index.clusters[cid].members == [0, 1, 2, 3]
    np.testing.assert_array_equal(index.frames_of([cid]), [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# empty-stream class-map fix
# ---------------------------------------------------------------------------

def test_empty_stream_keeps_class_map_and_width(tmp_path):
    """Regression: ingest() of an empty stream used to build an index with
    n_local_classes=0 even when the class map pinned the width — queries on
    specialized classes then fell outside the rank matrix."""
    cmap = ClassMap(global_ids=np.array([10, 42, 99]))
    empty = np.zeros((0, 6, 6, 3), np.float32)
    no_frames = np.zeros((0,), np.int64)
    cfg = IngestConfig(K=2)

    index, stats = ingest(empty, no_frames, _cheap, 1e9, cfg,
                          class_map=cmap, n_local_classes=7)
    assert index.n_local_classes == 7
    assert index.class_map is cmap

    # width derived from the class map when not given explicitly
    index2, _ = ingest(empty, no_frames, _cheap, 1e9, cfg, class_map=cmap)
    assert index2.n_local_classes == cmap.n_local == 4
    assert index2.lookup(10) == [] and index2.lookup(777) == []

    # survives persistence
    path = str(tmp_path / "empty_spec")
    index2.save(path)
    loaded = TopKIndex.load(path)
    assert loaded.n_local_classes == 4
    assert loaded.class_map is not None
    np.testing.assert_array_equal(loaded.class_map.global_ids,
                                  cmap.global_ids)


def test_ingest_unsorted_frames_preserves_caller_object_ids():
    """The one-shot wrapper reorders processing by frame but member/object
    ids keep referring to the caller's array positions."""
    r = np.random.default_rng(3)
    n = 60
    crops = r.random((n, 6, 6, 3)).astype(np.float32)
    frames = r.integers(0, 10, n)       # unsorted
    cfg = IngestConfig(K=2, threshold=50.0, max_clusters=8, batch_size=16,
                       pixel_diff=False)
    index, _ = ingest(crops, frames, _cheap, 1e9, cfg)
    assert index.n_objects == n
    members = []
    for cid in index.store.row_cids[:index.store.n_rows].tolist():
        members.extend(index.clusters[cid].members)
    assert sorted(members) == list(range(n))
