"""Time-sharded archive: rollover invariant + cross-shard fan-out.

Core properties (the safety net for every future archive refactor):

* **Rollover invariant** — for random streams and random chunk splits,
  every shard sealed by a rolling ``StreamingIngestor`` is byte-identical
  on disk to a one-shot ``ingest()`` of exactly its window.
* **Fan-out equivalence** — ``ArchiveQueryEngine`` answers equal the union
  of per-shard ``QueryEngine`` answers, for any LRU capacity (including 1,
  which forces a reload per shard per round).
* **Warm across rollovers** — a long-lived archive engine fed
  ``IngestDelta``s keeps answering with zero query-path GT invocations
  while shards seal underneath it.
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import index as index_mod
from repro.core.archive import (ArchiveQueryEngine, LazyShardIndex,
                                ShardCatalog, ShardLoader)
from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig, ingest
from repro.core.streaming import StreamingIngestor

FEAT_DIM = 12
N_CLASSES = 5


def _cheap(batch):
    flat = batch.reshape(len(batch), -1)
    feats = (flat[:, :FEAT_DIM] * 10.0).astype(np.float32)
    probs = np.abs(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES]) + 1e-3
    return (probs / probs.sum(1, keepdims=True)).astype(np.float32), feats


def _gt_apply(batch):
    return np.rint(batch[:, 0, 0, 2] * 8).astype(np.int64) % N_CLASSES


def _stream(seed, n=400, dup_rate=0.35):
    r = np.random.default_rng(seed)
    n_frames = max(n // 5, 2)
    modes = r.random((20, 6, 6, 3)).astype(np.float32)
    pick = r.integers(0, 20, n)
    crops = np.clip(modes[pick] + r.normal(0, 0.05, (n, 6, 6, 3)), 0, 1
                    ).astype(np.float32)
    frames = np.sort(r.integers(0, n_frames, n))
    for i in range(1, n):
        if frames[i] == frames[i - 1] + 1 and r.random() < dup_rate:
            crops[i] = np.clip(
                crops[i - 1] + r.normal(0, 1e-3, crops[i].shape), 0, 1
            ).astype(np.float32)
    return crops, frames


def _chunks(rng_draw, n, max_chunks=8):
    k = rng_draw(st.integers(1, max_chunks))
    if k == 1 or n < 2:
        return [n]
    cuts = sorted({rng_draw(st.integers(1, n - 1)) for _ in range(k - 1)})
    bounds = [0] + cuts + [n]
    return [b - a for a, b in zip(bounds, bounds[1:])]


def _file_bytes(prefix):
    # format-agnostic: enumerates whatever files save() wrote (v3 npz or
    # v4 per-column npy)
    return index_mod.saved_file_bytes(prefix)


def _windows(catalog, n_total):
    """Per-shard [lo, hi) windows of the concatenated stream."""
    bases = [m.obj_base for m in catalog] + [n_total]
    return [(m, bases[i], bases[i + 1])
            for i, m in enumerate(catalog)]


CFG = IngestConfig(K=2, threshold=1.5, max_clusters=24, batch_size=32,
                   high_water=0.8, evict_frac=0.5)


# ---------------------------------------------------------------------------
# the rollover + fan-out property
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.data())
def test_rollover_shards_equal_oneshot_windows_and_union(data):
    """Random stream, random chunk split, rollover mid-stream: every
    sealed shard is byte-identical to a one-shot ingest of its window,
    and archive answers equal the per-shard engine union — with an LRU
    capacity of 1."""
    seed = data.draw(st.integers(0, 10_000), label="seed")
    n = data.draw(st.integers(1, 350), label="n")
    shard_objects = data.draw(st.sampled_from([60, 110, 170]),
                              label="shard_objects")
    crops, frames = _stream(seed, n)
    with tempfile.TemporaryDirectory() as d:
        catalog = ShardCatalog.open(os.path.join(d, "arch"))
        ing = StreamingIngestor(_cheap, 1e9, CFG, catalog=catalog,
                                shard_objects=shard_objects)
        rest_c, rest_f = crops, frames
        for size in _chunks(data.draw, n):
            ing.feed(rest_c[:size], rest_f[:size])
            rest_c, rest_f = rest_c[size:], rest_f[size:]
            ing.flush()                 # interleaved duplicate attaches
        ing.finish()

        assert len(catalog) == -(-n // shard_objects)
        for m, lo, hi in _windows(catalog, n):
            assert m.obj_base == lo and hi - lo <= shard_objects
            one, _ = ingest(crops[lo:hi], frames[lo:hi], _cheap, 1e9, CFG)
            p = os.path.join(d, "one")
            one.save(p)
            assert _file_bytes(os.path.join(catalog.root, m.path)) \
                == _file_bytes(p), f"shard {m.shard_id} != window ingest"
            assert m.n_objects == one.n_objects
            assert m.n_clusters == one.n_clusters

        archive = ArchiveQueryEngine(catalog, gt_apply=_gt_apply,
                                     gt_flops_per_image=1e9, capacity=1)
        results, batch = archive.query_many(list(range(N_CLASSES)))
        for cls, res in zip(range(N_CLASSES), results):
            parts, matched = [], []
            for m in catalog:
                shard_engine = QueryEngine(catalog.load_shard(m.shard_id),
                                           gt_apply=_gt_apply)
                r = shard_engine.query(cls)
                parts.append(r.frames)
                matched.extend((m.shard_id, c) for c in r.matched_clusters)
            want = (np.unique(np.concatenate(parts)) if parts
                    else np.array([], np.int64))
            np.testing.assert_array_equal(res.frames, want)
            assert res.matched == matched
        if len(catalog) > 1:
            assert batch.n_shard_evictions > 0     # capacity 1 really binds
        # warm round: same answers, zero GT
        warm_results, warm = archive.query_many(list(range(N_CLASSES)))
        assert warm.n_gt_invocations == 0
        for a, b in zip(results, warm_results):
            np.testing.assert_array_equal(a.frames, b.frames)


def test_rollover_unsorted_chunk_keeps_arrival_order_ids():
    """Default ids under rollover are arrival ranks, so shards sealed
    from an internally-unsorted chunk still match a one-shot ingest of
    their window (the window's objects in arrival order) — and oracle
    labels stay aligned."""
    r = np.random.default_rng(31)
    crops, frames = _stream(31, 100)
    perm = r.permutation(100)
    crops, frames = crops[perm], frames[perm]     # internally unsorted
    order = np.argsort(frames, kind="stable")
    with tempfile.TemporaryDirectory() as d:
        catalog = ShardCatalog.open(d)
        ing = StreamingIngestor(_cheap, 1e9, CFG, catalog=catalog,
                                shard_objects=60)
        ing.feed(crops, frames)
        ing.finish()
        assert len(catalog) == 2
        for m, lo, hi in _windows(catalog, 100):
            sel = np.sort(order[lo:hi])           # window in arrival order
            one, _ = ingest(crops[sel], frames[sel], _cheap, 1e9, CFG)
            p = os.path.join(d, "one")
            one.save(p)
            assert _file_bytes(catalog.path_of(m.shard_id)) \
                == _file_bytes(p), f"shard {m.shard_id}"
        # the global id line = per-window arrival-order concatenation
        sel_all = np.concatenate([np.sort(order[lo:hi])
                                  for _, lo, hi in _windows(catalog, 100)])
        labels = _gt_apply(crops[sel_all])
        oracle = ArchiveQueryEngine(catalog, oracle_labels=labels)
        via_gt = ArchiveQueryEngine(catalog, gt_apply=_gt_apply)
        a, _ = oracle.query_many(list(range(N_CLASSES)))
        b, _ = via_gt.query_many(list(range(N_CLASSES)))
        for ra, rb in zip(a, b):
            np.testing.assert_array_equal(ra.frames, rb.frames)
            assert ra.matched == rb.matched


def test_frame_window_rollover_seals_on_absolute_boundaries():
    """shard_frames=W seals at absolute [i*W, (i+1)*W) windows regardless
    of chunking, and the shard files still match one-shot ingests."""
    crops, frames = _stream(11, 300)
    W = 20
    with tempfile.TemporaryDirectory() as d:
        catalog = ShardCatalog.open(d)
        ing = StreamingIngestor(_cheap, 1e9, CFG, catalog=catalog,
                                shard_frames=W)
        for lo in range(0, len(crops), 77):
            ing.feed(crops[lo:lo + 77], frames[lo:lo + 77])
        ing.finish()
        assert len(catalog) >= 2
        for m, lo, hi in _windows(catalog, len(crops)):
            assert m.frame_lo // W == m.frame_hi // W       # one window
            np.testing.assert_array_equal(frames[lo:hi] // W,
                                          m.frame_lo // W)
            one, _ = ingest(crops[lo:hi], frames[lo:hi], _cheap, 1e9, CFG)
            p = os.path.join(d, "one")
            one.save(p)
            assert _file_bytes(catalog.path_of(m.shard_id)) \
                == _file_bytes(p)


def test_query_while_ingest_warm_across_rollovers():
    """A long-lived archive engine prefetching each flush delta answers
    like a cold engine on the same state, with zero query-path GT."""
    crops, frames = _stream(3, 500)
    cfg = IngestConfig(K=3, threshold=1.5, max_clusters=48, batch_size=48,
                       high_water=0.85, evict_frac=0.4)
    with tempfile.TemporaryDirectory() as d:
        catalog = ShardCatalog.open(d)
        ing = StreamingIngestor(_cheap, 1e9, cfg,
                                n_local_classes=N_CLASSES,
                                catalog=catalog, shard_objects=160)
        warm = ArchiveQueryEngine(catalog, gt_apply=_gt_apply,
                                  gt_flops_per_image=1e9, capacity=2,
                                  ingestor=ing)
        workload = list(range(N_CLASSES))
        sealed_seen = 0
        for start in range(0, len(crops), 130):
            ing.feed(crops[start:start + 130], frames[start:start + 130])
            delta = ing.flush()
            sealed_seen += len(delta.sealed_shards)
            warm.prefetch(delta)
            results, batch = warm.query_many(workload)
            assert batch.n_gt_invocations == 0   # prefetch took the cost
            cold = ArchiveQueryEngine(catalog, gt_apply=_gt_apply,
                                      gt_flops_per_image=1e9, capacity=2,
                                      ingestor=ing)
            cold_results, _ = cold.query_many(workload)
            for a, b in zip(results, cold_results):
                np.testing.assert_array_equal(a.frames, b.frames)
                assert a.matched == b.matched
        ing.finish()
        warm.prefetch(ing.flush())
        final, fb = warm.query_many(workload)
        assert fb.n_gt_invocations == 0
        assert sealed_seen + len(ing.flush().sealed_shards) \
            <= len(catalog) == 4


# ---------------------------------------------------------------------------
# catalog / loader plumbing
# ---------------------------------------------------------------------------

def _tiny_archive(d, n=180, shard_objects=70):
    crops, frames = _stream(17, n)
    catalog = ShardCatalog.open(d)
    ing = StreamingIngestor(_cheap, 1e9, CFG, catalog=catalog,
                            shard_objects=shard_objects)
    ing.feed(crops, frames)
    ing.finish()
    return catalog


def test_resumed_catalog_continues_obj_base_and_frame_line(tmp_path):
    """A new ingestor on a non-empty catalog must continue the global
    object-id line and the non-decreasing frame contract where the
    archive ends — not restart obj_base at 0 (which would alias oracle
    labels across runs)."""
    crops, frames = _stream(29, 160)
    catalog = _tiny_archive(str(tmp_path), n=160, shard_objects=70)
    n_first = sum(m.n_objects for m in catalog)
    resumed = ShardCatalog.open(str(tmp_path))
    ing = StreamingIngestor(_cheap, 1e9, CFG, catalog=resumed,
                            shard_objects=70)
    assert ing.shard_obj_base == n_first
    with pytest.raises(ValueError):        # frames behind the archive end
        ing.feed(crops[:4], np.zeros(4, np.int64))
    ing.feed(crops, frames + catalog.shards[-1].frame_hi)
    ing.finish()
    bases = [m.obj_base for m in resumed]
    assert bases == sorted(set(bases))     # strictly increasing, no alias
    assert bases[len(catalog.shards) - 1] + \
        catalog.shards[-1].n_objects == bases[len(catalog.shards)]


def test_catalog_roundtrips_through_json(tmp_path):
    catalog = _tiny_archive(str(tmp_path))
    reopened = ShardCatalog.open(str(tmp_path))
    assert reopened.shards == catalog.shards
    assert reopened.next_shard_id() == len(catalog)
    idx = reopened.load_shard(0)
    assert idx.n_clusters == catalog.shards[0].n_clusters


def test_shard_loader_lru_counts_hits_loads_evictions(tmp_path):
    catalog = _tiny_archive(str(tmp_path))           # 3 shards
    loader = ShardLoader(catalog, capacity=1)
    loader.get(0)
    loader.get(0)
    assert (loader.n_loads, loader.n_hits, loader.n_evictions) == (1, 1, 0)
    loader.get(1)
    assert loader.n_evictions == 1 and len(loader) == 1
    loader.get(0)                                    # reload after eviction
    assert loader.n_loads == 3
    with pytest.raises(ValueError):
        ShardLoader(catalog, capacity=0)
    with pytest.raises(KeyError):
        loader.get(99)


def test_rollover_requires_catalog_and_self_drive():
    with pytest.raises(ValueError):
        StreamingIngestor(_cheap, 1e9, CFG, shard_objects=10)
    with pytest.raises(ValueError):
        StreamingIngestor(None, 1e9, CFG,
                          catalog=ShardCatalog("unused"), shard_objects=10)
    with pytest.raises(ValueError):
        StreamingIngestor(_cheap, 1e9, CFG,
                          catalog=ShardCatalog("unused"), shard_objects=0)


def test_archive_engine_requires_exactly_one_labeler(tmp_path):
    catalog = _tiny_archive(str(tmp_path))
    with pytest.raises(ValueError):
        ArchiveQueryEngine(catalog)
    with pytest.raises(ValueError):
        ArchiveQueryEngine(catalog, gt_apply=_gt_apply,
                           oracle_labels=np.zeros(10, np.int64))


def test_archive_cached_label_is_read_only_probe(tmp_path):
    """cached_label validates against the live index or a resident shard
    and returns None otherwise — never pulling a cold shard through the
    LRU (a probe must not evict a hot shard)."""
    catalog = _tiny_archive(str(tmp_path))               # 3 shards
    engine = ArchiveQueryEngine(catalog, gt_apply=_gt_apply, capacity=1)
    for m in catalog:
        assert engine.cached_label(m.shard_id, 0) is None   # cold cache
    results, _ = engine.query_many(list(range(N_CLASSES)))
    assert engine.loader.n_loads == 3
    resident = next(iter(engine.loader._lru))            # only one resident
    sid, cid = next((s, c) for r in results for s, c in r.matched
                    if s == resident)
    assert engine.cached_label(sid, cid) == _gt_apply(
        catalog.load_shard(sid).rep_crops([cid]))[0]
    loads = engine.loader.n_loads
    for m in catalog:
        if m.shard_id != resident:
            engine.cached_label(m.shard_id, 0)           # non-resident
    assert engine.loader.n_loads == loads                # no disk pulls
    assert engine.cached_label(resident, 10**9) is None  # unknown cid


def test_oracle_mode_uses_obj_base_offsets(tmp_path):
    """Shard-local first-member ids + obj_base address the global
    oracle-label array correctly."""
    crops, frames = _stream(23, 220)
    labels = _gt_apply(crops)
    catalog = ShardCatalog.open(str(tmp_path))
    ing = StreamingIngestor(_cheap, 1e9, CFG, catalog=catalog,
                            shard_objects=90)
    ing.feed(crops, frames)
    ing.finish()
    oracle = ArchiveQueryEngine(catalog, oracle_labels=labels, capacity=2)
    via_gt = ArchiveQueryEngine(catalog, gt_apply=_gt_apply, capacity=2)
    a, batch_a = oracle.query_many(list(range(N_CLASSES)))
    b, batch_b = via_gt.query_many(list(range(N_CLASSES)))
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.frames, rb.frames)
        assert ra.matched == rb.matched
    # per-query fresh-verdict attribution sums to the batch total in both
    # labeler modes
    for results, batch in ((a, batch_a), (b, batch_b)):
        assert batch.n_gt_invocations > 0
        assert sum(r.n_gt_invocations for r in results) \
            == batch.n_gt_invocations


# ---------------------------------------------------------------------------
# crash safety, bytes-bounded LRU, quantized lazy shards
# ---------------------------------------------------------------------------

def test_catalog_seal_survives_manifest_write_failure(tmp_path, monkeypatch):
    """Failure injected between shard write and manifest rename: the old
    manifest stays intact and loadable, the in-memory shard list rolls
    back, and a retry reseals under the same shard id."""
    import repro.core.archive as archive_mod
    catalog = _tiny_archive(str(tmp_path))               # 3 shards
    before = [m.shard_id for m in catalog]
    crops, frames = _stream(5, 40)
    idx, _ = ingest(crops, frames, _cheap, 1e9, CFG)

    def boom(src, dst):
        raise OSError("injected: crash before manifest rename")

    monkeypatch.setattr(archive_mod.os, "replace", boom)
    with pytest.raises(OSError, match="injected"):
        catalog.seal(idx, int(frames[0]), int(frames[-1]), obj_base=10**6)
    monkeypatch.undo()

    # in-memory state rolled back; on-disk manifest untouched
    assert [m.shard_id for m in catalog] == before
    assert catalog.next_shard_id() == len(before)
    reopened = ShardCatalog.open(str(tmp_path))
    assert [m.shard_id for m in reopened] == before
    for m in reopened:
        assert reopened.load_shard(m.shard_id).n_clusters == m.n_clusters

    # retry reseals under the same id (overwriting the orphan files)
    meta = catalog.seal(idx, int(frames[0]), int(frames[-1]),
                        obj_base=10**6)
    assert meta.shard_id == len(before)
    assert meta.n_bytes == index_mod.saved_nbytes(
        catalog.path_of(meta.shard_id))
    again = ShardCatalog.open(str(tmp_path))
    assert [m.shard_id for m in again] == before + [meta.shard_id]


def test_shard_loader_bytes_bound_evicts_and_tracks_residency(tmp_path):
    """capacity_bytes bounds summed heap residency, re-checked on every
    get; the most recently used shard is never evicted even when it alone
    busts the budget."""
    catalog = _tiny_archive(str(tmp_path))               # 3 shards
    # 1-byte budget: any resident shard is over budget, so each get keeps
    # exactly the MRU shard and evicts the rest
    loader = ShardLoader(catalog, capacity_bytes=1)
    a = loader.get(0)
    a.lookup(0)                                          # grow rank cache
    assert len(loader) == 1 and loader.resident_bytes > 1
    loader.get(1)
    assert len(loader) == 1 and loader.n_evictions == 1
    loader.get(1)
    assert loader.n_hits == 1

    # a budget that fits everything: no evictions, residency is the sum
    # of the per-shard heap footprints
    roomy = ShardLoader(catalog, capacity_bytes=1 << 30)
    for m in catalog:
        roomy.get(m.shard_id).lookup(0)
    assert roomy.n_evictions == 0 and len(roomy) == 3
    assert roomy.resident_bytes == sum(
        int(roomy.get(m.shard_id).nbytes) for m in catalog)


def test_shard_loader_capacity_kwargs(tmp_path):
    """Exactly one bound applies: bytes (default), count via
    capacity_shards, or count via the deprecated capacity alias."""
    catalog = _tiny_archive(str(tmp_path))               # 3 shards
    # deprecated alias behaves exactly like capacity_shards
    by_alias = ShardLoader(catalog, capacity=1)
    by_kw = ShardLoader(catalog, capacity_shards=1)
    for loader in (by_alias, by_kw):
        loader.get(0)
        loader.get(1)
        assert loader.n_evictions == 1 and len(loader) == 1
    with pytest.raises(ValueError):
        ShardLoader(catalog, capacity_bytes=10, capacity_shards=2)
    with pytest.raises(ValueError):
        ShardLoader(catalog, capacity_shards=2, capacity=2)
    with pytest.raises(ValueError):
        ShardLoader(catalog, capacity_bytes=0)
    with pytest.raises(ValueError):
        ShardLoader(catalog, capacity_shards=0)
    # neither bound -> bytes default, all three shards fit
    default = ShardLoader(catalog)
    assert default.capacity_bytes is not None
    for m in catalog:
        default.get(m.shard_id)
    assert default.n_evictions == 0


def test_archive_stats_surface_loader_residency(tmp_path):
    """ArchiveStats mirrors the loader's residency after every
    query/prefetch: resident_bytes, hit rate, evictions."""
    catalog = _tiny_archive(str(tmp_path))               # 3 shards
    engine = ArchiveQueryEngine(catalog, gt_apply=_gt_apply, capacity=2)
    assert engine.stats.resident_bytes == 0
    engine.query_many(list(range(N_CLASSES)))
    assert engine.stats.n_shard_loads == 3
    assert engine.stats.n_shard_evictions >= 1          # capacity 2 binds
    assert engine.stats.resident_bytes == engine.loader.resident_bytes > 0
    # a loader that fits the whole archive: second round is all hits
    roomy = ArchiveQueryEngine(catalog, gt_apply=_gt_apply)
    roomy.query_many(list(range(N_CLASSES)))
    roomy.query_many(list(range(N_CLASSES)))
    assert roomy.stats.n_shard_hits == 3
    assert roomy.stats.shard_hit_rate == 0.5


def test_lazy_v4_shard_answers_match_eager_dequant(tmp_path):
    """The lossless-path identity: a v4 shard served lazily (mmap columns
    + in-kernel dequant rank) answers lookup / frames_of / rep_crops
    byte-identically to eagerly loading the same files into fp32."""
    crops, frames = _stream(41, 150)
    idx, _ = ingest(crops, frames, _cheap, 1e9, CFG)
    path = str(tmp_path / "shard")
    idx.save(path)                                       # v4 default
    import json as _json
    with open(path + ".json") as f:
        meta = _json.load(f)
    lazy = LazyShardIndex(path, meta)
    eager = index_mod.TopKIndex.load(path)
    assert (lazy.n_clusters, lazy.n_objects) \
        == (eager.n_clusters, eager.n_objects)
    for cls in range(N_CLASSES):
        for kx in range(1, CFG.K + 1):
            a, b = lazy.lookup(cls, Kx=kx), eager.lookup(cls, Kx=kx)
            assert a == b
            np.testing.assert_array_equal(lazy.frames_of(a),
                                          eager.frames_of(b))
    cids = sorted(eager.clusters)
    np.testing.assert_array_equal(lazy.rep_crops(cids),
                                  eager.rep_crops(cids))
    with pytest.raises(KeyError):
        lazy.frames_of([10**9])
    with pytest.raises(ValueError):
        lazy.lookup(0, Kx=CFG.K + 1)


def test_mixed_format_catalog_serves_v3_and_v4_shards(tmp_path):
    """A catalog holding a v3 (fp32 npz) and a v4 (quantized) shard
    serves both through one loader — eager for v3, lazy for v4 — and the
    fan-out still equals the per-shard union."""
    crops, frames = _stream(43, 140)
    idx1, _ = ingest(crops[:70], frames[:70], _cheap, 1e9, CFG)
    idx2, _ = ingest(crops[70:], frames[70:], _cheap, 1e9, CFG)
    catalog = ShardCatalog.open(str(tmp_path))
    catalog.seal(idx1, int(frames[0]), int(frames[69]), obj_base=0,
                 format=3)
    catalog.seal(idx2, int(frames[70]), int(frames[-1]), obj_base=70)
    loader = ShardLoader(catalog)
    assert not isinstance(loader.get(0), LazyShardIndex)
    assert isinstance(loader.get(1), LazyShardIndex)
    assert catalog.shards[0].n_bytes > 0
    assert catalog.shards[1].n_bytes > 0

    engine = ArchiveQueryEngine(catalog, gt_apply=_gt_apply)
    results, _ = engine.query_many(list(range(N_CLASSES)))
    for cls, res in zip(range(N_CLASSES), results):
        parts = []
        for m in catalog:
            shard_engine = QueryEngine(catalog.load_shard(m.shard_id),
                                       gt_apply=_gt_apply)
            parts.append(shard_engine.query(cls).frames)
        want = (np.unique(np.concatenate(parts)) if parts
                else np.array([], np.int64))
        np.testing.assert_array_equal(res.frames, want)
