"""specialize() edge cases (§4.3): Ls larger than the observed class set,
and single-class samples — the equal-class re-weighting path must stay
finite (no NaN) in both."""
import numpy as np
import jax.numpy as jnp

from repro.common.config import CheapCNNConfig
from repro.core.specialize import specialize

BASE = CheapCNNConfig("tiny", input_res=8, n_blocks=1, width=8,
                      feature_dim=16)


def _sample(labels, seed=0):
    r = np.random.default_rng(seed)
    crops = r.random((len(labels), 8, 8, 3)).astype(np.float32)
    return crops, np.asarray(labels)


def test_ls_larger_than_observed_classes():
    """Ls=6 but only 2 classes observed: the class map keeps just the
    observed classes and training weights stay finite."""
    crops, labels = _sample([3, 3, 3, 7, 7, 3, 7, 3])
    sm = specialize(crops, labels, Ls=6, base_cfg=BASE, steps=2,
                    batch_size=4)
    np.testing.assert_array_equal(sm.class_map.global_ids, [3, 7])
    assert sm.class_map.n_local == 3            # 2 observed + OTHER
    assert sm.cfg.n_classes == 3
    assert all(np.isfinite(h["loss"]) for h in sm.history)


def test_single_class_sample_weights_finite():
    """All samples from one class: OTHER gets zero weight, the observed
    class normalizes to 1, and the loss is finite (previously the
    ``w / w[counts > 0].mean()`` path could NaN on degenerate splits)."""
    crops, labels = _sample([5] * 10, seed=1)
    sm = specialize(crops, labels, Ls=4, base_cfg=BASE, steps=2,
                    batch_size=4)
    np.testing.assert_array_equal(sm.class_map.global_ids, [5])
    assert sm.class_map.n_local == 2
    assert all(np.isfinite(h["loss"]) for h in sm.history)
    # the model still classifies (probs finite, normalized)
    probs, feats = sm.make_apply(batch_pad=4)(crops)
    assert np.isfinite(probs).all() and np.isfinite(feats).all()
    np.testing.assert_allclose(probs.sum(1), 1.0, atol=1e-5)


def test_empty_sample_does_not_nan():
    """Degenerate empty sample: weights fall back to ones instead of
    dividing by an empty mean."""
    from repro.core.specialize import estimate_distribution
    classes, counts = estimate_distribution(np.zeros((0,), np.int64))
    assert len(classes) == 0 and len(counts) == 0
    # the weight formula itself (extracted): no positives -> all-ones
    c = np.zeros(3, np.float64)
    w = np.where(c > 0, c.sum() / np.maximum(c, 1), 0.0)
    pos = c > 0
    w = w / w[pos].mean() if pos.any() else np.ones_like(w)
    assert np.isfinite(w).all()
