"""Top-K index invariants + persistence (paper §3, §4.1, §5)."""
import numpy as np
import pytest

from repro.core.index import (ClassMap, Cluster, TopKIndex, saved_file_bytes,
                              saved_files)


def _mk_cluster(cid, probs, members, frames, d=8):
    c = Cluster(cid, centroid=np.zeros(d, np.float32),
                rep_crop=np.zeros((4, 4, 3), np.float32),
                mean_probs=np.zeros_like(probs))
    for m, f in zip(members, frames):
        c.add(m, f, np.zeros(d, np.float32), probs)
    return c


def test_topk_ranks_descending():
    probs = np.array([0.1, 0.5, 0.05, 0.3, 0.05], np.float32)
    c = _mk_cluster(0, probs, [1], [1])
    np.testing.assert_array_equal(c.topk(3), [1, 3, 0])


def test_lookup_respects_Kx():
    """§5: dynamic K_x <= K filters by ingest-time rank."""
    idx = TopKIndex(K=3, n_local_classes=5)
    probs = np.array([0.1, 0.5, 0.05, 0.3, 0.05], np.float32)
    idx.add_cluster(_mk_cluster(0, probs, [0, 1], [0, 1]))
    assert idx.lookup(1, Kx=1) == [0]
    assert idx.lookup(3, Kx=1) == []          # rank 1 >= Kx
    assert idx.lookup(3, Kx=2) == [0]
    assert idx.lookup(0, Kx=3) == [0]
    assert idx.lookup(2, Kx=3) == []          # rank 3 cut by K=3
    with pytest.raises(ValueError):
        idx.lookup(1, Kx=4)                   # beyond-K ranks never stored


def test_frames_union_sorted_unique():
    idx = TopKIndex(K=2, n_local_classes=3)
    p = np.array([0.7, 0.2, 0.1], np.float32)
    idx.add_cluster(_mk_cluster(0, p, [0, 1], [5, 3]))
    idx.add_cluster(_mk_cluster(1, p, [2], [5]))
    frames = idx.frames_of([0, 1])
    np.testing.assert_array_equal(frames, [3, 5])


def test_class_map_other_semantics():
    cmap = ClassMap(global_ids=np.array([10, 42, 99]))
    assert cmap.to_local(42) == 1
    assert cmap.to_local(7) == cmap.other_local == 3
    assert cmap.to_global(1) == 42
    assert cmap.to_global(3) == -1            # OTHER sentinel
    assert cmap.n_local == 4


def test_specialized_lookup_routes_unknown_class_to_other():
    cmap = ClassMap(global_ids=np.array([10, 42]))
    idx = TopKIndex(K=1, n_local_classes=3, class_map=cmap)
    # cluster strongly OTHER (local id 2)
    p = np.array([0.0, 0.1, 0.9], np.float32)
    idx.add_cluster(_mk_cluster(0, p, [0], [0]))
    # any class outside {10, 42} hits the OTHER clusters
    assert idx.lookup(777) == [0]
    assert idx.lookup(10) == []


def test_mean_probs_running_mean():
    idx = TopKIndex(K=1, n_local_classes=2)
    c = Cluster(0, np.zeros(4, np.float32), np.zeros((2, 2, 3)),
                np.zeros(2, np.float32))
    c.add(0, 0, np.zeros(4, np.float32), np.array([1.0, 0.0], np.float32))
    c.add(1, 1, np.zeros(4, np.float32), np.array([0.0, 1.0], np.float32))
    np.testing.assert_allclose(c.mean_probs, [0.5, 0.5])


def test_add_batch_matches_per_object_adds():
    """Vectorized store fold == sequential Cluster.add running means."""
    r = np.random.default_rng(0)
    B, D, C = 40, 8, 5
    cids = r.integers(0, 6, B)
    feats = r.normal(0, 1, (B, D)).astype(np.float32)
    probs = r.random((B, C)).astype(np.float32)
    crops = r.random((B, 4, 4, 3)).astype(np.float32)
    frames = np.arange(B) // 4

    idx = TopKIndex(K=3, n_local_classes=C)
    idx.add_batch(cids, feats, probs, np.arange(B), frames, crops=crops)

    # oracle: per-object dataclass adds
    oracle = {}
    for i in range(B):
        cid = int(cids[i])
        if cid not in oracle:
            oracle[cid] = Cluster(cid, np.zeros(D, np.float32),
                                  crops[i].copy(),
                                  np.zeros(C, np.float32))
        oracle[cid].add(i, int(frames[i]), feats[i], probs[i],
                        crop=crops[i])
    assert idx.n_clusters == len(oracle)
    assert idx.n_objects == B
    for cid, cl in oracle.items():
        got = idx.clusters[cid]
        assert got.count == cl.count
        assert got.members == cl.members
        assert got.frames == cl.frames
        np.testing.assert_allclose(got.centroid, cl.centroid, atol=1e-5)
        np.testing.assert_allclose(got.mean_probs, cl.mean_probs, atol=1e-5)
        np.testing.assert_allclose(got.rep_crop, cl.rep_crop)
    np.testing.assert_array_equal(
        idx.first_members(list(oracle)),
        [oracle[c].members[0] for c in oracle])


def test_attach_adds_members_without_moving_centroid():
    idx = TopKIndex(K=2, n_local_classes=3)
    p = np.array([0.7, 0.2, 0.1], np.float32)
    idx.add_cluster(_mk_cluster(0, p, [0, 1], [5, 3]))
    before = idx.clusters[0].centroid.copy()
    idx.attach(np.array([0, 0]), np.array([7, 8]), np.array([9, 9]))
    cl = idx.clusters[0]
    assert cl.count == 4 and cl.members == [0, 1, 7, 8]
    np.testing.assert_array_equal(cl.centroid, before)
    np.testing.assert_array_equal(idx.frames_of([0]), [3, 5, 9])


def test_add_cluster_same_cid_replaces():
    """Dict-era semantics: re-adding a cluster_id replaces the cluster."""
    p = np.array([0.7, 0.2, 0.1], np.float32)
    idx = TopKIndex(K=2, n_local_classes=3)
    idx.add_cluster(_mk_cluster(0, p, [0, 1], [0, 1]))
    idx.add_cluster(_mk_cluster(0, p, [5], [9]))
    assert idx.n_clusters == 1 and idx.n_objects == 1
    assert idx.clusters[0].members == [5]
    assert idx.lookup(0) == [0]
    np.testing.assert_array_equal(idx.frames_of([0]), [9])


def test_csr_refreshes_after_row_allocation():
    """Reading members/frames, then adding a cluster with no members, then
    reading the new cluster must not hit a stale CSR index."""
    p = np.array([0.7, 0.2, 0.1], np.float32)
    idx = TopKIndex(K=2, n_local_classes=3)
    idx.add_cluster(_mk_cluster(0, p, [0, 1], [0, 1]))
    np.testing.assert_array_equal(idx.frames_of([0]), [0, 1])   # builds CSR
    idx.add_cluster(Cluster(1, np.zeros(8, np.float32),
                            np.zeros((4, 4, 3), np.float32), p))  # no members
    assert idx.clusters[1].members == []
    np.testing.assert_array_equal(idx.frames_of([1]), [])


def test_unknown_cid_raises_keyerror():
    """Dict-era contract: querying an absent cluster id is an error, not a
    silent wrong answer."""
    p = np.array([0.7, 0.2, 0.1], np.float32)
    idx = TopKIndex(K=2, n_local_classes=3)
    idx.add_cluster(_mk_cluster(10, p, [0], [0]))
    with pytest.raises(KeyError):
        idx.frames_of([15])
    with pytest.raises(KeyError):
        idx.first_members([999])
    with pytest.raises(KeyError):
        TopKIndex(K=1, n_local_classes=2).frames_of([0])


def test_add_batch_crop_storage_deferred_until_supplied():
    """crops=None rows don't poison the store: a later crop-bearing batch
    allocates storage with the right shape."""
    idx = TopKIndex(K=2, n_local_classes=3)
    z = np.zeros((1, 4), np.float32)
    zp = np.zeros((1, 3), np.float32)
    idx.add_batch(np.array([0]), z, zp, np.array([0]), np.array([0]))
    idx.add_batch(np.array([1]), z, zp, np.array([1]), np.array([1]),
                  crops=np.ones((1, 2, 2, 3), np.float32))
    assert idx.store.rep_crops.shape[1:] == (2, 2, 3)
    np.testing.assert_allclose(idx.rep_crops([1]),
                               np.ones((1, 2, 2, 3), np.float32))


def test_clusters_view_is_read_only():
    p = np.array([0.7, 0.2, 0.1], np.float32)
    idx = TopKIndex(K=2, n_local_classes=3)
    idx.add_cluster(_mk_cluster(0, p, [0], [0]))
    with pytest.raises(TypeError):
        idx.clusters[0].add(1, 1, np.zeros(8, np.float32), p)


def test_load_legacy_dict_era_format(tmp_path):
    """Indexes written by the Dict[int, Cluster] implementation load into
    the SoA store unchanged (same JSON + NPZ layout)."""
    import json as _json
    path = str(tmp_path / "legacy")
    meta = {
        "K": 2,
        "n_local_classes": 3,
        "class_map": [3, 8],
        "clusters": {
            "0": {"count": 3, "members": [0, 1, 2], "frames": [0, 0, 1]},
            "5": {"count": 1, "members": [3], "frames": [2]},
        },
    }
    arrays = {
        "centroid_0": np.arange(8, dtype=np.float32),
        "probs_0": np.array([0.6, 0.3, 0.1], np.float32),
        "crop_0": np.zeros((4, 4, 3), np.float32),
        "centroid_5": np.ones(8, np.float32),
        "probs_5": np.array([0.1, 0.3, 0.6], np.float32),
        "crop_5": np.ones((4, 4, 3), np.float32),
    }
    np.savez_compressed(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        _json.dump(meta, f)

    idx = TopKIndex.load(path)
    assert idx.K == 2 and idx.n_clusters == 2 and idx.n_objects == 4
    assert idx.clusters[5].members == [3]
    assert idx.clusters[0].frames == [0, 0, 1]
    np.testing.assert_array_equal(idx.frames_of([0, 5]), [0, 1, 2])
    assert idx.lookup(3) == [0]               # local 0 top-ranked in cl 0
    # save -> load again: format round-trips through the store (v4 is
    # lossy-quantized, so centroids match to quantization step, not bit)
    idx.save(str(tmp_path / "again"))
    idx2 = TopKIndex.load(str(tmp_path / "again"))
    assert idx2.summary() == idx.summary()
    np.testing.assert_allclose(idx2.clusters[5].centroid,
                               idx.clusters[5].centroid, atol=1e-2)


def test_save_writes_columnar_npz(tmp_path):
    """Format v3 (pinned): one npz key per field, not O(M) per-cid keys."""
    idx = TopKIndex(K=2, n_local_classes=3)
    p = np.array([0.6, 0.3, 0.1], np.float32)
    for cid in range(20):
        idx.add_cluster(_mk_cluster(cid, p, [cid], [cid]))
    path = str(tmp_path / "col")
    idx.save(path, format=3)
    keys = set(np.load(path + ".npz").keys())
    assert keys == {"row_cids", "centroids", "mean_probs", "rep_crops",
                    "counts", "first_objs", "versions", "log_cids",
                    "log_objs", "log_frames", "att_cids", "att_objs",
                    "att_frames"}
    import json as _json
    with open(path + ".json") as f:
        meta = _json.load(f)
    assert meta["format"] == 3 and "clusters" not in meta
    idx2 = TopKIndex.load(path)
    assert idx2.summary() == idx.summary()
    assert idx2.clusters[7].members == [7]


def test_load_v2_single_log_format(tmp_path):
    """Format-2 files (single member log, no attach log) still load."""
    import json as _json
    path = str(tmp_path / "v2")
    np.savez_compressed(
        path + ".npz",
        row_cids=np.array([0, 1]),
        centroids=np.eye(2, 4, dtype=np.float32),
        mean_probs=np.array([[0.6, 0.3, 0.1], [0.1, 0.3, 0.6]], np.float32),
        rep_crops=np.zeros((2, 4, 4, 3), np.float32),
        counts=np.array([2, 1]), first_objs=np.array([0, 2]),
        versions=np.array([1, 1]),
        log_cids=np.array([0, 0, 1]), log_objs=np.array([0, 1, 2]),
        log_frames=np.array([0, 1, 2]))
    with open(path + ".json", "w") as f:
        _json.dump({"format": 2, "K": 2, "n_local_classes": 3,
                    "class_map": None}, f)
    idx = TopKIndex.load(path)
    assert idx.n_clusters == 2 and idx.n_objects == 3
    assert idx.clusters[0].members == [0, 1]
    np.testing.assert_array_equal(idx.frames_of([0, 1]), [0, 1, 2])
    assert idx.lookup(0) == [0] and idx.lookup(2) == [1]


def test_attach_timing_invisible_to_reads_and_save(tmp_path):
    """Members attached early (mid-stream flush) vs late (one-shot) read
    and save identically: the attach log is canonicalized by (obj, frame)."""
    def build(order):
        idx = TopKIndex(K=2, n_local_classes=3)
        p = np.array([0.6, 0.3, 0.1], np.float32)
        f = np.ones((1, 4), np.float32)
        c = np.zeros((1, 2, 2, 3), np.float32)
        idx.add_batch(np.array([0]), f, p[None], np.array([0]),
                      np.array([0]), crops=c)
        for obj, frame in order:
            idx.attach(np.array([0]), np.array([obj]), np.array([frame]))
        return idx
    early = build([(1, 1), (2, 2)])
    late = build([(2, 2), (1, 1)])
    assert early.clusters[0].members == late.clusters[0].members == [0, 1, 2]
    pa, pb = str(tmp_path / "a"), str(tmp_path / "b")
    early.save(pa)
    late.save(pb)
    assert saved_file_bytes(pa) == saved_file_bytes(pb)


def test_columnar_roundtrip_preserves_versions(tmp_path):
    """Centroid generation counters survive persistence, so a GT-label
    cache keyed on (cid, version) stays coherent across save/load."""
    idx = TopKIndex(K=2, n_local_classes=3)
    z = np.zeros((1, 4), np.float32)
    pr = np.array([[0.6, 0.3, 0.1]], np.float32)
    crop = np.zeros((1, 2, 2, 3), np.float32)
    for _ in range(3):      # three folds -> version 3
        idx.add_batch(np.array([0]), z, pr, np.array([0]), np.array([0]),
                      crops=crop)
    path = str(tmp_path / "ver")
    idx.save(path)
    idx2 = TopKIndex.load(path)
    row = idx2.store.row_of(0)
    assert int(idx2.store.versions[row]) == 3


def test_save_load_empty_index(tmp_path):
    idx = TopKIndex(K=2, n_local_classes=3)
    path = str(tmp_path / "empty")
    idx.save(path)
    idx2 = TopKIndex.load(path)
    assert idx2.n_clusters == 0 and idx2.lookup(0) == []


def test_save_load_roundtrip(tmp_path):
    cmap = ClassMap(global_ids=np.array([3, 8]))
    idx = TopKIndex(K=2, n_local_classes=3, class_map=cmap)
    p = np.array([0.6, 0.3, 0.1], np.float32)
    idx.add_cluster(_mk_cluster(0, p, [0, 1, 2], [0, 0, 1]))
    idx.add_cluster(_mk_cluster(1, p[::-1].copy(), [3], [2]))
    path = str(tmp_path / "index")
    idx.save(path)
    idx2 = TopKIndex.load(path)
    assert idx2.K == 2 and idx2.n_clusters == 2
    assert idx2.lookup(3) == idx.lookup(3)
    assert idx2.lookup(999) == idx.lookup(999)
    np.testing.assert_array_equal(idx2.frames_of([0, 1]),
                                  idx.frames_of([0, 1]))
    # v4 stores probs as uint8 with a per-row scale: max abs error is
    # rowmax / 255 / 2 (half a quantization step)
    np.testing.assert_allclose(idx2.clusters[0].mean_probs,
                               idx.clusters[0].mean_probs, atol=0.6 / 255)


def test_v4_file_layout(tmp_path):
    """Format v4: meta json + one raw .npy per column (mmap-able), no npz;
    quantized columns are int8/uint8 with per-row float32 scales."""
    import json as _json
    idx = TopKIndex(K=2, n_local_classes=3)
    p = np.array([0.6, 0.3, 0.1], np.float32)
    for cid in range(20):
        idx.add_cluster(_mk_cluster(cid, p, [cid], [cid]))
    path = str(tmp_path / "v4")
    idx.save(path)
    with open(path + ".json") as f:
        meta = _json.load(f)
    assert meta["format"] == 4 and meta["n_rows"] == 20
    assert not (tmp_path / "v4.npz").exists()
    for suffix in saved_files(path):          # suffixes: .json, .<col>.npy
        assert (tmp_path / ("v4" + suffix)).exists()
    cents = np.load(path + ".centroids_q.npy", mmap_mode="r")
    probs = np.load(path + ".mean_probs_q.npy", mmap_mode="r")
    crops = np.load(path + ".rep_crops_q.npy", mmap_mode="r")
    assert cents.dtype == np.int8 and probs.dtype == np.uint8
    assert crops.dtype == np.uint8
    assert np.load(path + ".centroid_scales.npy").dtype == np.float32
    assert np.load(path + ".prob_scales.npy").dtype == np.float32
    assert np.load(path + ".crop_qparams.npy").shape == (2,)


def test_v4_roundtrip_bounds_and_exact_ints(tmp_path):
    """v4 round-trip: int columns exact, float columns within one
    quantization step, lookup answers identical to the source index."""
    r = np.random.default_rng(3)
    B, D, C = 60, 8, 5
    idx = TopKIndex(K=3, n_local_classes=C)
    idx.add_batch(r.integers(0, 12, B),
                  r.normal(0, 2, (B, D)).astype(np.float32),
                  r.random((B, C)).astype(np.float32),
                  np.arange(B), np.arange(B) // 3,
                  crops=r.random((B, 4, 4, 3)).astype(np.float32))
    path = str(tmp_path / "rt")
    idx.save(path)
    idx2 = TopKIndex.load(path)
    assert idx2.summary() == idx.summary()
    for cid in idx.clusters:
        a, b = idx.clusters[cid], idx2.clusters[cid]
        assert a.members == b.members and a.frames == b.frames
        assert a.count == b.count
        step_c = np.abs(a.centroid).max() / 127
        np.testing.assert_allclose(b.centroid, a.centroid,
                                   atol=step_c / 2 + 1e-6)
        step_p = a.mean_probs.max() / 255
        np.testing.assert_allclose(b.mean_probs, a.mean_probs,
                                   atol=step_p / 2 + 1e-6)
    for g in range(C):
        for kx in range(1, 4):
            assert idx2.lookup(g, Kx=kx) == idx.lookup(g, Kx=kx)
    crops = idx.rep_crops(sorted(idx.clusters))
    crops2 = idx2.rep_crops(sorted(idx.clusters))
    span = crops.max() - crops.min()
    np.testing.assert_allclose(crops2, crops, atol=span / 255 / 2 + 1e-6)


def _answers(idx):
    """Full query surface of an index: every lookup x Kx, plus frames."""
    out = {}
    n = idx.n_local_classes + 2
    for g in range(n):
        for kx in range(1, idx.K + 1):
            cids = idx.lookup(g, Kx=kx)
            out[(g, kx)] = (cids, idx.frames_of(cids).tolist())
    return out


def test_migration_v1_v2_v3_to_v4(tmp_path):
    """Property: any legacy on-disk format, loaded and re-saved as v4,
    answers every query identically.  Per-row prob values are kept far
    apart so lossy quantization cannot collapse an ingest-time rank."""
    import json as _json
    # --- v1: dict-era per-cid arrays
    p1 = str(tmp_path / "v1")
    np.savez_compressed(
        p1 + ".npz",
        centroid_0=np.arange(4, dtype=np.float32),
        probs_0=np.array([0.7, 0.2, 0.1], np.float32),
        crop_0=np.zeros((2, 2, 3), np.float32),
        centroid_5=np.ones(4, np.float32),
        probs_5=np.array([0.1, 0.2, 0.7], np.float32),
        crop_5=np.ones((2, 2, 3), np.float32))
    with open(p1 + ".json", "w") as f:
        _json.dump({"K": 2, "n_local_classes": 3, "class_map": [3, 8],
                    "clusters": {
                        "0": {"count": 2, "members": [0, 1],
                              "frames": [0, 1]},
                        "5": {"count": 1, "members": [2], "frames": [2]},
                    }}, f)
    # --- v2: columnar, single member log
    p2 = str(tmp_path / "v2")
    np.savez_compressed(
        p2 + ".npz",
        row_cids=np.array([0, 1]),
        centroids=np.eye(2, 4, dtype=np.float32),
        mean_probs=np.array([[0.7, 0.2, 0.1], [0.1, 0.2, 0.7]], np.float32),
        rep_crops=np.zeros((2, 2, 2, 3), np.float32),
        counts=np.array([2, 1]), first_objs=np.array([0, 2]),
        versions=np.array([1, 1]),
        log_cids=np.array([0, 0, 1]), log_objs=np.array([0, 1, 2]),
        log_frames=np.array([0, 1, 2]))
    with open(p2 + ".json", "w") as f:
        _json.dump({"format": 2, "K": 2, "n_local_classes": 3,
                    "class_map": None}, f)
    # --- v3: current fp32 columnar with attach log
    p3 = str(tmp_path / "v3")
    idx3 = TopKIndex(K=2, n_local_classes=3)
    idx3.add_batch(np.array([0, 0, 1]),
                   np.eye(3, 4, dtype=np.float32),
                   np.array([[0.7, 0.2, 0.1], [0.6, 0.3, 0.1],
                             [0.1, 0.2, 0.7]], np.float32),
                   np.arange(3), np.array([0, 1, 2]),
                   crops=np.random.default_rng(0)
                   .random((3, 2, 2, 3)).astype(np.float32))
    idx3.attach(np.array([1]), np.array([3]), np.array([4]))
    idx3.save(p3, format=3)

    for tag, path in (("v1", p1), ("v2", p2), ("v3", p3)):
        src = TopKIndex.load(path)
        migrated_path = str(tmp_path / (tag + "_as_v4"))
        src.save(migrated_path)          # default = format 4
        dst = TopKIndex.load(migrated_path)
        assert dst.summary() == src.summary(), tag
        assert _answers(dst) == _answers(src), tag
