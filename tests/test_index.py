"""Top-K index invariants + persistence (paper §3, §4.1, §5)."""
import numpy as np
import pytest

from repro.core.index import ClassMap, Cluster, TopKIndex


def _mk_cluster(cid, probs, members, frames, d=8):
    c = Cluster(cid, centroid=np.zeros(d, np.float32),
                rep_crop=np.zeros((4, 4, 3), np.float32),
                mean_probs=np.zeros_like(probs))
    for m, f in zip(members, frames):
        c.add(m, f, np.zeros(d, np.float32), probs)
    return c


def test_topk_ranks_descending():
    probs = np.array([0.1, 0.5, 0.05, 0.3, 0.05], np.float32)
    c = _mk_cluster(0, probs, [1], [1])
    np.testing.assert_array_equal(c.topk(3), [1, 3, 0])


def test_lookup_respects_Kx():
    """§5: dynamic K_x <= K filters by ingest-time rank."""
    idx = TopKIndex(K=3, n_local_classes=5)
    probs = np.array([0.1, 0.5, 0.05, 0.3, 0.05], np.float32)
    idx.add_cluster(_mk_cluster(0, probs, [0, 1], [0, 1]))
    assert idx.lookup(1, Kx=1) == [0]
    assert idx.lookup(3, Kx=1) == []          # rank 1 >= Kx
    assert idx.lookup(3, Kx=2) == [0]
    assert idx.lookup(0, Kx=3) == [0]
    assert idx.lookup(2, Kx=3) == []          # rank 3 cut by K=3


def test_frames_union_sorted_unique():
    idx = TopKIndex(K=2, n_local_classes=3)
    p = np.array([0.7, 0.2, 0.1], np.float32)
    idx.add_cluster(_mk_cluster(0, p, [0, 1], [5, 3]))
    idx.add_cluster(_mk_cluster(1, p, [2], [5]))
    frames = idx.frames_of([0, 1])
    np.testing.assert_array_equal(frames, [3, 5])


def test_class_map_other_semantics():
    cmap = ClassMap(global_ids=np.array([10, 42, 99]))
    assert cmap.to_local(42) == 1
    assert cmap.to_local(7) == cmap.other_local == 3
    assert cmap.to_global(1) == 42
    assert cmap.to_global(3) == -1            # OTHER sentinel
    assert cmap.n_local == 4


def test_specialized_lookup_routes_unknown_class_to_other():
    cmap = ClassMap(global_ids=np.array([10, 42]))
    idx = TopKIndex(K=1, n_local_classes=3, class_map=cmap)
    # cluster strongly OTHER (local id 2)
    p = np.array([0.0, 0.1, 0.9], np.float32)
    idx.add_cluster(_mk_cluster(0, p, [0], [0]))
    # any class outside {10, 42} hits the OTHER clusters
    assert idx.lookup(777) == [0]
    assert idx.lookup(10) == []


def test_mean_probs_running_mean():
    idx = TopKIndex(K=1, n_local_classes=2)
    c = Cluster(0, np.zeros(4, np.float32), np.zeros((2, 2, 3)),
                np.zeros(2, np.float32))
    c.add(0, 0, np.zeros(4, np.float32), np.array([1.0, 0.0], np.float32))
    c.add(1, 1, np.zeros(4, np.float32), np.array([0.0, 1.0], np.float32))
    np.testing.assert_allclose(c.mean_probs, [0.5, 0.5])


def test_save_load_roundtrip(tmp_path):
    cmap = ClassMap(global_ids=np.array([3, 8]))
    idx = TopKIndex(K=2, n_local_classes=3, class_map=cmap)
    p = np.array([0.6, 0.3, 0.1], np.float32)
    idx.add_cluster(_mk_cluster(0, p, [0, 1, 2], [0, 0, 1]))
    idx.add_cluster(_mk_cluster(1, p[::-1].copy(), [3], [2]))
    path = str(tmp_path / "index")
    idx.save(path)
    idx2 = TopKIndex.load(path)
    assert idx2.K == 2 and idx2.n_clusters == 2
    assert idx2.lookup(3) == idx.lookup(3)
    assert idx2.lookup(999) == idx.lookup(999)
    np.testing.assert_array_equal(idx2.frames_of([0, 1]),
                                  idx.frames_of([0, 1]))
    np.testing.assert_allclose(idx2.clusters[0].mean_probs,
                               idx.clusters[0].mean_probs)
