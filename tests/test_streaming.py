"""Streaming ingest equivalence harness (the property that makes every
future ingest refactor safe).

Core property: for random streams and random chunk splits, a
``StreamingIngestor`` fed in chunks — with flushes (and their duplicate
attaches) interleaved — produces an index *byte-identical on disk* to
one-shot ``ingest()`` over the concatenated stream, including across
eviction boundaries. Plus: multi-stream runner equivalence, and
query-while-ingest returning exactly what a fresh engine sees.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import index_save_bytes as _save_bytes
from conftest import make_chunks as _chunks
from conftest import make_stream as _stream
from repro.core.engine import QueryEngine
from repro.core.index import TopKIndex
from repro.core.ingest import IngestConfig, ingest
from repro.core.streaming import MultiStreamRunner, StreamingIngestor

FEAT_DIM = 12
N_CLASSES = 5


def _cheap(batch):
    """Per-example-pure cheap-CNN stub: probs/feats are functions of the
    crop pixels alone, so stream-private and stacked device batches give
    identical per-object outputs."""
    flat = batch.reshape(len(batch), -1)
    feats = (flat[:, :FEAT_DIM] * 10.0).astype(np.float32)
    probs = np.abs(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES]) + 1e-3
    return (probs / probs.sum(1, keepdims=True)).astype(np.float32), feats


def _gt_apply(batch):
    return np.rint(batch[:, 0, 0, 2] * 8).astype(np.int64) % N_CLASSES


# ---------------------------------------------------------------------------
# the equivalence property
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.data())
def test_streaming_equals_oneshot_byte_identical(data):
    """Random stream, random chunk split, eviction-heavy config: the
    chunked run (with interleaved flushes) saves byte-identically to the
    one-shot run."""
    seed = data.draw(st.integers(0, 10_000), label="seed")
    n = data.draw(st.integers(0, 400), label="n")
    batch_size = data.draw(st.sampled_from([32, 64, 100]), label="batch")
    gate = data.draw(st.booleans(), label="gate")
    stride = data.draw(st.sampled_from([1, 1, 2, 3]), label="stride")
    crops, frames = _stream(seed, n)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=24,
                       batch_size=batch_size, high_water=0.8,
                       evict_frac=0.5, gate=gate, frame_stride=stride)

    one_index, one_stats = ingest(crops, frames, _cheap, 1e9, cfg)

    ing = StreamingIngestor(_cheap, 1e9, cfg)
    for size in _chunks(data.draw, n):
        taken, crops = crops[:size], crops[size:]
        tf, frames = frames[:size], frames[size:]
        ing.feed(taken, tf)
        ing.flush()                     # interleaved duplicate attaches
    chunk_index, chunk_stats = ing.finish()

    assert _save_bytes(chunk_index, "s") == _save_bytes(one_index, "o")
    assert chunk_stats.n_objects == one_stats.n_objects
    assert chunk_stats.n_cnn_invocations == one_stats.n_cnn_invocations
    assert chunk_stats.n_pixel_dedup == one_stats.n_pixel_dedup
    assert chunk_stats.n_evictions == one_stats.n_evictions


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_multi_stream_runner_matches_self_driven(seed):
    """Two streams through one stacked shared-CNN runner == each stream
    ingested on its own, byte for byte."""
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=32, batch_size=48,
                       high_water=0.85, evict_frac=0.4)
    streams = {name: _stream(seed + i, 300 + 40 * i)
               for i, name in enumerate(["cam_a", "cam_b"])}

    solo = {name: ingest(c, f, _cheap, 1e9, cfg)[0]
            for name, (c, f) in streams.items()}

    runner = MultiStreamRunner(
        {name: StreamingIngestor(None, 1e9, cfg) for name in streams},
        _cheap, batch_pad=32)
    # interleave feeds chunk by chunk (uneven chunk sizes per stream)
    cursors = {name: 0 for name in streams}
    sizes = {"cam_a": 77, "cam_b": 130}
    while any(cursors[nm] < len(streams[nm][0]) for nm in streams):
        feeds = {}
        for nm in streams:
            c, f = streams[nm]
            i = cursors[nm]
            if i < len(c):
                feeds[nm] = (c[i:i + sizes[nm]], f[i:i + sizes[nm]])
                cursors[nm] = i + sizes[nm]
        runner.feed(feeds)
        runner.flush()
    finished = runner.finish()

    for name in streams:
        idx, _ = finished[name]
        assert _save_bytes(idx, name) == _save_bytes(solo[name], name + "s")


# ---------------------------------------------------------------------------
# redundancy gate: gated == ungated on exact-duplicate streams
# ---------------------------------------------------------------------------

def _exact_stream(seed, n, n_modes=8, n_frames=None):
    """Stream where every duplicate is an EXACT copy of one of ``n_modes``
    base crops — threshold-safe for the gate, so gated ingest must lose
    nothing relative to ungated."""
    r = np.random.default_rng(seed)
    n_frames = n_frames or max(n // 5, 2)
    modes = r.random((n_modes, 6, 6, 3)).astype(np.float32)
    pick = r.integers(0, n_modes, n)
    crops = modes[pick].copy()
    frames = np.sort(r.integers(0, n_frames, n))
    return crops, frames


def _frames_by_class(index):
    return {c: sorted(np.asarray(index.frames_of(index.lookup(c))).tolist())
            for c in range(N_CLASSES)}


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_gated_equals_ungated_on_exact_duplicate_streams(data):
    """The gate's correctness contract: on a stream whose duplicates are
    exact, gated ingest answers every class query with the same frames as
    ungated ingest (attach-instead-of-fold loses nothing), while spending
    strictly fewer CNN invocations — and the gated run itself is
    chunk-invariant (byte-identical to one-shot gated)."""
    seed = data.draw(st.integers(0, 10_000), label="seed")
    n = data.draw(st.integers(1, 300), label="n")
    crops, frames = _exact_stream(seed, n)
    base = dict(K=2, threshold=1.5, max_clusters=64, batch_size=32)

    idx_un, st_un = ingest(crops, frames, _cheap, 1e9,
                           IngestConfig(**base, gate=False),
                           n_local_classes=N_CLASSES)
    gcfg = IngestConfig(**base, gate=True, gate_threshold=0.01)
    idx_g, st_g = ingest(crops, frames, _cheap, 1e9, gcfg,
                         n_local_classes=N_CLASSES)

    assert _frames_by_class(idx_g) == _frames_by_class(idx_un)
    assert idx_g.n_objects == idx_un.n_objects == n
    assert st_g.n_cnn_invocations <= st_un.n_cnn_invocations

    # chunk invariance of the gated run (ring admission is deferred to
    # frame close, so chunk boundaries can't change what the gate sees)
    ing = StreamingIngestor(_cheap, 1e9, gcfg, n_local_classes=N_CLASSES)
    rest_c, rest_f = crops, frames
    for size in _chunks(data.draw, n):
        ing.feed(rest_c[:size], rest_f[:size])
        rest_c, rest_f = rest_c[size:], rest_f[size:]
        ing.flush()
    chunk_idx, chunk_stats = ing.finish()
    assert _save_bytes(chunk_idx, "g") == _save_bytes(idx_g, "go")
    assert chunk_stats.n_gate_skipped == st_g.n_gate_skipped


def test_gate_chunk_invariance_across_shard_rollovers():
    """Every shard sealed by a gated rolling ingestor is byte-identical to
    a one-shot gated ingest of exactly its window — the gate ring must be
    reset at each seal, never leak across shards."""
    import os
    import tempfile

    from repro.core.archive import ShardCatalog

    crops, frames = _exact_stream(7, 260)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=64, batch_size=32,
                       gate=True, gate_threshold=0.01)
    with tempfile.TemporaryDirectory() as d:
        catalog = ShardCatalog.open(os.path.join(d, "arch"))
        ing = StreamingIngestor(_cheap, 1e9, cfg, catalog=catalog,
                                shard_objects=90)
        for start in range(0, len(crops), 70):
            ing.feed(crops[start:start + 70], frames[start:start + 70])
            ing.flush()
        ing.finish()

        from repro.core.index import saved_file_bytes as _file_bytes

        bases = [m.obj_base for m in catalog] + [len(crops)]
        assert len(catalog) == -(-len(crops) // 90)
        for i, m in enumerate(catalog):
            lo, hi = bases[i], bases[i + 1]
            one, _ = ingest(crops[lo:hi], frames[lo:hi], _cheap, 1e9, cfg)
            p = os.path.join(d, "one")
            one.save(p)
            assert _file_bytes(os.path.join(catalog.root, m.path)) \
                == _file_bytes(p), f"gated shard {m.shard_id} != window"


def test_gate_attaches_duplicate_chains_to_root_cluster():
    """Regression for gate/tracker transitivity: a gate hit must rewrite
    the tracker's view of the frame (``amend_last``) so that a
    *consecutive-frame* duplicate of a gate-matched crop still resolves to
    the original root — otherwise its frame is attached to a root that
    never reached a cluster and the object is silently lost."""
    r = np.random.default_rng(0)
    a = r.random((6, 6, 3)).astype(np.float32)
    crops = np.stack([a, a, a])            # frames 0, 2, 3: blink then chain
    frames = np.array([0, 2, 3], np.int64)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=16, batch_size=8,
                       gate=True, gate_threshold=0.01)
    index, stats = ingest(crops, frames, _cheap, 1e9, cfg)
    assert stats.n_cnn_invocations == 1    # tracker misses 0->2, gate hits
    assert stats.n_gate_skipped >= 1
    assert index.n_objects == 3
    assert index.n_clusters == 1
    cid = int(index.store.row_cids[0])
    assert sorted(np.asarray(index.frames_of([cid])).tolist()) == [0, 2, 3]


def test_frame_stride_equals_prefiltered_stream():
    """``frame_stride=s`` must be exactly equivalent to pre-filtering the
    stream to frames divisible by s (absolute grid, chunk-invariant) —
    byte-identical indexes, with the dropped arrivals counted."""
    crops, frames = _exact_stream(3, 200, n_frames=60)
    base = dict(K=2, threshold=1.5, max_clusters=64, batch_size=32)
    strided, st_s = ingest(crops, frames, _cheap, 1e9,
                           IngestConfig(**base, frame_stride=3))
    keep = frames % 3 == 0
    pre, _ = ingest(crops[keep], frames[keep], _cheap, 1e9,
                    IngestConfig(**base))
    assert _save_bytes(strided, "s3") == _save_bytes(pre, "pre")
    assert st_s.n_sampled_out == int((~keep).sum())
    assert st_s.n_objects == int(keep.sum())


def test_stride_validation_and_mid_run_change():
    with pytest.raises(ValueError):
        StreamingIngestor(_cheap, 1e9, IngestConfig(frame_stride=0))
    ing = StreamingIngestor(_cheap, 1e9, IngestConfig(batch_size=8))
    with pytest.raises(ValueError):
        ing.set_frame_stride(0)
    assert ing.frame_stride == 1
    ing.set_frame_stride(4)
    assert ing.frame_stride == 4
    crops, frames = _exact_stream(5, 40, n_frames=20)
    ing.feed(crops, frames)
    index, stats = ing.finish()
    keep = int((frames % 4 == 0).sum())
    assert stats.n_sampled_out == len(crops) - keep
    assert index.n_objects == keep


# ---------------------------------------------------------------------------
# query-while-ingest
# ---------------------------------------------------------------------------

def test_query_while_ingest_matches_fresh_engine():
    """Between chunks, a long-lived warm engine must answer exactly like a
    cold engine built on the same index snapshot (precise version-keyed
    invalidation), and the final interleaved round equals post-hoc."""
    crops, frames = _stream(3, 600)
    cfg = IngestConfig(K=3, threshold=1.5, max_clusters=48, batch_size=64,
                       high_water=0.85, evict_frac=0.4)
    ing = StreamingIngestor(_cheap, 1e9, cfg, n_local_classes=N_CLASSES)
    warm = QueryEngine(ing.index, gt_apply=_gt_apply,
                       gt_flops_per_image=1e9)
    workload = list(range(N_CLASSES))
    last = None
    for start in range(0, len(crops), 150):
        ing.feed(crops[start:start + 150], frames[start:start + 150])
        delta = ing.flush()
        warm.prefetch(delta.touched_cids)
        results, batch = warm.query_many(workload)
        assert batch.n_gt_invocations == 0      # prefetch took the GT cost
        fresh = QueryEngine(ing.index, gt_apply=_gt_apply,
                            gt_flops_per_image=1e9)
        fresh_results, _ = fresh.query_many(workload)
        for a, b in zip(results, fresh_results):
            np.testing.assert_array_equal(a.frames, b.frames)
            assert a.matched_clusters == b.matched_clusters
        last = results
    index, _ = ing.finish()
    warm.prefetch(ing.flush().touched_cids)
    final, _ = warm.query_many(workload)
    posthoc = QueryEngine(index, gt_apply=_gt_apply, gt_flops_per_image=1e9)
    posthoc_results, _ = posthoc.query_many(workload)
    for a, b in zip(final, posthoc_results):
        np.testing.assert_array_equal(a.frames, b.frames)
    assert last is not None


def test_flush_delta_names_new_and_touched_clusters():
    crops, frames = _stream(11, 200)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=64, batch_size=50,
                       pixel_diff=False)
    ing = StreamingIngestor(_cheap, 1e9, cfg)
    ing.feed(crops, frames)
    delta = ing.flush()
    assert delta.n_objects_published == 200 - delta.n_pending_unique
    assert set(delta.new_cids) <= set(delta.touched_cids)
    versions = {int(c): int(ing.index.store.versions[ing.index.store.row_of(c)])
                for c in delta.touched_cids}
    assert all(v >= 1 for v in versions.values())
    # a flush with nothing new publishes nothing
    empty = ing.flush()
    assert empty.n_objects_published == 0 and empty.touched_cids == []
    # the tail only folds at finish
    index, stats = ing.finish()
    assert index.n_objects == 200
    assert stats.n_cnn_invocations == 200


# ---------------------------------------------------------------------------
# lifecycle / contract errors
# ---------------------------------------------------------------------------

def test_flush_prunes_root_cid_map_to_active_window():
    """The root -> cid map must stay O(active frame window) over a long
    stream, not O(total unique objects) — and pruning must not change the
    result (covered by the byte-identity property, which flushes)."""
    crops, frames = _stream(5, 800, n_frames=400)
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=64, batch_size=32)
    ing = StreamingIngestor(_cheap, 1e9, cfg)
    sizes = []
    for start in range(0, len(crops), 100):
        ing.feed(crops[start:start + 100], frames[start:start + 100])
        ing.flush()
        sizes.append(len(ing._root_cid))
    n_unique = ing.stats.n_objects - ing.stats.n_pixel_dedup \
        - ing.n_pending_unique
    assert max(sizes) < 0.5 * n_unique      # pruned, not accumulated
    index, _ = ing.finish()
    assert index.n_objects == 800           # nothing lost to pruning


def test_feed_rejects_decreasing_frames_across_chunks():
    cfg = IngestConfig(batch_size=32)
    ing = StreamingIngestor(_cheap, 1e9, cfg)
    crops = np.random.default_rng(0).random((4, 6, 6, 3)).astype(np.float32)
    ing.feed(crops, np.array([5, 5, 6, 7]))
    with pytest.raises(ValueError):
        ing.feed(crops, np.array([3, 3, 4, 4]))


def test_rejected_feed_leaves_state_unchanged():
    """Regression: ``feed`` used to bump ``_n_seen`` / ``stats.n_objects``
    *before* the non-decreasing-frame check raised, so a rejected chunk
    permanently corrupted stats and shifted every later default object id
    (silently changing clustering results). Validation must precede any
    mutation."""
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=24, batch_size=32)
    crops, frames = _stream(1, 120)
    ing = StreamingIngestor(_cheap, 1e9, cfg)
    half = len(crops) // 2
    ing.feed(crops[:half], frames[:half])
    snap = (ing.stats.n_objects, ing.stats.n_pixel_dedup, ing._n_seen,
            ing._obj_next, ing.n_pending_unique, ing.n_pending_dups,
            ing._max_frame)
    bad = np.random.default_rng(9).random((4, 6, 6, 3)).astype(np.float32)
    with pytest.raises(ValueError):
        ing.feed(bad, np.zeros(4, np.int64))       # out of order: rejected
    assert (ing.stats.n_objects, ing.stats.n_pixel_dedup, ing._n_seen,
            ing._obj_next, ing.n_pending_unique, ing.n_pending_dups,
            ing._max_frame) == snap
    # object-id assignment is unaffected: finishing equals a run that
    # never saw the rejected chunk, byte for byte
    ing.feed(crops[half:], frames[half:])
    chunk_index, _ = ing.finish()
    one_index, _ = ingest(crops, frames, _cheap, 1e9, cfg)
    assert _save_bytes(chunk_index, "r") == _save_bytes(one_index, "o")


def test_feed_rejects_decreasing_frames_without_pixel_diff():
    """The contract is enforced even when pixel differencing is off — an
    out-of-order chunk would silently move the CNN batch partition away
    from the one-shot run's."""
    cfg = IngestConfig(batch_size=32, pixel_diff=False)
    ing = StreamingIngestor(_cheap, 1e9, cfg)
    crops = np.random.default_rng(0).random((4, 6, 6, 3)).astype(np.float32)
    ing.feed(crops, np.array([5, 5, 6, 7]))
    with pytest.raises(ValueError):
        ing.feed(crops, np.array([3, 3, 4, 4]))


def test_default_obj_ids_are_arrival_positions_in_unsorted_chunk():
    """Default object ids are arrival positions in the fed chunk, not
    positions after the internal frame-sort — oracle labels are aligned
    to arrival order."""
    cfg = IngestConfig(K=2, threshold=1.5, max_clusters=16, batch_size=4,
                       pixel_diff=False)
    ing = StreamingIngestor(_cheap, 1e9, cfg)
    crops = np.random.default_rng(0).random((6, 6, 6, 3)).astype(np.float32)
    ing.feed(crops, np.array([2, 0, 1, 2, 0, 1]))
    index, _ = ing.finish()
    s = index.store
    pairs = set(zip(s._m_objs[:s.m_n].tolist(),
                    s._m_frames[:s.m_n].tolist()))
    assert pairs == {(1, 0), (4, 0), (2, 1), (5, 1), (0, 2), (3, 2)}


def test_take_on_empty_buffer_returns_empty_arrays():
    """Regression: ``take_tail``/``take_ready_batch`` on an ingestor whose
    unique buffer is still empty crashed with ``None[:0]`` (TypeError) —
    e.g. an external driver finishing a stream whose chunks were all
    duplicates, before any unique object was buffered."""
    ing = StreamingIngestor(None, 1e9, IngestConfig(batch_size=8))
    for crops, objs, frames in (ing.take_tail(), ing.take_ready_batch()):
        assert len(crops) == len(objs) == len(frames) == 0
        assert objs.dtype == np.int64 and frames.dtype == np.int64
    index, stats = ing.finish()
    assert index.n_clusters == 0 and stats.n_objects == 0


def test_take_tail_after_full_drain_keeps_crop_shape():
    """After the buffer drains to empty, a further take returns empties
    with the stream's crop shape (so a shape-polymorphic driver can still
    batch them)."""
    cfg = IngestConfig(batch_size=4, pixel_diff=False)
    ing = StreamingIngestor(None, 1e9, cfg)
    crops = np.random.default_rng(0).random((8, 6, 6, 3)).astype(np.float32)
    ing.feed(crops, np.zeros(8, np.int64))
    ing.take_ready_batch()
    ing.take_ready_batch()
    tail_crops, tail_objs, _ = ing.take_tail()
    assert tail_crops.shape == (0, 6, 6, 3)
    assert len(tail_objs) == 0


def test_feed_after_finish_raises():
    ing = StreamingIngestor(_cheap, 1e9, IngestConfig(batch_size=8))
    crops, frames = _stream(1, 20)
    ing.feed(crops, frames)
    ing.finish()
    with pytest.raises(RuntimeError):
        ing.feed(crops, frames)


def test_runner_rejects_self_driven_ingestors():
    with pytest.raises(ValueError):
        MultiStreamRunner({"a": StreamingIngestor(_cheap, 1e9,
                                                  IngestConfig())}, _cheap)
    with pytest.raises(ValueError):
        MultiStreamRunner({}, _cheap)


def test_runner_driven_finish_requires_runner():
    ing = StreamingIngestor(None, 1e9, IngestConfig(batch_size=64))
    crops, frames = _stream(2, 30)
    ing.feed(crops, frames)              # buffered: no CNN to drain with
    with pytest.raises(RuntimeError):
        ing.finish()


def test_empty_feeds_and_empty_finish():
    ing = StreamingIngestor(_cheap, 1e9, IngestConfig(batch_size=8),
                            n_local_classes=N_CLASSES)
    ing.feed(np.zeros((0, 6, 6, 3), np.float32), np.zeros((0,), np.int64))
    index, stats = ing.finish()
    assert index.n_clusters == 0 and stats.n_objects == 0
    assert index.n_local_classes == N_CLASSES
