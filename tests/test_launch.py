"""Launch-layer coverage: mesh construction, step builders, and a reduced
dry-run (lower+compile) in a subprocess with 8 virtual devices — the same
path the production dry-run takes, scaled down so it runs in seconds."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_make_production_mesh_is_a_function_not_module_state():
    import repro.launch.mesh as m
    # importing must not have created any mesh / touched device count
    assert callable(m.make_production_mesh)
    src = open(m.__file__).read()
    assert "os.environ[" not in src     # never mutates device state on import


def test_elastic_choose_mesh_single_device():
    from repro.train.elastic import choose_mesh
    mesh = choose_mesh(jax.devices(), model_parallelism=1, pods=1)
    assert mesh.shape["model"] == 1
    assert mesh.shape["data"] >= 1


def test_reshard_roundtrip_same_mesh():
    from repro.train.elastic import reshard, choose_mesh
    mesh = choose_mesh(jax.devices())
    tree = {"layers": {"attn": {"wq": np.ones((2, 8, 8), np.float32)}},
            "tok_embed": np.ones((16, 8), np.float32)}
    import jax.numpy as jnp
    tree = jax.tree.map(jnp.asarray, tree)
    out = reshard(tree, mesh)
    np.testing.assert_array_equal(np.asarray(out["tok_embed"]),
                                  np.asarray(tree["tok_embed"]))


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax
    from repro.common.config import LM_SHAPES, reduced
    from repro.configs import get_arch
    import repro.launch.steps as st
    from repro.launch.dryrun import collective_stats

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")),
                              d_model=128, n_heads=4, n_kv_heads=2)
    cell = dataclasses.replace(LM_SHAPES["train_4k"], seq_len=128,
                               global_batch=8)
    spec = st.build_lm(cfg, cell, mesh)
    with mesh:
        lowered = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                          out_shardings=spec.out_shardings,
                          donate_argnums=spec.donate_argnums
                          ).lower(*spec.args)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax returns [dict]
        ca = ca[0]
    coll = collective_stats(compiled.as_text())
    print(json.dumps({
        "flops": float(ca.get("flops", 0)),
        "arg_bytes": int(ma.argument_size_in_bytes),
        "n_collectives": sum(coll["counts"].values()),
    }))
""")


@pytest.mark.slow
def test_dryrun_lower_compile_8dev_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["arg_bytes"] > 0
    assert rec["n_collectives"] > 0      # sharded program communicates


def test_collective_stats_parser():
    from repro.launch.dryrun import collective_stats
    hlo = textwrap.dedent("""
      %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
      %ar = f32[64]{0} all-reduce(%y), replica_groups={{0,1,2,3}}
      %rs = f32[4]{0} reduce-scatter(%z), replica_groups=[2,8]<=[16]
      %done = f32[8]{0} all-gather-done(%t)
    """)
    st = collective_stats(hlo)
    assert st["counts"]["all-gather"] == 1
    assert st["counts"]["all-reduce"] == 1
    assert st["counts"]["reduce-scatter"] == 1
    ag = 16 * 128 * 2 * 15 / 16
    assert abs(st["wire_bytes"]["all-gather"] - ag) < 1
    ar = 64 * 4 * 2 * 3 / 4
    assert abs(st["wire_bytes"]["all-reduce"] - ar) < 1
    rs = 4 * 4 * 7
    assert abs(st["wire_bytes"]["reduce-scatter"] - rs) < 1


def test_input_specs_are_abstract():
    """StepSpec args must be ShapeDtypeStruct — no device allocation."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    import repro.launch.steps as st
    spec = st.build("vit-s16", "serve_b1", mesh)
    for leaf in jax.tree.leaves(spec.args):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
