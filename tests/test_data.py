"""Synthetic video substrate: stream statistics match paper §2.2; background
subtraction finds the planted objects; pixel differencing matches dups."""
import numpy as np
import pytest

from repro.data import (BackgroundSubtractor, StreamConfig, VideoStream,
                        extract_crops, get_stream, pixel_difference)
from repro.data.video import STREAM_ZOO, _class_proto


def test_stream_zoo_has_13_streams():
    assert len(STREAM_ZOO) == 13
    assert len({s.name for s in STREAM_ZOO}) == 13


def test_limited_class_set_per_stream():
    """§2.2.2: each stream uses a small, stream-specific subset of classes."""
    vs = get_stream("lausanne", duration_s=60)
    _, _, _, labels = vs.objects_array()
    assert 0 < len(np.unique(labels)) <= vs.cfg.n_stream_classes
    # two streams overlap little (Jaccard ~0.46 in the paper)
    vs2 = get_stream("jacksonh", duration_s=60)
    a = set(vs.stream_classes.tolist())
    b = set(vs2.stream_classes.tolist())
    assert len(a & b) / len(a | b) < 0.6


def test_class_frequency_skew():
    """§2.2.2: a few classes dominate (power law)."""
    vs = get_stream("auburn_c", duration_s=240)
    _, _, _, labels = vs.objects_array()
    _, counts = np.unique(labels, return_counts=True)
    counts = np.sort(counts)[::-1]
    top3 = counts[:3].sum() / counts.sum()
    assert top3 > 0.5


def test_objects_persist_across_frames():
    """§2.2.3: the same track appears in many consecutive frames."""
    vs = get_stream("cnn", duration_s=30)
    _, frames, tracks, _ = vs.objects_array()
    if len(tracks):
        _, counts = np.unique(tracks, return_counts=True)
        assert counts.mean() > 5


def test_track_crops_nearly_identical():
    vs = get_stream("bend", duration_s=60)
    crops, frames, tracks, _ = vs.objects_array()
    tids, counts = np.unique(tracks, return_counts=True)
    tid = tids[np.argmax(counts)]
    sel = crops[tracks == tid]
    d = np.abs(sel[0] - sel[-1]).mean()
    assert d < 0.15          # slow drift, §2.2.3


def test_class_protos_distinct():
    a, b = _class_proto(3, 32), _class_proto(4, 32)
    assert np.abs(a - b).mean() > 0.05


def test_bgsub_detects_planted_objects():
    vs = get_stream("lausanne", duration_s=20, fps=5)
    bg = BackgroundSubtractor(threshold=0.05)
    n_boxes = 0
    for frame in vs.frames(max_frames=60):
        boxes = bg(frame)
        n_boxes += len(boxes)
        crops = extract_crops(frame, boxes, vs.cfg.obj_res)
        assert crops.shape[1:] == (32, 32, 3)
    assert n_boxes > 0


def test_bgsub_static_scene_is_silent():
    bg = BackgroundSubtractor()
    frame = np.full((64, 64, 3), 0.4, np.float32)
    assert bg(frame) == []
    for _ in range(5):
        assert bg(frame + 1e-4) == []


def test_pixel_difference_matches_duplicates():
    r = np.random.default_rng(0)
    a = r.random((3, 8, 8, 3)).astype(np.float32)
    b = np.stack([a[2] + 1e-3, r.random((8, 8, 3)).astype(np.float32)])
    m = pixel_difference(a, b, threshold=0.02)
    assert m[2] == 0                    # a[2] ~ b[0]
    assert m[0] == -1 and m[1] == -1    # no match


def test_object_stream_respects_frame_stride():
    vs = get_stream("sittard", duration_s=30)
    n1 = len(vs.objects_array(frame_stride=1)[0])
    n5 = len(vs.objects_array(frame_stride=5)[0])
    assert n5 < n1


def test_object_chunks_concatenate_to_objects_array():
    """The streaming feed unit: chunk concatenation equals the one-shot
    materialization exactly, with non-decreasing frames across chunks."""
    vs = get_stream("oxford", duration_s=30)
    want = vs.objects_array()
    chunks = list(vs.object_chunks(chunk_frames=45))
    assert len(chunks) > 1
    last_frame = -1
    for crops, frames, tracks, labels in chunks:
        if len(frames):
            assert frames.min() >= last_frame
            last_frame = frames.max()
    got = [np.concatenate([c[i] for c in chunks]) for i in range(4)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_object_chunks_rejects_bad_window():
    vs = get_stream("oxford", duration_s=10)
    with pytest.raises(ValueError):
        next(vs.object_chunks(chunk_frames=0))


# ---------------------------------------------------------------------------
# blocked pixel_difference + hardened BackgroundSubtractor (PR 6)
# ---------------------------------------------------------------------------

def _dense_pixel_difference(crops_a, crops_b, threshold):
    """The original all-pairs broadcast, kept as the blocked path's oracle."""
    a = crops_a.reshape(len(crops_a), -1)
    b = crops_b.reshape(len(crops_b), -1)
    d = np.abs(a[:, None, :] - b[None, :, :]).mean(-1)
    j = d.argmin(1)
    return np.where(d[np.arange(len(a)), j] < threshold, j, -1)


def test_pixel_difference_blocked_equals_dense(monkeypatch):
    """Force multiple row blocks; the blocked result must equal the old
    dense broadcast exactly (argmin ties included)."""
    from repro.data import bgsub
    monkeypatch.setattr(bgsub, "_BLOCK_ELEMS", 7 * 48)   # ~1 row per block
    rng = np.random.default_rng(0)
    a = rng.random((23, 4, 4, 3)).astype(np.float32)
    b = rng.random((7, 4, 4, 3)).astype(np.float32)
    b[2] = a[5]
    b[3] = b[2]                 # duplicate ref: tie must break low
    got = bgsub.pixel_difference(a, b, 0.1, backend="numpy")
    np.testing.assert_array_equal(got, _dense_pixel_difference(a, b, 0.1))
    assert got[5] == 2


def test_pixel_difference_threshold_strict():
    a = np.zeros((1, 2, 2, 3), np.float32)
    b = np.full((1, 2, 2, 3), 0.5, np.float32)
    assert pixel_difference(a, b, 0.5)[0] == -1          # d == thr: no match
    assert pixel_difference(a, b, 0.500001)[0] == 0


def test_pixel_difference_kernel_backend_matches_numpy():
    rng = np.random.default_rng(1)
    a = rng.random((31, 8, 8, 3)).astype(np.float32)
    b = rng.random((17, 8, 8, 3)).astype(np.float32)
    b[4] = a[9] + 1e-4
    mk = pixel_difference(a, b, 0.02, backend="kernel")
    mn = pixel_difference(a, b, 0.02, backend="numpy")
    np.testing.assert_array_equal(mk, mn)
    assert mk[9] == 4


def test_pixel_difference_rejects_unknown_backend():
    a = np.zeros((1, 2, 2, 3), np.float32)
    with pytest.raises(ValueError):
        pixel_difference(a, a, 0.1, backend="gpu")


def test_bgsub_frame_smaller_than_one_tile():
    """ty == 0 / tx == 0 must yield [] (not crash or mislabel), while the
    background model still tracks the stream."""
    bs = BackgroundSubtractor(tile=8)
    r = np.random.default_rng(0)
    f0 = r.random((4, 40, 3)).astype(np.float32)          # ty == 0
    assert bs(f0) == []
    assert bs(np.ones_like(f0)) == []
    assert bs._bg is not None and bs._bg.shape == f0.shape
    bs2 = BackgroundSubtractor(tile=8)
    g0 = r.random((40, 5, 3)).astype(np.float32)          # tx == 0
    assert bs2(g0) == []
    assert bs2(np.ones_like(g0)) == []


def test_bgsub_non_multiple_resolution_labels_complete_tiles():
    """Boxes never extend past the last complete tile on a 70x51 frame."""
    bs = BackgroundSubtractor(tile=8, min_tiles=1, threshold=0.05)
    base = np.zeros((70, 51, 3), np.float32)
    bs(base)
    hot = base.copy()
    hot[8:32, 8:32] = 1.0
    boxes = bs(hot)
    assert boxes
    for b in boxes:
        assert b.y1 <= (70 // 8) * 8 and b.x1 <= (51 // 8) * 8


def test_bgsub_constant_stream_stays_silent():
    bs = BackgroundSubtractor(tile=8, min_tiles=1)
    f = np.full((64, 64, 3), 0.3, np.float32)
    assert all(bs(f.copy()) == [] for _ in range(5))


def test_bgsub_components_vectorized_equals_bfs():
    """The iterative min-label propagation returns the same boxes in the
    same order as the reference BFS, over random hot grids."""
    bs = BackgroundSubtractor(tile=8)
    rng = np.random.default_rng(0)
    for density in (0.1, 0.3, 0.5, 0.8):
        for _ in range(10):
            hot = rng.random((9, 13)) < density
            assert bs._components(hot) == bs._components_bfs(hot)
    # degenerate grids
    assert bs._components(np.zeros((5, 5), bool)) == []
    assert bs._components(np.ones((1, 1), bool)) == \
        bs._components_bfs(np.ones((1, 1), bool))


def test_bgsub_kernel_backend_matches_numpy():
    """Same stream through both backends -> identical boxes every frame."""
    rng = np.random.default_rng(3)
    frames = [rng.random((48, 56, 3)).astype(np.float32) for _ in range(4)]
    frames.append(frames[-1].copy())
    frames[2][8:24, 16:40] += 0.5
    bn = BackgroundSubtractor(tile=8, min_tiles=1, backend="numpy")
    bk = BackgroundSubtractor(tile=8, min_tiles=1, backend="kernel")
    for f in frames:
        assert bn(f.copy()) == bk(f.copy())
    np.testing.assert_allclose(bn._bg, np.asarray(bk._bg), atol=1e-5)
