"""Synthetic video substrate: stream statistics match paper §2.2; background
subtraction finds the planted objects; pixel differencing matches dups."""
import numpy as np
import pytest

from repro.data import (BackgroundSubtractor, StreamConfig, VideoStream,
                        extract_crops, get_stream, pixel_difference)
from repro.data.video import STREAM_ZOO, _class_proto


def test_stream_zoo_has_13_streams():
    assert len(STREAM_ZOO) == 13
    assert len({s.name for s in STREAM_ZOO}) == 13


def test_limited_class_set_per_stream():
    """§2.2.2: each stream uses a small, stream-specific subset of classes."""
    vs = get_stream("lausanne", duration_s=60)
    _, _, _, labels = vs.objects_array()
    assert 0 < len(np.unique(labels)) <= vs.cfg.n_stream_classes
    # two streams overlap little (Jaccard ~0.46 in the paper)
    vs2 = get_stream("jacksonh", duration_s=60)
    a = set(vs.stream_classes.tolist())
    b = set(vs2.stream_classes.tolist())
    assert len(a & b) / len(a | b) < 0.6


def test_class_frequency_skew():
    """§2.2.2: a few classes dominate (power law)."""
    vs = get_stream("auburn_c", duration_s=240)
    _, _, _, labels = vs.objects_array()
    _, counts = np.unique(labels, return_counts=True)
    counts = np.sort(counts)[::-1]
    top3 = counts[:3].sum() / counts.sum()
    assert top3 > 0.5


def test_objects_persist_across_frames():
    """§2.2.3: the same track appears in many consecutive frames."""
    vs = get_stream("cnn", duration_s=30)
    _, frames, tracks, _ = vs.objects_array()
    if len(tracks):
        _, counts = np.unique(tracks, return_counts=True)
        assert counts.mean() > 5


def test_track_crops_nearly_identical():
    vs = get_stream("bend", duration_s=60)
    crops, frames, tracks, _ = vs.objects_array()
    tids, counts = np.unique(tracks, return_counts=True)
    tid = tids[np.argmax(counts)]
    sel = crops[tracks == tid]
    d = np.abs(sel[0] - sel[-1]).mean()
    assert d < 0.15          # slow drift, §2.2.3


def test_class_protos_distinct():
    a, b = _class_proto(3, 32), _class_proto(4, 32)
    assert np.abs(a - b).mean() > 0.05


def test_bgsub_detects_planted_objects():
    vs = get_stream("lausanne", duration_s=20, fps=5)
    bg = BackgroundSubtractor(threshold=0.05)
    n_boxes = 0
    for frame in vs.frames(max_frames=60):
        boxes = bg(frame)
        n_boxes += len(boxes)
        crops = extract_crops(frame, boxes, vs.cfg.obj_res)
        assert crops.shape[1:] == (32, 32, 3)
    assert n_boxes > 0


def test_bgsub_static_scene_is_silent():
    bg = BackgroundSubtractor()
    frame = np.full((64, 64, 3), 0.4, np.float32)
    assert bg(frame) == []
    for _ in range(5):
        assert bg(frame + 1e-4) == []


def test_pixel_difference_matches_duplicates():
    r = np.random.default_rng(0)
    a = r.random((3, 8, 8, 3)).astype(np.float32)
    b = np.stack([a[2] + 1e-3, r.random((8, 8, 3)).astype(np.float32)])
    m = pixel_difference(a, b, threshold=0.02)
    assert m[2] == 0                    # a[2] ~ b[0]
    assert m[0] == -1 and m[1] == -1    # no match


def test_object_stream_respects_frame_stride():
    vs = get_stream("sittard", duration_s=30)
    n1 = len(vs.objects_array(frame_stride=1)[0])
    n5 = len(vs.objects_array(frame_stride=5)[0])
    assert n5 < n1


def test_object_chunks_concatenate_to_objects_array():
    """The streaming feed unit: chunk concatenation equals the one-shot
    materialization exactly, with non-decreasing frames across chunks."""
    vs = get_stream("oxford", duration_s=30)
    want = vs.objects_array()
    chunks = list(vs.object_chunks(chunk_frames=45))
    assert len(chunks) > 1
    last_frame = -1
    for crops, frames, tracks, labels in chunks:
        if len(frames):
            assert frames.min() >= last_frame
            last_frame = frames.max()
    got = [np.concatenate([c[i] for c in chunks]) for i in range(4)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_object_chunks_rejects_bad_window():
    vs = get_stream("oxford", duration_s=10)
    with pytest.raises(ValueError):
        next(vs.object_chunks(chunk_frames=0))
