"""Batched query serving with worker parallelism (§5 Implementation).

Ingests two streams, then serves a mixed query workload across them with a
thread pool of query workers (the paper parallelizes a query's GT-CNN work
across workers when resources are idle). Also demonstrates the §5
"dynamically adjusting K at query-time" enhancement.

  PYTHONPATH=src:. python examples/serve_queries.py
"""
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.common.config import CheapCNNConfig
from repro.core import IngestConfig, ingest, query
from repro.core.query import (dominant_classes, gt_frames_by_class,
                              precision_recall)
from repro.core.specialize import specialize
from repro.data import get_stream

GT_FLOPS = 1.2e11


def build_stream(name):
    vs = get_stream(name, duration_s=45, fps=10)
    crops, frames, _, labels = vs.objects_array()
    base = CheapCNNConfig(f"cheap-{name}", input_res=32, n_blocks=3,
                          width=24, feature_dim=128)
    sm = specialize(crops, labels, Ls=5, base_cfg=base, steps=120)
    index, _ = ingest(crops, frames, sm.make_apply(), GT_FLOPS / 50,
                      IngestConfig(K=4, threshold=0.8, max_clusters=512),
                      class_map=sm.class_map)
    from benchmarks.common import gt_oracle
    return dict(index=index, labels=labels, frames=frames,
                gt=gt_oracle(labels))


def main():
    streams = {n: build_stream(n) for n in ("lausanne", "auburn_r")}
    # query workload: every dominant class of every stream
    workload = [(n, int(c)) for n, s in streams.items()
                for c in dominant_classes(s["labels"])[:4]]
    print(f"serving {len(workload)} queries over {len(streams)} streams")

    def serve_one(job):
        name, cls = job
        s = streams[name]
        t0 = time.perf_counter()
        res = query(s["index"], cls, s["gt"], GT_FLOPS)
        gtf = gt_frames_by_class(s["labels"], s["frames"])
        p, r = precision_recall(res.frames, gtf.get(cls, np.array([])))
        return (name, cls, len(res.frames), res.n_gt_invocations,
                (time.perf_counter() - t0) * 1e3, p, r)

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=4) as pool:
        results = list(pool.map(serve_one, workload))
    wall = time.perf_counter() - t0

    lat = [r[4] for r in results]
    for name, cls, nf, ngt, ms, p, r in results:
        print(f"  {name:10s} class={cls:4d}: {nf:5d} frames, {ngt:3d} "
              f"GT calls, {ms:6.1f} ms  P={p:.2f} R={r:.2f}")
    print(f"total wall {wall:.2f}s | p50={np.percentile(lat, 50):.0f}ms "
          f"p95={np.percentile(lat, 95):.0f}ms")

    # dynamic K_x: fewer candidate clusters at lower Kx (lower latency)
    s = streams["lausanne"]
    cls = int(dominant_classes(s["labels"])[0])
    for kx in (4, 2, 1):
        res = query(s["index"], cls, s["gt"], GT_FLOPS, Kx=kx)
        print(f"  Kx={kx}: candidates={res.n_candidate_clusters} "
              f"frames={len(res.frames)}")


if __name__ == "__main__":
    main()
