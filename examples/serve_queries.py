"""Batched multi-query serving through the QueryEngine (§5 Implementation).

Ingests two streams, then serves a mixed concurrent query workload: each
stream's queries share one GT-CNN pass over the union of their candidate
clusters, and a second (warm) round is answered almost entirely from the
persistent GT-label cache. Also demonstrates the §5 "dynamically adjusting
K at query-time" enhancement — lower Kx reuses the same cache.

  PYTHONPATH=src:. python examples/serve_queries.py
"""
import numpy as np

from repro.common.config import CheapCNNConfig
from repro.core import IngestConfig, QueryEngine, ingest
from repro.core.query import (dominant_classes, gt_frames_by_class,
                              precision_recall)
from repro.core.specialize import specialize
from repro.data import get_stream

GT_FLOPS = 1.2e11


def build_stream(name):
    vs = get_stream(name, duration_s=45, fps=10)
    crops, frames, _, labels = vs.objects_array()
    base = CheapCNNConfig(f"cheap-{name}", input_res=32, n_blocks=3,
                          width=24, feature_dim=128)
    sm = specialize(crops, labels, Ls=5, base_cfg=base, steps=120)
    index, _ = ingest(crops, frames, sm.make_apply(), GT_FLOPS / 50,
                      IngestConfig(K=4, threshold=0.8, max_clusters=512),
                      class_map=sm.class_map)
    from benchmarks.common import gt_oracle
    return dict(engine=QueryEngine(index, gt_apply=gt_oracle(labels),
                                   gt_flops_per_image=GT_FLOPS),
                labels=labels,
                gtf=gt_frames_by_class(labels, frames))


def main():
    streams = {n: build_stream(n) for n in ("lausanne", "auburn_r")}
    workload = {n: [int(c) for c in dominant_classes(s["labels"])[:4]]
                for n, s in streams.items()}
    n_queries = sum(len(w) for w in workload.values())
    print(f"serving {n_queries} queries over {len(streams)} streams")

    for rnd, tag in enumerate(("cold", "warm")):
        for name, s in streams.items():
            results, batch = s["engine"].query_many(workload[name])
            print(f"[{tag}] {name}: {batch.n_queries} queries in "
                  f"{batch.wall_s*1e3:.1f}ms | {batch.n_candidates} "
                  f"candidates -> {batch.n_unique_candidates} unique, "
                  f"{batch.n_cache_hits} cached, "
                  f"{batch.n_gt_invocations} GT calls")
            if rnd == 0:
                for cls, res in zip(workload[name], results):
                    p, r = precision_recall(res.frames,
                                            s["gtf"].get(cls, np.array([])))
                    print(f"    class={cls:4d}: {len(res.frames):5d} frames"
                          f"  P={p:.2f} R={r:.2f}")

    # dynamic K_x: fewer candidate clusters at lower Kx (lower latency);
    # verdicts come straight from the warm cache (0 fresh GT calls)
    s = streams["lausanne"]
    cls = int(dominant_classes(s["labels"])[0])
    for kx in (4, 2, 1):
        res = s["engine"].query(cls, Kx=kx)
        print(f"  Kx={kx}: candidates={res.n_candidate_clusters} "
              f"frames={len(res.frames)} fresh_gt={res.n_gt_invocations}")


if __name__ == "__main__":
    main()
