"""Quickstart: the whole Focus loop in ~40 lines.

  PYTHONPATH=src:. python examples/quickstart.py

Generates a synthetic surveillance stream, specializes a cheap ingest CNN
(§4.3), builds the clustered top-K index (§4.1-4.2), then answers
"find all frames with class X" queries by running the GT-CNN only on
cluster centroids — and prints the cost/latency wins vs the two baselines.
"""
import numpy as np

from repro.common.config import CheapCNNConfig
from repro.core import IngestConfig, ingest, query
from repro.core.query import (dominant_classes, gpu_seconds,
                              gt_frames_by_class, precision_recall)
from repro.core.specialize import specialize
from repro.data import get_stream

GT_FLOPS = 1.2e11      # GT-CNN (vit-l16 @224) per-object cost


def main():
    # 1. a synthetic plaza camera, 60s @ 10 fps, exact ground truth
    stream = get_stream("lausanne", duration_s=60, fps=10)
    crops, frames, _, labels = stream.objects_array()
    print(f"stream: {len(crops)} detected objects, "
          f"{len(np.unique(labels))} classes")

    # 2. specialize a cheap CNN on this stream (top-Ls classes + OTHER)
    base = CheapCNNConfig("cheap", input_res=32, n_blocks=4, width=32,
                          feature_dim=128)
    sm = specialize(crops, labels, Ls=5, base_cfg=base, steps=150)
    print(f"specialized model acc: {sm.history[-1]['acc']:.3f}")

    # 3. ingest: cheap CNN -> top-K index + object clusters
    index, stats = ingest(crops, frames, sm.make_apply(),
                          cheap_flops_per_image=GT_FLOPS / 50,
                          cfg=IngestConfig(K=2, threshold=0.8,
                                           max_clusters=512),
                          class_map=sm.class_map)
    print(f"index: {index.n_clusters} clusters for {index.n_objects} objects"
          f"  (ingest {gpu_seconds(stats.cheap_flops):.2f} GPU-s vs"
          f" Ingest-all {gpu_seconds(len(crops) * GT_FLOPS):.2f} GPU-s)")

    # 4. query by class; GT-CNN (here: exact oracle) on centroids only
    from benchmarks.common import gt_oracle
    gt_apply = gt_oracle(labels)
    gtf = gt_frames_by_class(labels, frames)
    for x in dominant_classes(labels)[:3]:
        res = query(index, int(x), gt_apply, GT_FLOPS)
        p, r = precision_recall(res.frames, gtf[int(x)])
        speedup = len(crops) / max(res.n_gt_invocations, 1)
        print(f"query class {x}: {len(res.frames)} frames  "
              f"P={p:.2f} R={r:.2f}  {speedup:.0f}x fewer GT-CNN calls "
              f"than Query-all")


if __name__ == "__main__":
    main()
