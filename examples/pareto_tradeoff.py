"""Parameter selection walkthrough (§4.4, Fig. 6).

Sweeps (CheapCNN_i, K, T) for one stream, prints the viable configs, the
Pareto boundary, and the Balance / Opt-Ingest / Opt-Query selections.

  PYTHONPATH=src:. python examples/pareto_tradeoff.py
"""
import numpy as np

from benchmarks.common import GT_FLOPS, stream_sweep
from repro.core.params import pareto_boundary, select


def main():
    stream = "auburn_c"
    evals, n_objects = stream_sweep(stream, duration_s=60)
    ingest_all = n_objects * GT_FLOPS
    query_all = n_objects * GT_FLOPS

    viable = [e for e in evals if e.viable]
    front = pareto_boundary(evals)
    print(f"{stream}: {len(evals)} configs, {len(viable)} viable, "
          f"{len(front)} on the Pareto boundary\n")
    print(f"{'model':>7} {'K':>3} {'T':>5} {'P':>6} {'R':>6} "
          f"{'ingest':>9} {'query':>9}  on-front")
    for e in sorted(viable, key=lambda e: e.ingest_flops)[:15]:
        print(f"{e.candidate.model_id:>7} {e.candidate.K:>3} "
              f"{e.candidate.T:>5.2f} {e.precision:>6.3f} {e.recall:>6.3f} "
              f"{ingest_all/e.ingest_flops:>8.0f}x "
              f"{query_all/max(e.query_flops,1):>8.0f}x  "
              f"{'*' if e in front else ''}")

    print()
    for policy in ("balance", "opt_ingest", "opt_query"):
        c = select(evals, policy)
        if c is None:
            print(f"{policy:>11}: no viable config")
            continue
        print(f"{policy:>11}: model={c.candidate.model_id} K={c.candidate.K} "
              f"T={c.candidate.T} -> ingest {ingest_all/c.ingest_flops:.0f}x "
              f"cheaper, query {query_all/max(c.query_flops,1):.0f}x faster")


if __name__ == "__main__":
    main()
