"""Specialization study (§4.3): generic vs specialized cheap CNN.

Trains (a) a generic 1000-way compressed CNN and (b) a per-stream
specialized (Ls+OTHER) CNN with the full training substrate (AdamW +
cosine, checkpoint/restart), then shows the paper's claim: the specialized
model reaches the recall target with a much smaller K.

  PYTHONPATH=src:. python examples/train_specialized.py
"""
import numpy as np

from repro.common.config import CheapCNNConfig
from repro.core import IngestConfig, ingest
from repro.core.query import (dominant_classes, gt_frames_by_class,
                              precision_recall)
from repro.core.specialize import specialize, train_generic
from repro.data import get_stream


def recall_at_k(index, labels, frames, ks):
    dom = dominant_classes(labels)
    gtf = gt_frames_by_class(labels, frames)
    out = {}
    for K in ks:
        rs = []
        for x in dom:
            cids = index.lookup(x, K)
            matched = [c for c, fm in
                       zip(cids, index.first_members(cids))
                       if labels[fm] == x]
            _, r = precision_recall(index.frames_of(matched),
                                    gtf.get(x, np.array([])))
            rs.append(r)
        out[K] = float(np.mean(rs))
    return out


def main():
    stream = get_stream("auburn_r", duration_s=90, fps=10)
    crops, frames, _, labels = stream.objects_array()
    print(f"{len(crops)} objects, {len(np.unique(labels))} classes")

    generic_cfg = CheapCNNConfig("generic", input_res=32, n_blocks=3,
                                 width=24, n_classes=1000, feature_dim=128)
    spec_cfg = CheapCNNConfig("spec", input_res=32, n_blocks=3, width=24,
                              feature_dim=128)

    print("training generic 1000-way model (300 steps)...")
    gm = train_generic(crops, labels, generic_cfg, steps=300)
    print(f"  final acc {gm.history[-1]['acc']:.3f}")
    print("training specialized Ls=5+OTHER model (300 steps)...")
    sm = specialize(crops, labels, Ls=5, base_cfg=spec_cfg, steps=300)
    print(f"  final acc {sm.history[-1]['acc']:.3f}")

    ks = (1, 2, 4, 8, 16)
    gi, _ = ingest(crops, frames, gm.make_apply(), 1e9,
                   IngestConfig(K=max(ks), threshold=0.8, max_clusters=1024))
    si, _ = ingest(crops, frames, sm.make_apply(), 1e9,
                   IngestConfig(K=max(ks), threshold=0.8, max_clusters=1024),
                   class_map=sm.class_map)
    rg = recall_at_k(gi, labels, frames, ks)
    rs = recall_at_k(si, labels, frames, ks)
    print(f"{'K':>4} {'generic recall':>15} {'specialized recall':>20}")
    for K in ks:
        print(f"{K:>4} {rg[K]:>15.3f} {rs[K]:>20.3f}")
    kg = next((K for K in ks if rg[K] >= 0.95), None)
    ksp = next((K for K in ks if rs[K] >= 0.95), None)
    print(f"K needed for 95% recall: generic={kg}, specialized={ksp} "
          f"(paper: specialization drops K from 60-200 to 2-4)")


if __name__ == "__main__":
    main()
