"""Step builders: (arch, shape-cell, mesh) -> jit-able step + ShapeDtypeStruct
inputs + shardings. Shared by the dry-run, the trainer and the server.

Every builder returns a StepSpec whose ``args`` are ShapeDtypeStruct
stand-ins (weak-type-correct, shardable, no device allocation) — lowering
via jax.jit(fn, in_shardings=...).lower(*args) never touches device memory.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import (DiTConfig, EffNetConfig, LMConfig, ShapeCell,
                                 ViTConfig)
from repro.configs import get_arch, get_shapes
from repro.distributed import param_shardings
from repro.models import dit, efficientnet, transformer, vit
from repro.train import optimizer as opt

OPT_CFG = opt.OptConfig(lr=3e-4, warmup_steps=2000, total_steps=100000)


@dataclass
class StepSpec:
    name: str
    fn: Callable
    args: Tuple[Any, ...]          # pytrees of ShapeDtypeStruct
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    skip_reason: Optional[str] = None   # set for inapplicable cells


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dp_axes(mesh: Mesh, batch: int):
    """Largest (pod,data)-combination that divides the batch, else None."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    cands = []
    if len(names) == 2:
        cands.append(tuple(names))
    cands += [(n,) for n in names]
    for c in sorted(cands, key=lambda c: -math.prod(mesh.shape[n] for n in c)):
        if batch % math.prod(mesh.shape[n] for n in c) == 0:
            return c if len(c) > 1 else c[0]
    return None


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_param_shapes(cfg: LMConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: transformer.init(key, cfg))


def _cache_sharding(cfg: LMConfig, cell: ShapeCell, mesh: Mesh):
    """(L, B, S, KV, hd) cache: batch over dp; model axis over KV heads when
    divisible, else sequence (SP — MQA/GQA with few heads, long caches)."""
    dp = _dp_axes(mesh, cell.global_batch)
    m = mesh.shape["model"]
    if cell.kind == "long":
        # B=1: spend every axis on sequence
        axes = _all_axes(mesh)
        if cell.seq_len % math.prod(mesh.shape[a] for a in axes) == 0:
            return _ns(mesh, None, None, axes, None, None)
    if cfg.n_kv_heads % m == 0:
        return _ns(mesh, None, dp, None, "model", None)
    if cell.seq_len % m == 0:
        return _ns(mesh, None, dp, "model", None, None)
    return _ns(mesh, None, dp, None, None, None)


def _zero1_shardings(o_shapes, mesh: Mesh):
    """Shard AdamW m/v over as much of the mesh as divides the leading dim
    (ZeRO-1); scalars replicated."""
    axes = _all_axes(mesh)

    def visit(leaf):
        for cand in (axes, axes[:-1], axes[-1:]):
            size = math.prod(mesh.shape[a] for a in cand) if cand else 1
            if leaf.ndim >= 1 and leaf.shape[0] % size == 0 and size > 1:
                return _ns(mesh, cand if len(cand) > 1 else cand[0],
                           *([None] * (leaf.ndim - 1)))
        return _ns(mesh)

    return jax.tree.map(visit, o_shapes)


def build_lm(cfg: LMConfig, cell: ShapeCell, mesh: Mesh) -> StepSpec:
    import dataclasses as _dc

    if cell.kind == "long" and cfg.attention == "full":
        # Paper-faithful configs are pure full attention -> skip per
        # instructions; the window variant is built via build_lm_long_window.
        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=None, args=(),
            in_shardings=(), out_shardings=None,
            skip_reason=("pure full-attention arch; long_500k requires "
                         "sub-quadratic attention (DESIGN.md). Window-"
                         "attention variant reported separately."))

    p_shapes = _lm_param_shapes(cfg)
    ddp = getattr(cfg, "parallelism", "fsdp_tp") == "ddp_zero1"
    if ddp:
        # ZeRO-1 for small models: params REPLICATED (no per-layer weight
        # gathers, no TP activation reduces); only the optimizer moments are
        # sharded; the batch spreads over EVERY mesh axis.
        p_shard = jax.tree.map(lambda _: _ns(mesh), p_shapes)
    else:
        p_shard = param_shardings(p_shapes, mesh, scan_layers=True)
    B, S = cell.global_batch, cell.seq_len
    dp = _dp_axes(mesh, B)
    if ddp:
        all_ax = _all_axes(mesh)
        if B % math.prod(mesh.shape[a] for a in all_ax) == 0:
            dp = all_ax
    model_mesh = None if ddp else mesh   # no activation constraints in DDP

    if cell.kind == "train":
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        if ddp:
            o_shard = _zero1_shardings(o_shapes, mesh)
        else:
            o_shard = param_shardings(o_shapes, mesh, scan_layers=True)
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        b_shard = {"tokens": _ns(mesh, dp, None),
                   "labels": _ns(mesh, dp, None)}

        n_mb = max(1, cfg.train_microbatches)
        g_dtype = {"f32": jnp.float32, "bf16": jnp.bfloat16}[
            getattr(cfg, "grad_reduce_dtype", "f32")]

        def train_step(params, opt_state, batch):
            def loss(p, toks, labs):
                return transformer.loss_fn(p, toks, labs, cfg,
                                           mesh=model_mesh)

            if n_mb == 1:
                (l, _), grads = jax.value_and_grad(loss, has_aux=True)(
                    params, batch["tokens"], batch["labels"])
            else:
                # grad accumulation: peak activation memory / n_mb
                mbs = jax.tree.map(
                    lambda x: x.reshape((n_mb, x.shape[0] // n_mb)
                                        + x.shape[1:]), batch)

                def acc(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = jax.value_and_grad(loss, has_aux=True)(
                        params, mb["tokens"], mb["labels"])
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + l), ()

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, l), _ = jax.lax.scan(
                    acc, (g0, jnp.zeros((), jnp.float32)), mbs)
                grads = jax.tree.map(lambda g: g / n_mb, grads)
                l = l / n_mb
            # wire-format cast: the cross-replica reduce (and, under ZeRO-1,
            # the grad slice each shard reads) moves bf16 instead of f32.
            grads = jax.tree.map(lambda g: g.astype(g_dtype), grads)
            params, opt_state, _ = opt.update(params, grads, opt_state,
                                              OPT_CFG)
            return params, opt_state, l

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=train_step,
            args=(p_shapes, o_shapes, batch),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, _ns(mesh)),
            donate_argnums=(0, 1))

    if cell.kind == "prefill":
        import dataclasses as _dcc
        batch = _sds((B, S), jnp.int32)
        n_bc = cfg.prefill_batch_chunks or 1
        if cfg.prefill_batch_chunks == 0 and cfg.d_model >= 6144 \
                and S >= 32768:
            # long-prefill recipe (see EXPERIMENTS.md §Perf): dp residuals +
            # 1k query chunks + batch halves keep the live set under 16 GB
            cfg = _dcc.replace(cfg, act_sharding="dp", attn_q_chunk=1024)
            n_bc = 2 if B % 2 == 0 else 1
        while B % n_bc:
            n_bc -= 1

        def serve_step(params, tokens):
            if n_bc == 1:
                return transformer.prefill(params, tokens, cfg,
                                           mesh=model_mesh)
            # serialize the batch in chunks (barrier-chained) to halve the
            # live activation set of very long prefills
            outs = []
            prev = None
            bs = B // n_bc
            for i in range(n_bc):
                blk = tokens[i * bs:(i + 1) * bs]
                if prev is not None:
                    blk, _ = jax.lax.optimization_barrier((blk, prev))
                prev = transformer.prefill(params, blk, cfg, mesh=model_mesh)
                outs.append(prev)
            return jnp.concatenate(outs, axis=0)

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=serve_step,
            args=(p_shapes, batch),
            in_shardings=(p_shard, _ns(mesh, dp, None)),
            out_shardings=_ns(mesh, dp, None, None if ddp else "model"))

    if cell.kind in ("decode", "long"):
        c_shapes = jax.eval_shape(
            lambda: transformer.init_cache(cfg, B, S))
        c_shard = jax.tree.map(lambda _: _cache_sharding(cfg, cell, mesh),
                               c_shapes)
        token = _sds((B, 1), jnp.int32)
        clen = _sds((), jnp.int32)

        def serve_step(params, cache, token, cache_len):
            return transformer.decode_step(params, cache, token, cache_len,
                                           cfg, mesh=model_mesh)

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=serve_step,
            args=(p_shapes, c_shapes, token, clen),
            in_shardings=(p_shard, c_shard, _ns(mesh, dp, None), _ns(mesh)),
            out_shardings=(_ns(mesh, dp, None, None if ddp else "model"),
                           c_shard),
            donate_argnums=(1,))

    raise ValueError(cell.kind)


def build_lm_long_window(cfg: LMConfig, cell: ShapeCell, mesh: Mesh,
                         window: int = 8192) -> StepSpec:
    """Beyond-paper variant: sliding-window attention so long_500k lowers."""
    import dataclasses as _dc
    wcfg = _dc.replace(cfg, attention="window", window=window,
                       name=cfg.name + f"-win{window}")
    spec = build_lm(wcfg, cell, mesh)
    spec.name = f"{cfg.name}:{cell.name}:window{window}"
    return spec


# ---------------------------------------------------------------------------
# DiT family
# ---------------------------------------------------------------------------

def build_dit(cfg: DiTConfig, cell: ShapeCell, mesh: Mesh) -> StepSpec:
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda: dit.init(key, cfg))
    p_shard = param_shardings(p_shapes, mesh, scan_layers=True)
    B = cell.global_batch
    res = cell.img_res // cfg.vae_factor
    dp = _dp_axes(mesh, B)
    seed = _sds((2,), jnp.uint32)

    if cell.kind == "dit_train":
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_shard = param_shardings(o_shapes, mesh, scan_layers=True)
        batch = {"latents": _sds((B, res, res, cfg.latent_channels),
                                 jnp.float32),
                 "labels": _sds((B,), jnp.int32)}
        b_shard = {"latents": _ns(mesh, dp, None, None, None),
                   "labels": _ns(mesh, dp)}

        def train_step(params, opt_state, batch, seed):
            rng = jax.random.wrap_key_data(seed)

            def loss(p):
                return dit.loss_fn(p, batch["latents"], batch["labels"], rng,
                                   cfg, mesh=mesh)
            (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params)
            params, opt_state, _ = opt.update(params, grads, opt_state,
                                              OPT_CFG)
            return params, opt_state, l

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=train_step,
            args=(p_shapes, o_shapes, batch, seed),
            in_shardings=(p_shard, o_shard, b_shard, _ns(mesh, None)),
            out_shardings=(p_shard, o_shard, _ns(mesh)),
            donate_argnums=(0, 1))

    if cell.kind == "dit_gen":
        labels = _sds((B,), jnp.int32)

        def serve_step(params, labels, seed):
            rng = jax.random.wrap_key_data(seed)
            return dit.sample(params, rng, labels, cfg,
                              img_res=cell.img_res, n_steps=cell.steps,
                              mesh=mesh)

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=serve_step,
            args=(p_shapes, labels, seed),
            in_shardings=(p_shard, _ns(mesh, dp), _ns(mesh, None)),
            out_shardings=_ns(mesh, dp, None, None, None))

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# Vision family (ViT / DeiT / EfficientNet)
# ---------------------------------------------------------------------------

def build_vit(cfg: ViTConfig, cell: ShapeCell, mesh: Mesh) -> StepSpec:
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda: vit.init(key, cfg))
    p_shard = param_shardings(p_shapes, mesh, scan_layers=True)
    B, R = cell.global_batch, cell.img_res
    dp = _dp_axes(mesh, B)
    images = _sds((B, R, R, 3), jnp.float32)
    img_shard = _ns(mesh, dp, None, None, None)

    if cell.kind == "cls":
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_shard = param_shardings(o_shapes, mesh, scan_layers=True)
        batch = {"images": images, "labels": _sds((B,), jnp.int32)}
        b_shard = {"images": img_shard, "labels": _ns(mesh, dp)}

        def train_step(params, opt_state, batch):
            def loss(p):
                return vit.loss_fn(p, batch["images"], batch["labels"], cfg,
                                   mesh=mesh)
            (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params)
            params, opt_state, _ = opt.update(params, grads, opt_state,
                                              OPT_CFG)
            return params, opt_state, l

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=train_step,
            args=(p_shapes, o_shapes, batch),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, _ns(mesh)),
            donate_argnums=(0, 1))

    if cell.kind == "serve":
        if getattr(cfg, "serve_pure_dp", False):
            # Pure-DP serving: weights replicated (vit-l16 is 0.6 GB bf16),
            # batch padded up to the full chip count and spread over EVERY
            # axis -> zero per-layer collectives; one small resharding
            # collective for the pad/spread at entry.
            n_chips = math.prod(mesh.shape.values())
            pad_to = ((B + n_chips - 1) // n_chips) * n_chips
            p_repl = jax.tree.map(lambda _: _ns(mesh), p_shapes)
            axes = _all_axes(mesh)

            def serve_step(params, images):
                x = jnp.pad(images, ((0, pad_to - B), (0, 0), (0, 0), (0, 0)))
                x = jax.lax.with_sharding_constraint(
                    x, _ns(mesh, axes, None, None, None))
                logits = vit.forward(params, x, cfg, mesh=None)
                return logits[:B]

            return StepSpec(
                name=f"{cfg.name}:{cell.name}", fn=serve_step,
                args=(p_shapes, images),
                in_shardings=(p_repl, img_shard),
                out_shardings=_ns(mesh, dp, None))

        def serve_step(params, images):
            return vit.forward(params, images, cfg, mesh=mesh)

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=serve_step,
            args=(p_shapes, images),
            in_shardings=(p_shard, img_shard),
            out_shardings=_ns(mesh, dp, None))

    raise ValueError(cell.kind)


def build_effnet(cfg: EffNetConfig, cell: ShapeCell, mesh: Mesh) -> StepSpec:
    key = jax.random.PRNGKey(0)
    ps_shapes = jax.eval_shape(lambda: efficientnet.init(key, cfg))
    p_shapes, s_shapes = ps_shapes
    p_shard = param_shardings(p_shapes, mesh, scan_layers=False)
    s_shard = param_shardings(s_shapes, mesh, scan_layers=False)
    B, R = cell.global_batch, cell.img_res
    dp = _dp_axes(mesh, B)
    images = _sds((B, R, R, 3), jnp.float32)
    img_shard = _ns(mesh, dp, None, None, None)

    if cell.kind == "cls":
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_shard = param_shardings(o_shapes, mesh, scan_layers=False)
        batch = {"images": images, "labels": _sds((B,), jnp.int32)}
        b_shard = {"images": img_shard, "labels": _ns(mesh, dp)}

        def train_step(params, state, opt_state, batch):
            def loss(p):
                l, (m, new_state) = efficientnet.loss_fn(
                    p, state, batch["images"], batch["labels"], cfg,
                    mesh=mesh)
                return l, new_state
            (l, new_state), grads = jax.value_and_grad(
                loss, has_aux=True)(params)
            params, opt_state, _ = opt.update(params, grads, opt_state,
                                              OPT_CFG)
            return params, new_state, opt_state, l

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=train_step,
            args=(p_shapes, s_shapes, o_shapes, batch),
            in_shardings=(p_shard, s_shard, o_shard, b_shard),
            out_shardings=(p_shard, s_shard, o_shard, _ns(mesh)),
            donate_argnums=(0, 2))

    if cell.kind == "serve":
        def serve_step(params, state, images):
            logits, _ = efficientnet.forward(params, state, images, cfg,
                                             train=False, mesh=mesh)
            return logits

        return StepSpec(
            name=f"{cfg.name}:{cell.name}", fn=serve_step,
            args=(p_shapes, s_shapes, images),
            in_shardings=(p_shard, s_shard, img_shard),
            out_shardings=_ns(mesh, dp, None))

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def build(arch_id: str, cell_name: str, mesh: Mesh,
          variant: Optional[str] = None,
          cfg_overrides: Optional[dict] = None) -> StepSpec:
    import dataclasses as _dc
    cfg = get_arch(arch_id)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    cell = get_shapes(arch_id)[cell_name]
    if isinstance(cfg, LMConfig):
        if cell.kind == "long" and variant == "window":
            return build_lm_long_window(cfg, cell, mesh)
        return build_lm(cfg, cell, mesh)
    if isinstance(cfg, DiTConfig):
        return build_dit(cfg, cell, mesh)
    if isinstance(cfg, ViTConfig):
        return build_vit(cfg, cell, mesh)
    if isinstance(cfg, EffNetConfig):
        return build_effnet(cfg, cell, mesh)
    raise TypeError(type(cfg))
