"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
nothing here does that globally.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (jax.sharding.AxisType landed after 0.4.x; older releases
    are Auto-only, so omitting the kwarg is equivalent)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
