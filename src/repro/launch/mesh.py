"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
nothing here does that globally.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (jax.sharding.AxisType landed after 0.4.x; older releases
    are Auto-only, so omitting the kwarg is equivalent)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_ingest_mesh(n_devices: int):
    """1-D ``("data",)`` mesh for sharded multi-stream ingest
    (DESIGN.md §13): each device owns a disjoint block of stream slots.

    Unlike ``make_production_mesh`` (fixed 256/512-chip shapes), this
    takes any ``n_devices`` and validates it against the runtime device
    count up front, so a bad count fails with an actionable error instead
    of an opaque XLA one deep inside the first sharded dispatch. The mesh
    is built over the *first* ``n_devices`` devices, so CPU CI can build
    1/2/4-device meshes inside one 8-device
    ``--xla_force_host_platform_device_count`` process.

    Module contract preserved: device state is only touched when this is
    *called*, never at import.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    avail = jax.device_count()
    if n_devices > avail:
        raise ValueError(
            f"make_ingest_mesh(n_devices={n_devices}) but only {avail} "
            f"jax device(s) are visible; on CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices} in the "
            f"environment BEFORE the first jax import (see the "
            f"sharded-ingest CI step)")
    import numpy as np
    return jax.sharding.Mesh(np.array(jax.devices()[:n_devices]), ("data",))
