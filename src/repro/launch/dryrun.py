import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init); this module is the ONLY place 512 placeholder devices
exist — tests and benchmarks see 1 CPU device.

Per cell this records: compile success, memory_analysis (proves it fits),
cost_analysis FLOPs/bytes, the collective schedule parsed from the
partitioned HLO, and the three roofline terms (EXPERIMENTS.md §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh both --out experiments/dryrun
"""
import argparse
import json
import math
import re
import time
import traceback

import jax

from repro.common.config import DiTConfig, LMConfig, ShapeCell, ViTConfig
from repro.configs import ARCH_IDS, get_arch, get_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build

# --- TPU v5e hardware constants (roofline denominators) --------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/]+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind, from partitioned HLO.

    Ring-algorithm wire-cost model per participating device with group
    size k and result bytes R:
      all-gather: R(k-1)/k   all-reduce: 2R(k-1)/k
      reduce-scatter: R(k-1) all-to-all: R(k-1)/k  permute: R
    """
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue            # async pair: count the -start only
        shape_text, kind = m.group(1), m.group(2)
        r = _shape_bytes(shape_text)
        k = 1
        g = _GROUPS_RE.search(line)
        if g:
            k = int(g.group(2))
        else:
            g2 = _GROUPS_LIST_RE.search(line)
            if g2:
                k = len(g2.group(1).split(","))
        if k <= 1:
            factor = 0.0
        elif kind == "all-reduce":
            factor = 2.0 * (k - 1) / k
        elif kind == "reduce-scatter":
            factor = float(k - 1)
        elif kind == "collective-permute":
            factor = 1.0
        else:
            factor = (k - 1) / k
        out[kind] += r * factor
        counts[kind] += 1
    return {"wire_bytes": out, "counts": counts,
            "total_wire_bytes": sum(out.values())}


def _measure(spec) -> dict:
    """Compile a StepSpec and read per-device flops / bytes / wire bytes."""
    with_mesh = spec.in_shardings  # shardings carry the mesh
    lowered = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                      out_shardings=spec.out_shardings,
                      donate_argnums=spec.donate_argnums).lower(*spec.args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": coll["total_wire_bytes"],
            "coll": coll}


def estimate_costs(arch_id: str, cell_name: str, mesh, variant=None,
                   cfg_overrides=None):
    """Accurate per-device cost terms via two-point layer extrapolation.

    XLA's HloCostAnalysis counts a while-loop body ONCE, so the scanned
    full-depth compile undercounts FLOPs/bytes by ~L×. We therefore compile
    the same cell UNROLLED at n_layers=1 and n_layers=2 and extrapolate
    linearly: F(L) = F(1) + (L-1)·(F(2)-F(1)). The intercept captures
    embeddings/head/optimizer-outer work, the slope the per-layer work.
    DiT gen cells additionally scale by the sampler step count (the sampler
    is measured at steps=1). EfficientNet has no scan — measured directly.
    """
    import dataclasses as dc

    from repro.launch import steps as st

    cfg = get_arch(arch_id)
    if cfg_overrides:
        cfg = dc.replace(cfg, **cfg_overrides)
    cell = get_shapes(arch_id)[cell_name]
    if not hasattr(cfg, "scan_layers"):
        return None                      # effnet: direct measurement is exact

    recs = []
    for L in (1, 2):
        vcfg = dc.replace(cfg, n_layers=L, scan_layers=False)
        if isinstance(cfg, LMConfig):
            if cell.kind == "long" and variant == "window":
                vcfg = dc.replace(vcfg, attention="window", window=8192)
            spec = st.build_lm(vcfg, cell, mesh)
        elif isinstance(cfg, DiTConfig):
            vcell = (dc.replace(cell, steps=1)
                     if cell.kind == "dit_gen" else cell)
            spec = st.build_dit(vcfg, vcell, mesh)
        else:
            spec = st.build_vit(vcfg, cell, mesh)
        with mesh:
            recs.append(_measure(spec))

    L = cfg.n_layers

    def extrap(key):
        slope = max(recs[1][key] - recs[0][key], 0.0)
        return recs[0][key] + (L - 1) * slope

    out = {k: extrap(k) for k in ("flops", "bytes", "wire")}
    coll_kinds = {}
    for kind in recs[0]["coll"]["wire_bytes"]:
        a = recs[0]["coll"]["wire_bytes"][kind]
        b = recs[1]["coll"]["wire_bytes"][kind]
        coll_kinds[kind] = a + (L - 1) * max(b - a, 0.0)
    out["wire_by_kind"] = coll_kinds
    if isinstance(cfg, DiTConfig) and cell.kind == "dit_gen":
        for k in ("flops", "bytes", "wire"):
            out[k] *= cell.steps
        out["wire_by_kind"] = {k: v * cell.steps
                               for k, v in coll_kinds.items()}
    # Microbatched train steps: the accumulation scan body is counted once by
    # HloCostAnalysis; scale by n_mb (slightly overcounts the optimizer's
    # outer work, which runs once per step — small and conservative).
    n_mb = getattr(cfg, "train_microbatches", 1)
    if cell.kind == "train" and n_mb > 1:
        for k in ("flops", "bytes", "wire"):
            out[k] *= n_mb
        out["wire_by_kind"] = {k: v * n_mb
                               for k, v in out["wire_by_kind"].items()}
    out["method"] = "unrolled-2pt-extrapolation"
    return out


def model_flops(arch_id: str, cell: ShapeCell) -> float:
    """Reference useful work: 6·N·D train / 2·N·D inference (N = active)."""
    cfg = get_arch(arch_id)
    n = cfg.n_active_params()
    if isinstance(cfg, LMConfig):
        tokens = cell.global_batch * max(cell.seq_len, 1)
        if cell.kind == "train":
            return 6.0 * n * tokens
        if cell.kind == "prefill":
            return 2.0 * n * tokens
        return 2.0 * n * cell.global_batch          # decode: 1 new token
    if isinstance(cfg, DiTConfig):
        toks = cell.global_batch * cfg.n_tokens(cell.img_res)
        if cell.kind == "dit_train":
            return 6.0 * n * toks
        return 2.0 * n * toks * cell.steps
    # vision
    if isinstance(cfg, ViTConfig):
        fwd = 2.0 * n * cell.global_batch * cfg.n_tokens(cell.img_res)
    else:
        from repro.models.efficientnet import flops_per_image
        fwd = float(flops_per_image(cfg, cell.img_res)) * cell.global_batch
    return 3.0 * fwd if cell.kind == "cls" else fwd


def run_cell(arch_id: str, cell_name: str, multi_pod: bool,
             variant=None, cfg_overrides=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.shape.values())
    cell = get_shapes(arch_id)[cell_name]
    rec = {"arch": arch_id, "cell": cell_name, "variant": variant,
           "overrides": cfg_overrides,
           "mesh": dict(mesh.shape), "n_chips": n_chips, "ok": False}

    spec = build(arch_id, cell_name, mesh, variant=variant,
                 cfg_overrides=cfg_overrides)
    if spec.skip_reason:
        rec.update(skipped=True, skip_reason=spec.skip_reason, ok=True)
        return rec

    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            spec.fn, in_shardings=spec.in_shardings,
            out_shardings=spec.out_shardings,
            donate_argnums=spec.donate_argnums).lower(*spec.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[f] = getattr(ma, f, 0)
        live = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0))
        mem["live_bytes_per_device"] = live
        mem["fits_16gb_hbm"] = bool(live < 16e9)

    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    rec["scanned_raw"] = {          # as-compiled numbers (loop bodies 1x)
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "wire_bytes": coll["total_wire_bytes"],
        "collective_counts": coll["counts"],
    }

    est = estimate_costs(arch_id, cell_name, mesh, variant=variant,
                         cfg_overrides=cfg_overrides)
    if est is not None:
        flops_dev, bytes_dev = est["flops"], est["bytes"]
        wire_dev = est["wire"]
        coll = {"wire_bytes": est["wire_by_kind"], "counts": coll["counts"],
                "total_wire_bytes": wire_dev, "method": est["method"]}
    else:
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        wire_dev = coll["total_wire_bytes"]

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(arch_id, cell)
    hlo_total_flops = flops_dev * n_chips
    rec.update(
        ok=True, lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collectives=coll,
        roofline={**terms, "dominant": dominant,
                  "bound_step_s": max(terms.values())},
        model_flops=mf, hlo_total_flops=hlo_total_flops,
        useful_flops_ratio=(mf / hlo_total_flops if hlo_total_flops else 0.0),
        roofline_fraction=(
            (mf / n_chips / PEAK_FLOPS) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch in archs:
        shapes = (list(get_shapes(arch)) if args.shape == "all"
                  else args.shape.split(","))
        for cell in shapes:
            for mp in meshes:
                tag = "multi" if mp else "single"
                suffix = f"_{args.variant}" if args.variant else ""
                path = os.path.join(args.out,
                                    f"{arch}_{cell}_{tag}{suffix}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {path}")
                    continue
                print(f"[dryrun] {arch} x {cell} x {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, cell, mp, variant=args.variant)
                except Exception as e:
                    rec = {"arch": arch, "cell": cell, "variant": args.variant,
                           "mesh_tag": tag, "ok": False, "error": str(e),
                           "traceback": traceback.format_exc()}
                    n_fail += 1
                    print(f"  FAILED: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("ok") and not rec.get("skipped"):
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3g} "
                          f"dom={r['dominant']} "
                          f"roofline_frac={rec['roofline_fraction']:.3f}",
                          flush=True)
                elif rec.get("skipped"):
                    print(f"  skipped: {rec['skip_reason'][:60]}")
    print(f"done, failures={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
