"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the per-cell
JSON records written by repro.launch.dryrun.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        r = json.load(open(f))
        tag = "multi" if (r.get("mesh", {}).get("pod") or
                          "multi" in os.path.basename(f)) else "single"
        r["mesh_tag"] = tag
        r["file"] = os.path.basename(f)
        recs.append(r)
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def dryrun_table(recs):
    print("| arch | cell | mesh | status | compile | GB/dev | fits 16GB | "
          "collectives (AG/AR/RS/A2A/CP) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        arch, cell = r.get("arch"), r.get("cell")
        tag = r["mesh_tag"]
        var = f" ({r['variant']})" if r.get("variant") else ""
        if r.get("skipped"):
            print(f"| {arch} | {cell}{var} | {tag} | SKIP (full-attn, "
                  f"see DESIGN.md) | | | | |")
            continue
        if not r.get("ok"):
            print(f"| {arch} | {cell}{var} | {tag} | **FAIL**: "
                  f"{r.get('error','')[:60]} | | | | |")
            continue
        m = r.get("memory", {})
        live = m.get("live_bytes_per_device", 0) / 1e9
        fits = "yes" if m.get("fits_16gb_hbm") else "**NO**"
        c = r.get("scanned_raw", {}).get("collective_counts", {})
        cc = (f"{c.get('all-gather',0)}/{c.get('all-reduce',0)}"
              f"/{c.get('reduce-scatter',0)}/{c.get('all-to-all',0)}"
              f"/{c.get('collective-permute',0)}")
        print(f"| {arch} | {cell}{var} | {tag} | ok | {r['compile_s']}s | "
              f"{live:.1f} | {fits} | {cc} |")


def roofline_table(recs):
    print("| arch | cell | compute | memory | collective | dominant | "
          "bound/step | MODEL_FLOPS | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh_tag"] != "single" or r.get("skipped") or not r.get("ok"):
            continue
        rl = r["roofline"]
        var = f" ({r['variant']})" if r.get("variant") else ""
        print(f"| {r['arch']} | {r['cell']}{var} | {fmt_s(rl['compute_s'])} | "
              f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
              f"**{rl['dominant'].replace('_s','')}** | "
              f"{fmt_s(rl['bound_step_s'])} | {r['model_flops']:.3g} | "
              f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |")


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    n_ok = sum(1 for r in recs if r.get("ok"))
    n_skip = sum(1 for r in recs if r.get("skipped"))
    print(f"<!-- {len(recs)} cells: {n_ok} ok ({n_skip} documented skips), "
          f"{len(recs)-n_ok} failed -->\n")
    print("### Dry-run matrix\n")
    dryrun_table(recs)
    print("\n### Roofline (single-pod 16x16, per device)\n")
    roofline_table(recs)


if __name__ == "__main__":
    main()
