"""End-to-end Focus serving driver (the paper's deployment shape, §5).

Pipeline per stream: sample -> GT-label -> specialize cheap CNN ->
parameter selection (§4.4) -> ingest (index+clusters) -> serve queries.
Query workers batch centroid classifications; per-query latency and cost
are reported against the Ingest-all / Query-all baselines.

  PYTHONPATH=src python -m repro.launch.serve --stream lausanne \
      --policy balance --duration 60
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig, ingest
from repro.core.params import select, sweep
from repro.core.query import (dominant_classes, gpu_seconds,
                              gt_frames_by_class, precision_recall)
from repro.data import get_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stream", default="lausanne")
    ap.add_argument("--policy", default="balance",
                    choices=["balance", "opt_ingest", "opt_query"])
    ap.add_argument("--duration", type=int, default=60)
    ap.add_argument("--fps", type=int, default=10)
    ap.add_argument("--ls", type=int, default=6)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--rounds", type=int, default=3,
                    help="query-workload rounds (round 1 is cold, the rest "
                         "exercise the warm GT-label cache)")
    ap.add_argument("--index-out", default=None)
    args = ap.parse_args()

    from benchmarks.common import (GT_FLOPS, SPECIALIZED_FAMILY, get_model,
                                   gt_oracle)

    vs = get_stream(args.stream, duration_s=args.duration, fps=args.fps)
    crops, frames, tracks, labels = vs.objects_array()
    print(f"[serve] stream={args.stream} objects={len(crops)} "
          f"classes={len(np.unique(labels))}")

    # §4.4 parameter selection over the specialized family
    models, cmaps = {}, {}
    for mid in SPECIALIZED_FAMILY:
        apply_fn, acc_flops, cmap = get_model(args.stream, mid, crops,
                                              labels, args.duration,
                                              steps=args.steps, Ls=args.ls)
        models[mid] = (apply_fn, acc_flops)
        cmaps[mid] = cmap
    evals = sweep(crops, frames, labels, models, Ks=[1, 2, 4], Ts=[0.5, 0.8],
                  gt_flops=GT_FLOPS, class_maps=cmaps, max_clusters=2048)
    choice = select(evals, args.policy) or max(
        evals, key=lambda e: (e.recall, e.precision))
    print(f"[serve] policy={args.policy} -> model={choice.candidate.model_id}"
          f" K={choice.candidate.K} T={choice.candidate.T} "
          f"(P={choice.precision:.3f} R={choice.recall:.3f})")

    # ingest with the chosen config
    mid = choice.candidate.model_id
    t0 = time.perf_counter()
    index, stats = ingest(crops, frames, models[mid][0], models[mid][1],
                          IngestConfig(K=choice.candidate.K,
                                       threshold=choice.candidate.T,
                                       max_clusters=2048),
                          class_map=cmaps[mid])
    print(f"[serve] ingest: {index.n_clusters} clusters / "
          f"{index.n_objects} objects in {time.perf_counter()-t0:.1f}s "
          f"(GPU-cost {gpu_seconds(stats.cheap_flops):.1f} GPU-s vs "
          f"Ingest-all {gpu_seconds(len(crops)*GT_FLOPS):.1f} GPU-s)")
    if args.index_out:
        index.save(args.index_out)
        print(f"[serve] index persisted to {args.index_out}.(json|npz)")

    # serve the dominant-class workload through the batched engine: one
    # union + one GT-CNN pass for the whole concurrent batch, centroid
    # verdicts cached across repeated rounds (steady-state query traffic)
    engine = QueryEngine(index, gt_apply=gt_oracle(labels),
                         gt_flops_per_image=GT_FLOPS)
    gtf = gt_frames_by_class(labels, frames)
    workload = [int(x) for x in dominant_classes(labels)]
    ps, rs = [], []
    last = None
    for rnd in range(max(args.rounds, 1)):
        results, batch = engine.query_many(workload)
        last = batch
        qps = batch.n_queries / max(batch.wall_s, 1e-9)
        print(f"[serve] round {rnd}: {batch.n_queries} queries in "
              f"{batch.wall_s*1e3:.0f}ms ({qps:.1f} QPS) | candidates "
              f"{batch.n_candidates} -> {batch.n_unique_candidates} unique, "
              f"{batch.n_cache_hits} cached, {batch.n_gt_invocations} "
              f"GT-CNN calls ({gpu_seconds(batch.gt_flops)*1e3:.1f} GPU-ms "
              f"vs Query-all "
              f"{gpu_seconds(len(crops)*GT_FLOPS)*1e3:.1f} GPU-ms)")
        if rnd > 0:
            continue                  # accuracy identical across rounds
        for x, res in zip(workload, results):
            p, r = precision_recall(res.frames, gtf.get(x, np.array([])))
            ps.append(p)
            rs.append(r)
            print(f"  query class={x:4d}: {len(res.frames):5d} frames, "
                  f"{res.n_candidate_clusters:4d} candidates, "
                  f"{res.n_gt_invocations:4d} fresh GT-CNN calls "
                  f"P={p:.3f} R={r:.3f} wall={res.wall_s*1e3:.1f}ms")
    print(f"[serve] avg P={np.mean(ps):.3f} R={np.mean(rs):.3f} | last "
          f"round {last.wall_s*1e3:.1f}ms "
          f"({last.n_queries / max(last.wall_s, 1e-9):.1f} QPS, "
          f"{last.wall_s / max(last.n_queries, 1) * 1e3:.2f}ms/query amortized)"
          f" | lifetime GT calls {engine.stats.n_gt_invocations} for "
          f"{engine.stats.n_candidates} served candidates")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
