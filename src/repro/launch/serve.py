"""Multi-tenant Focus serving driver (the paper's deployment shape, §5).

Per stream: sample -> GT-label -> specialize cheap CNN -> parameter
selection (§4.4) -> ingest (index+clusters) -> serve queries. Queries are
served through a ``repro.serve.QueryService``: ``--tenants`` concurrent
tenants submit their class workloads into a bounded request queue, a
continuous batcher merges every in-flight request into ONE
``query_many`` / GT pass per cycle (answers byte-identical to serving
each request alone), and per-tenant latency SLOs (p50/p99, deadline
misses vs ``--slo-ms``) are reported at the end, against the Ingest-all /
Query-all cost baselines.

  PYTHONPATH=src python -m repro.launch.serve --stream lausanne \
      --policy balance --duration 60 --tenants 4

With ``--stream-chunks N`` the ingest runs *streaming*: the stream's
chunks are offered to the service, which arbitrates the device between
ingest and the tenants' queries per ``--service-policy`` — ``query``
protects query SLOs (chunks wait in a bounded backlog, shedding the
oldest on overflow per ``--ingest-backlog``), ``ingest`` runs chunks
first and lets admission control shed query overflow instead. Every
chunk that ingests is prefetched into the GT-label cache, so warm
queries between chunks stay off the GT-CNN path. The final index is
identical to a one-shot run at the same batch size whenever no chunk was
shed (chunking itself never changes the result — only the batch size
does).

With ``--archive DIR`` the ingest additionally rolls the live index over
into time shards (``--shard-objects`` each) sealed under DIR, and the
service queries through an ``ArchiveQueryEngine``: merged batches fan
out across every sealed shard plus the live one, with a single GT-CNN
pass over the uncached candidates of all shards — warm rounds survive
shard rollovers untouched.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.archive import ArchiveQueryEngine, ShardCatalog
from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig, ingest
from repro.core.params import select, sweep
from repro.core.query import (dominant_classes, gpu_seconds,
                              gt_frames_by_class, precision_recall)
from repro.core.streaming import StreamingIngestor
from repro.data import get_stream
from repro.serve import QueryService, ServiceConfig


def _mk_service(engine, args, ingestor=None) -> QueryService:
    cfg = ServiceConfig(
        max_queue_depth=args.queue_depth,
        max_batch_requests=args.batch_requests,
        policy=args.service_policy,
        max_ingest_backlog=(args.ingest_backlog
                            if args.ingest_backlog > 0 else None),
        default_deadline_s=(args.slo_ms / 1e3 if args.slo_ms > 0 else None))
    return QueryService(engine, cfg, ingestor=ingestor)


def _serve_round(service: QueryService, n_tenants: int, workload):
    """Submit one request per tenant — the shared dominant-class workload,
    rotated per tenant so the overlap the batcher dedupes is explicit —
    and pump the service idle. Returns (responses by tenant, wall_s)."""
    t0 = time.perf_counter()
    for t in range(n_tenants):
        rot = t % max(len(workload), 1)
        service.submit(f"tenant{t}",
                       list(workload[rot:]) + list(workload[:rot]))
    by_tenant = {}
    for resp in service.run_until_idle():
        by_tenant[resp.request.tenant] = resp
    return by_tenant, time.perf_counter() - t0


def _round_line(tag, service, by_tenant, wall, gt_delta):
    n_req = len(by_tenant)
    n_cls = sum(len(r.results) for r in by_tenant.values())
    qps = n_cls / max(wall, 1e-9)
    batch = service.last_batch
    merged = (f"{batch.n_unique_candidates} unique candidates, "
              f"{batch.n_cache_hits} cached"
              if batch is not None and n_req else "no batch ran")
    print(f"[serve] {tag}: {n_req} tenants x {max(n_cls // max(n_req, 1), 0)}"
          f" classes in {wall*1e3:.0f}ms ({qps:.1f} QPS) | "
          f"{service.stats.n_shared_queries} shared pairs lifetime | "
          f"{merged}, {gt_delta} GT-CNN calls | p99 "
          f"{service.slo.percentile_s(99.0)*1e3:.1f}ms")


def _mesh_pipeline_handle(args, apply_fn, cfg):
    """``--mesh-devices N``: route ingest through the sharded megastep
    over a ``make_ingest_mesh(N)`` mesh (DESIGN.md §13). The serve driver
    ingests one stream, so the placement is a single slot; the same
    pipeline stacks many streams in ``core.streaming.make_sharded_runner``.
    Returns the slot handle to pass as ``StreamingIngestor(pipeline=)``,
    or None when meshing is off."""
    if args.mesh_devices <= 0:
        return None
    traceable = getattr(apply_fn, "traceable", None)
    if traceable is None:
        raise SystemExit(
            "--mesh-devices needs a jax-traceable model forward "
            "(apply_fn.traceable); the selected model only exposes a "
            "host-staged apply")
    from repro.core.pipeline import ShardedIngestPipeline
    from repro.core.streaming import StreamPlacement
    from repro.launch.mesh import make_ingest_mesh
    mesh = make_ingest_mesh(args.mesh_devices)
    placement = StreamPlacement([args.stream], mesh.size)
    shared = ShardedIngestPipeline(traceable, mesh, placement.slots,
                                   cfg=cfg)
    return shared.handle(args.stream)


def _mk_ingestor(apply_fn, acc_flops, cfg, args, **kw):
    handle = _mesh_pipeline_handle(args, apply_fn, cfg)
    if handle is not None:
        return StreamingIngestor(None, acc_flops, cfg, pipeline=handle,
                                 **kw)
    return StreamingIngestor(apply_fn, acc_flops, cfg, **kw)


def _streaming_ingest(crops, frames, apply_fn, acc_flops, cfg, class_map,
                      workload, gt_apply, gt_flops, n_chunks, args):
    """Offer the stream's chunks to the service while tenants query
    between chunks from the live, still-growing index (query-while-
    ingest). Returns (index, stats, engine, service) — the engine's
    GT-label cache stays warm for the post-ingest query rounds.
    """
    ing = _mk_ingestor(apply_fn, acc_flops, cfg, args, class_map=class_map)
    engine = service = None
    bounds = np.linspace(0, len(crops), n_chunks + 1).astype(int)
    for rnd, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        if service is None and ing.index is not None:
            engine = QueryEngine(ing.index, gt_apply=gt_apply,
                                 gt_flops_per_image=gt_flops)
            service = _mk_service(engine, args, ingestor=ing)
        if service is None:
            ing.feed(crops[lo:hi], frames[lo:hi])    # class width unknown
            ing.flush()
            continue
        service.offer_ingest(crops[lo:hi], frames[lo:hi])
        gt0 = engine.stats.n_gt_invocations
        chunks0 = service.stats.n_ingest_chunks
        by_tenant, wall = _serve_round(service, args.tenants, workload)
        print(f"[serve] chunk {rnd}: +{hi - lo} objs offered "
              f"({service.stats.n_ingest_chunks - chunks0} ingested, "
              f"{service.pending_ingest} deferred, "
              f"{service.stats.n_ingest_shed_chunks} shed lifetime) | "
              f"{service.stats.n_prefetch_gt} prefetched GT lifetime")
        _round_line(f"chunk {rnd}", service, by_tenant, wall,
                    engine.stats.n_gt_invocations - gt0)
    if service is not None:
        service.drain_ingest()
    index, stats = ing.finish()
    if engine is not None:
        engine.prefetch(ing.flush().touched_cids)
    return index, stats, engine, service


def _archive_ingest(crops, frames, apply_fn, acc_flops, cfg, class_map,
                    workload, gt_apply, gt_flops, n_chunks, args):
    """Streaming ingest with shard rollover; merged tenant batches fan out
    across sealed shards + the live index through an
    ``ArchiveQueryEngine``. Returns (catalog, stats, engine, service)."""
    catalog = ShardCatalog.open(args.archive)
    ing = _mk_ingestor(apply_fn, acc_flops, cfg, args, class_map=class_map,
                       catalog=catalog, shard_objects=args.shard_objects)
    cache_kw = ({"capacity": args.shard_cache} if args.shard_cache > 0
                else {"capacity_bytes": args.shard_cache_mb << 20})
    engine = ArchiveQueryEngine(catalog, gt_apply=gt_apply,
                                gt_flops_per_image=gt_flops,
                                ingestor=ing, **cache_kw)
    service = _mk_service(engine, args, ingestor=ing)
    bounds = np.linspace(0, len(crops), n_chunks + 1).astype(int)
    for rnd, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        service.offer_ingest(crops[lo:hi], frames[lo:hi])
        gt0 = engine.stats.n_gt_invocations
        by_tenant, wall = _serve_round(service, args.tenants, workload)
        batch = service.last_batch
        shards = (f"{batch.n_shards} shards, {batch.n_shard_loads} loads"
                  if batch is not None else "no batch")
        print(f"[serve] chunk {rnd}: +{hi - lo} objs offered | "
              f"{len(catalog)} shards sealed ({shards}) | "
              f"{service.stats.n_prefetch_gt} prefetched GT lifetime")
        _round_line(f"chunk {rnd}", service, by_tenant, wall,
                    engine.stats.n_gt_invocations - gt0)
    service.drain_ingest()
    ing.finish()
    engine.prefetch(ing.flush())
    return catalog, ing.stats, engine, service


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stream", default="lausanne")
    ap.add_argument("--policy", default="balance",
                    choices=["balance", "opt_ingest", "opt_query"])
    ap.add_argument("--duration", type=int, default=60)
    ap.add_argument("--fps", type=int, default=10)
    ap.add_argument("--ls", type=int, default=6)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--rounds", type=int, default=3,
                    help="query-workload rounds (round 1 is cold, the rest "
                         "exercise the warm GT-label cache)")
    ap.add_argument("--tenants", type=int, default=4,
                    help="concurrent tenants submitting the query workload "
                         "each round")
    ap.add_argument("--service-policy", default="query",
                    choices=["query", "ingest"],
                    help="backpressure policy when ingest and queries "
                         "contend: 'query' defers/sheds ingest chunks, "
                         "'ingest' runs chunks first and sheds query "
                         "overflow via admission control")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-request latency SLO deadline in ms "
                         "(0 = no deadline accounting)")
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="admission bound on queued requests")
    ap.add_argument("--batch-requests", type=int, default=32,
                    help="max requests merged into one batch cycle")
    ap.add_argument("--ingest-backlog", type=int, default=0,
                    help="max deferred ingest chunks before the oldest is "
                         "shed (0 = unbounded, never shed)")
    ap.add_argument("--stream-chunks", type=int, default=0,
                    help="feed the stream in N chunks and serve the query "
                         "workload between chunks (query-while-ingest); "
                         "0 = one-shot ingest")
    ap.add_argument("--archive", default=None, metavar="DIR",
                    help="time-sharded archive mode: seal shards into DIR "
                         "during ingest and serve queries through the "
                         "cross-shard ArchiveQueryEngine")
    ap.add_argument("--shard-objects", type=int, default=2048,
                    help="archive mode: objects per sealed shard")
    ap.add_argument("--shard-cache", type=int, default=0,
                    help="archive mode: LRU capacity in resident shard "
                         "COUNT (deprecated bound; 0 = use --shard-cache-mb)")
    ap.add_argument("--shard-cache-mb", type=int, default=256,
                    help="archive mode: LRU capacity in MiB of resident "
                         "shard heap state (ignored when --shard-cache > 0)")
    ap.add_argument("--mesh-devices", type=int, default=0,
                    help="shard streaming/archive ingest over a 1-D "
                         "('data',) mesh of N devices via the fused "
                         "sharded megastep (0 = host-staged ingest); on "
                         "CPU export XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N before launch")
    ap.add_argument("--index-out", default=None)
    args = ap.parse_args()
    if args.mesh_devices > 0 and not (args.archive
                                      or args.stream_chunks > 0):
        raise SystemExit("--mesh-devices needs a streaming ingest path: "
                         "pass --stream-chunks N and/or --archive DIR")

    from benchmarks.common import (GT_FLOPS, SPECIALIZED_FAMILY, get_model,
                                   gt_oracle)

    vs = get_stream(args.stream, duration_s=args.duration, fps=args.fps)
    crops, frames, tracks, labels = vs.objects_array()
    print(f"[serve] stream={args.stream} objects={len(crops)} "
          f"classes={len(np.unique(labels))}")

    # §4.4 parameter selection over the specialized family
    models, cmaps = {}, {}
    for mid in SPECIALIZED_FAMILY:
        apply_fn, acc_flops, cmap = get_model(args.stream, mid, crops,
                                              labels, args.duration,
                                              steps=args.steps, Ls=args.ls)
        models[mid] = (apply_fn, acc_flops)
        cmaps[mid] = cmap
    evals = sweep(crops, frames, labels, models, Ks=[1, 2, 4], Ts=[0.5, 0.8],
                  gt_flops=GT_FLOPS, class_maps=cmaps, max_clusters=2048)
    choice = select(evals, args.policy) or max(
        evals, key=lambda e: (e.recall, e.precision))
    print(f"[serve] policy={args.policy} -> model={choice.candidate.model_id}"
          f" K={choice.candidate.K} T={choice.candidate.T} "
          f"(P={choice.precision:.3f} R={choice.recall:.3f})")

    # ingest with the chosen config
    mid = choice.candidate.model_id
    gtf_apply = gt_oracle(labels)
    workload = [int(x) for x in dominant_classes(labels)]
    cfg = IngestConfig(K=choice.candidate.K, threshold=choice.candidate.T,
                       max_clusters=2048)
    t0 = time.perf_counter()
    engine = service = None
    index = None
    if args.archive or args.stream_chunks > 0:
        # freshness scales with the CNN batch cut: size batches to the
        # chunk so each round actually publishes (the partition is still a
        # function of the stream alone, not of the chunking)
        import dataclasses
        n_chunks = args.stream_chunks if args.stream_chunks > 0 else 8
        chunk = max(1, -(-len(crops) // n_chunks))
        cfg = dataclasses.replace(cfg,
                                  batch_size=max(16, min(cfg.batch_size,
                                                         chunk)))
    if args.archive:
        catalog, stats, engine, service = _archive_ingest(
            crops, frames, models[mid][0], models[mid][1], cfg, cmaps[mid],
            workload, gtf_apply, GT_FLOPS, n_chunks, args)
        print(f"[serve] archive: {len(catalog)} shards "
              f"({sum(m.n_clusters for m in catalog)} clusters / "
              f"{sum(m.n_objects for m in catalog)} objects) sealed under "
              f"{args.archive} in {stats.wall_s:.1f}s "
              f"(GPU-cost {gpu_seconds(stats.cheap_flops):.1f} GPU-s vs "
              f"Ingest-all {gpu_seconds(len(crops)*GT_FLOPS):.1f} GPU-s)")
    elif args.stream_chunks > 0:
        index, stats, engine, service = _streaming_ingest(
            crops, frames, models[mid][0], models[mid][1], cfg, cmaps[mid],
            workload, gtf_apply, GT_FLOPS, args.stream_chunks, args)
    else:
        index, stats = ingest(crops, frames, models[mid][0], models[mid][1],
                              cfg, class_map=cmaps[mid])
    if index is not None:
        # streaming mode: elapsed time includes the interleaved query
        # rounds, so report the ingestor's own accounted wall instead
        ingest_s = (stats.wall_s if args.stream_chunks > 0
                    else time.perf_counter() - t0)
        print(f"[serve] ingest: {index.n_clusters} clusters / "
              f"{index.n_objects} objects in {ingest_s:.1f}s "
              f"(GPU-cost {gpu_seconds(stats.cheap_flops):.1f} GPU-s vs "
              f"Ingest-all {gpu_seconds(len(crops)*GT_FLOPS):.1f} GPU-s)")
    if args.index_out:
        if index is None:
            print("[serve] --index-out ignored: archive shards are already "
                  "persisted through the catalog")
        else:
            index.save(args.index_out)
            print(f"[serve] index persisted to {args.index_out}.(json|npz)")

    # steady-state traffic: every round, all tenants submit the dominant-
    # class workload; the service merges each round's in-flight requests
    # into one union + one GT-CNN pass, centroid verdicts cached across
    # rounds. In streaming mode the chunk rounds' service carries its warm
    # GT-label cache straight into these rounds.
    if engine is None:
        engine = QueryEngine(index, gt_apply=gtf_apply,
                             gt_flops_per_image=GT_FLOPS)
    if service is None:
        service = _mk_service(engine, args)
    gtf = gt_frames_by_class(labels, frames)
    ps, rs = [], []
    last_wall = last_ncls = None
    for rnd in range(max(args.rounds, 1)):
        gt0 = engine.stats.n_gt_invocations
        by_tenant, wall = _serve_round(service, args.tenants, workload)
        if not by_tenant:
            continue
        last_wall = wall
        last_ncls = sum(len(r.results) for r in by_tenant.values())
        _round_line(f"round {rnd}", service, by_tenant, wall,
                    engine.stats.n_gt_invocations - gt0)
        if rnd > 0:
            continue                  # accuracy identical across rounds
        resp0 = by_tenant.get("tenant0")
        if resp0 is None:
            continue
        for x, res in zip(workload, resp0.results):
            p, r = precision_recall(res.frames, gtf.get(x, np.array([])))
            ps.append(p)
            rs.append(r)
            print(f"  query class={x:4d}: {len(res.frames):5d} frames, "
                  f"{res.n_candidate_clusters:4d} candidates, "
                  f"{res.n_gt_invocations:4d} fresh GT-CNN calls "
                  f"P={p:.3f} R={r:.3f}")

    # summary — guarded: an empty dominant-class workload (or a stream
    # with no surviving objects) serves zero queries and must not push
    # np.mean through an empty list (NaN + RuntimeWarning)
    if not ps or last_wall is None or not last_ncls:
        print("[serve] no queries served (empty dominant-class workload "
              "or no surviving objects)")
    else:
        print(f"[serve] avg P={np.mean(ps):.3f} R={np.mean(rs):.3f} | last "
              f"round {last_wall*1e3:.1f}ms "
              f"({last_ncls / max(last_wall, 1e-9):.1f} QPS, "
              f"{last_wall / last_ncls * 1e3:.2f}ms/query amortized) | "
              f"lifetime GT calls {engine.stats.n_gt_invocations} for "
              f"{engine.stats.n_candidates} served candidates")
    svc = service.stats
    print(f"[serve] service: {svc.n_completed} requests "
          f"({svc.n_rejected} rejected) in {svc.n_merged_calls} merged "
          f"calls | {svc.n_merged_queries} unique pairs, "
          f"{svc.n_shared_queries} shared | ingest {svc.n_ingest_chunks} "
          f"chunks ({svc.n_ingest_deferred} chunk-cycles deferred, "
          f"{svc.n_ingest_shed_chunks} shed)")
    for ts in service.slo:
        p50 = f"{ts.p50_s*1e3:.1f}" if ts.latencies_s else "-"
        p99 = f"{ts.p99_s*1e3:.1f}" if ts.latencies_s else "-"
        print(f"  {ts.tenant}: {ts.n_completed}/{ts.n_submitted} served "
              f"p50={p50}ms p99={p99}ms deadline_missed="
              f"{ts.n_deadline_missed} rejected={ts.n_rejected}")
    if args.archive:
        st = engine.stats
        print(f"[serve] shard cache: {st.resident_bytes / 2**20:.2f} MiB "
              f"resident | {st.n_shard_loads} loads, {st.n_shard_hits} "
              f"hits ({st.shard_hit_rate:.0%}), {st.n_shard_evictions} "
              f"evictions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
