"""End-to-end Focus serving driver (the paper's deployment shape, §5).

Pipeline per stream: sample -> GT-label -> specialize cheap CNN ->
parameter selection (§4.4) -> ingest (index+clusters) -> serve queries.
Query workers batch centroid classifications; per-query latency and cost
are reported against the Ingest-all / Query-all baselines.

  PYTHONPATH=src python -m repro.launch.serve --stream lausanne \
      --policy balance --duration 60

With ``--stream-chunks N`` the ingest runs *streaming*: the stream is fed
in N chunks through a ``StreamingIngestor`` and the query workload is
served between chunks from the live, still-growing index
(query-while-ingest) — each round reports freshness latency and warm-cache
hit rates. The CNN batch size is scaled down to the chunk so every round
publishes; the final index is identical to a one-shot run at that same
batch size (chunking itself never changes the result — only the batch
size does).

With ``--archive DIR`` the ingest additionally rolls the live index over
into time shards (``--shard-objects`` each) sealed under DIR, and the
query workload is served through an ``ArchiveQueryEngine``: per-round
queries fan out across every sealed shard plus the live one, with a
single GT-CNN pass over the uncached candidates of all shards — warm
rounds survive shard rollovers untouched.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.archive import ArchiveQueryEngine, ShardCatalog
from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig, ingest
from repro.core.params import select, sweep
from repro.core.query import (dominant_classes, gpu_seconds,
                              gt_frames_by_class, precision_recall)
from repro.core.streaming import StreamingIngestor
from repro.data import get_stream


def _streaming_ingest(crops, frames, apply_fn, acc_flops, cfg, class_map,
                      workload, gt_apply, gt_flops, n_chunks):
    """Feed the stream in chunks, serving the query workload between
    chunks from the live index. Returns (index, stats, warm engine) — the
    engine's GT-label cache stays valid for the post-ingest query rounds.
    """
    ing = StreamingIngestor(apply_fn, acc_flops, cfg, class_map=class_map)
    engine = None
    bounds = np.linspace(0, len(crops), n_chunks + 1).astype(int)
    for rnd, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        t0 = time.perf_counter()
        ing.feed(crops[lo:hi], frames[lo:hi])
        feed_ms = (time.perf_counter() - t0) * 1e3
        # freshness = flush + prefetch + warm queries (ingest excluded,
        # matching benchmarks/streaming_bench.py)
        t1 = time.perf_counter()
        delta = ing.flush()
        if ing.index is None:
            continue                       # class width not yet known
        if engine is None:
            engine = QueryEngine(ing.index, gt_apply=gt_apply,
                                 gt_flops_per_image=gt_flops)
        fresh_gt = engine.prefetch(delta.touched_cids)
        results, batch = engine.query_many(workload)
        fresh_ms = (time.perf_counter() - t1) * 1e3
        frames_seen = int(sum(len(r.frames) for r in results))
        print(f"[serve] chunk {rnd}: +{hi - lo} objs in {feed_ms:.0f}ms "
              f"({delta.n_objects_published} published, "
              f"{delta.n_pending_unique} buffered) | "
              f"{len(delta.touched_cids)} clusters touched, "
              f"{fresh_gt} prefetched GT | {batch.n_queries} queries warm "
              f"({batch.n_cache_hits}/{batch.n_unique_candidates} cached, "
              f"{frames_seen} frames) | freshness {fresh_ms:.0f}ms")
    index, stats = ing.finish()
    if engine is not None:
        engine.prefetch(ing.flush().touched_cids)
    return index, stats, engine


def _archive_ingest(crops, frames, apply_fn, acc_flops, cfg, class_map,
                    workload, gt_apply, gt_flops, n_chunks, archive_dir,
                    shard_objects, shard_cache):
    """Feed the stream in chunks with shard rollover, serving the query
    workload between chunks through an ``ArchiveQueryEngine`` that spans
    the sealed shards and the live index. Returns (catalog, stats, engine).
    """
    catalog = ShardCatalog.open(archive_dir)
    ing = StreamingIngestor(apply_fn, acc_flops, cfg, class_map=class_map,
                            catalog=catalog, shard_objects=shard_objects)
    engine = ArchiveQueryEngine(catalog, gt_apply=gt_apply,
                                gt_flops_per_image=gt_flops,
                                capacity=shard_cache, ingestor=ing)
    bounds = np.linspace(0, len(crops), n_chunks + 1).astype(int)
    for rnd, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        t0 = time.perf_counter()
        ing.feed(crops[lo:hi], frames[lo:hi])
        feed_ms = (time.perf_counter() - t0) * 1e3
        t1 = time.perf_counter()
        delta = ing.flush()
        fresh_gt = engine.prefetch(delta)
        results, batch = engine.query_many(workload)
        fresh_ms = (time.perf_counter() - t1) * 1e3
        frames_seen = int(sum(len(r.frames) for r in results))
        print(f"[serve] chunk {rnd}: +{hi - lo} objs in {feed_ms:.0f}ms | "
              f"{len(delta.sealed_shards)} shards sealed "
              f"({len(catalog)} total), {fresh_gt} prefetched GT | "
              f"{batch.n_queries} queries over {batch.n_shards} shards "
              f"({batch.n_cache_hits}/{batch.n_unique_candidates} cached, "
              f"{batch.n_shard_loads} shard loads, {frames_seen} frames) | "
              f"freshness {fresh_ms:.0f}ms")
    ing.finish()
    engine.prefetch(ing.flush())
    return catalog, ing.stats, engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stream", default="lausanne")
    ap.add_argument("--policy", default="balance",
                    choices=["balance", "opt_ingest", "opt_query"])
    ap.add_argument("--duration", type=int, default=60)
    ap.add_argument("--fps", type=int, default=10)
    ap.add_argument("--ls", type=int, default=6)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--rounds", type=int, default=3,
                    help="query-workload rounds (round 1 is cold, the rest "
                         "exercise the warm GT-label cache)")
    ap.add_argument("--stream-chunks", type=int, default=0,
                    help="feed the stream in N chunks and serve the query "
                         "workload between chunks (query-while-ingest); "
                         "0 = one-shot ingest")
    ap.add_argument("--archive", default=None, metavar="DIR",
                    help="time-sharded archive mode: seal shards into DIR "
                         "during ingest and serve queries through the "
                         "cross-shard ArchiveQueryEngine")
    ap.add_argument("--shard-objects", type=int, default=2048,
                    help="archive mode: objects per sealed shard")
    ap.add_argument("--shard-cache", type=int, default=4,
                    help="archive mode: LRU capacity of resident shards")
    ap.add_argument("--index-out", default=None)
    args = ap.parse_args()

    from benchmarks.common import (GT_FLOPS, SPECIALIZED_FAMILY, get_model,
                                   gt_oracle)

    vs = get_stream(args.stream, duration_s=args.duration, fps=args.fps)
    crops, frames, tracks, labels = vs.objects_array()
    print(f"[serve] stream={args.stream} objects={len(crops)} "
          f"classes={len(np.unique(labels))}")

    # §4.4 parameter selection over the specialized family
    models, cmaps = {}, {}
    for mid in SPECIALIZED_FAMILY:
        apply_fn, acc_flops, cmap = get_model(args.stream, mid, crops,
                                              labels, args.duration,
                                              steps=args.steps, Ls=args.ls)
        models[mid] = (apply_fn, acc_flops)
        cmaps[mid] = cmap
    evals = sweep(crops, frames, labels, models, Ks=[1, 2, 4], Ts=[0.5, 0.8],
                  gt_flops=GT_FLOPS, class_maps=cmaps, max_clusters=2048)
    choice = select(evals, args.policy) or max(
        evals, key=lambda e: (e.recall, e.precision))
    print(f"[serve] policy={args.policy} -> model={choice.candidate.model_id}"
          f" K={choice.candidate.K} T={choice.candidate.T} "
          f"(P={choice.precision:.3f} R={choice.recall:.3f})")

    # ingest with the chosen config
    mid = choice.candidate.model_id
    gtf_apply = gt_oracle(labels)
    workload = [int(x) for x in dominant_classes(labels)]
    cfg = IngestConfig(K=choice.candidate.K, threshold=choice.candidate.T,
                       max_clusters=2048)
    t0 = time.perf_counter()
    engine = None
    index = None
    if args.archive or args.stream_chunks > 0:
        # freshness scales with the CNN batch cut: size batches to the
        # chunk so each round actually publishes (the partition is still a
        # function of the stream alone, not of the chunking)
        import dataclasses
        n_chunks = args.stream_chunks if args.stream_chunks > 0 else 8
        chunk = max(1, -(-len(crops) // n_chunks))
        cfg = dataclasses.replace(cfg,
                                  batch_size=max(16, min(cfg.batch_size,
                                                         chunk)))
    if args.archive:
        catalog, stats, engine = _archive_ingest(
            crops, frames, models[mid][0], models[mid][1], cfg, cmaps[mid],
            workload, gtf_apply, GT_FLOPS, n_chunks, args.archive,
            args.shard_objects, args.shard_cache)
        print(f"[serve] archive: {len(catalog)} shards "
              f"({sum(m.n_clusters for m in catalog)} clusters / "
              f"{sum(m.n_objects for m in catalog)} objects) sealed under "
              f"{args.archive} in {stats.wall_s:.1f}s "
              f"(GPU-cost {gpu_seconds(stats.cheap_flops):.1f} GPU-s vs "
              f"Ingest-all {gpu_seconds(len(crops)*GT_FLOPS):.1f} GPU-s)")
    elif args.stream_chunks > 0:
        index, stats, engine = _streaming_ingest(
            crops, frames, models[mid][0], models[mid][1], cfg, cmaps[mid],
            workload, gtf_apply, GT_FLOPS, args.stream_chunks)
    else:
        index, stats = ingest(crops, frames, models[mid][0], models[mid][1],
                              cfg, class_map=cmaps[mid])
    if index is not None:
        # streaming mode: elapsed time includes the interleaved query
        # rounds, so report the ingestor's own accounted wall instead
        ingest_s = (stats.wall_s if args.stream_chunks > 0
                    else time.perf_counter() - t0)
        print(f"[serve] ingest: {index.n_clusters} clusters / "
              f"{index.n_objects} objects in {ingest_s:.1f}s "
              f"(GPU-cost {gpu_seconds(stats.cheap_flops):.1f} GPU-s vs "
              f"Ingest-all {gpu_seconds(len(crops)*GT_FLOPS):.1f} GPU-s)")
    if args.index_out:
        if index is None:
            print("[serve] --index-out ignored: archive shards are already "
                  "persisted through the catalog")
        else:
            index.save(args.index_out)
            print(f"[serve] index persisted to {args.index_out}.(json|npz)")

    # serve the dominant-class workload through the batched engine: one
    # union + one GT-CNN pass for the whole concurrent batch, centroid
    # verdicts cached across repeated rounds (steady-state query traffic).
    # In streaming mode the interleaved rounds' engine carries its warm
    # GT-label cache straight into these rounds.
    if engine is None:
        engine = QueryEngine(index, gt_apply=gtf_apply,
                             gt_flops_per_image=GT_FLOPS)
    gtf = gt_frames_by_class(labels, frames)
    ps, rs = [], []
    last = None
    for rnd in range(max(args.rounds, 1)):
        results, batch = engine.query_many(workload)
        last = batch
        qps = batch.n_queries / max(batch.wall_s, 1e-9)
        print(f"[serve] round {rnd}: {batch.n_queries} queries in "
              f"{batch.wall_s*1e3:.0f}ms ({qps:.1f} QPS) | candidates "
              f"{batch.n_candidates} -> {batch.n_unique_candidates} unique, "
              f"{batch.n_cache_hits} cached, {batch.n_gt_invocations} "
              f"GT-CNN calls ({gpu_seconds(batch.gt_flops)*1e3:.1f} GPU-ms "
              f"vs Query-all "
              f"{gpu_seconds(len(crops)*GT_FLOPS)*1e3:.1f} GPU-ms)")
        if rnd > 0:
            continue                  # accuracy identical across rounds
        for x, res in zip(workload, results):
            p, r = precision_recall(res.frames, gtf.get(x, np.array([])))
            ps.append(p)
            rs.append(r)
            print(f"  query class={x:4d}: {len(res.frames):5d} frames, "
                  f"{res.n_candidate_clusters:4d} candidates, "
                  f"{res.n_gt_invocations:4d} fresh GT-CNN calls "
                  f"P={p:.3f} R={r:.3f} wall={res.wall_s*1e3:.1f}ms")
    print(f"[serve] avg P={np.mean(ps):.3f} R={np.mean(rs):.3f} | last "
          f"round {last.wall_s*1e3:.1f}ms "
          f"({last.n_queries / max(last.wall_s, 1e-9):.1f} QPS, "
          f"{last.wall_s / max(last.n_queries, 1) * 1e3:.2f}ms/query amortized)"
          f" | lifetime GT calls {engine.stats.n_gt_invocations} for "
          f"{engine.stats.n_candidates} served candidates")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
