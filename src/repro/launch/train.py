"""End-to-end training driver.

Trains any assigned arch (reduced or full config) on synthetic data with the
full substrate: AdamW, schedules, grad accumulation, checkpoint/restart,
preemption handling. On this CPU container use --reduced; on a pod the same
driver runs the full config over make_production_mesh().

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import (DiTConfig, EffNetConfig, LMConfig,
                                 ViTConfig, reduced)
from repro.configs import ARCH_IDS, get_arch
from repro.models import dit, efficientnet, transformer, vit
from repro.train import (CheckpointManager, OptConfig, TrainConfig, train)


def lm_data(cfg, batch, seq, seed=0):
    r = np.random.default_rng(seed)
    # synthetic LM task: noisy copy (learnable quickly, loss visibly drops)
    while True:
        toks = r.integers(0, cfg.vocab_size, (batch, seq))
        labels = np.roll(toks, -1, axis=1)
        yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def vit_data(cfg, batch, seed=0):
    from repro.data.video import _class_proto
    r = np.random.default_rng(seed)
    protos = np.stack([_class_proto(c, cfg.img_res)
                       for c in range(cfg.n_classes)])
    while True:
        y = r.integers(0, cfg.n_classes, batch)
        x = protos[y] + r.normal(0, 0.1, (batch, cfg.img_res, cfg.img_res, 3))
        yield {"images": jnp.asarray(x, jnp.float32), "labels": jnp.asarray(y)}


def dit_data(cfg, batch, seed=0):
    r = np.random.default_rng(seed)
    res = cfg.img_res // cfg.vae_factor
    while True:
        yield {"latents": jnp.asarray(
                   r.normal(0, 1, (batch, res, res, cfg.latent_channels)),
                   jnp.float32),
               "labels": jnp.asarray(r.integers(0, cfg.n_classes, batch))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    rng = jax.random.PRNGKey(0)

    if isinstance(cfg, LMConfig):
        params = transformer.init(rng, cfg)
        data = lm_data(cfg, args.batch, args.seq)

        def loss_fn(p, batch, r):
            return transformer.loss_fn(p, batch["tokens"], batch["labels"],
                                       cfg)
    elif isinstance(cfg, ViTConfig):
        params = vit.init(rng, cfg)
        data = vit_data(cfg, args.batch)

        def loss_fn(p, batch, r):
            return vit.loss_fn(p, batch["images"], batch["labels"], cfg)
    elif isinstance(cfg, DiTConfig):
        params = dit.init(rng, cfg)
        data = dit_data(cfg, args.batch)

        def loss_fn(p, batch, r):
            return dit.loss_fn(p, batch["latents"], batch["labels"], r, cfg)
    elif isinstance(cfg, EffNetConfig):
        params_state = efficientnet.init(rng, cfg)
        params, state = params_state
        data = vit_data(cfg, args.batch)

        def loss_fn(p, batch, r):
            l, (m, _) = efficientnet.loss_fn(p, state, batch["images"],
                                             batch["labels"], cfg)
            return l, m
    else:
        raise SystemExit(f"unsupported {type(cfg)}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps={args.steps}")
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    ocfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, log_every=max(args.steps // 10, 1),
                       n_microbatches=args.microbatches,
                       compression=args.compression,
                       ckpt_every=args.ckpt_every)
    params, hist = train(loss_fn, params, data, ocfg, tcfg, ckpt=ckpt,
                         hooks=[lambda m: print(
                             f"  step {m['step']:5d} loss {m['loss']:.4f} "
                             f"({m['step_time_s']*1e3:.0f} ms/step)")])
    print(f"[train] final loss {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
