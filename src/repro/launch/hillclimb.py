import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb harness (§Perf methodology).

Re-lowers ONE (arch x shape) cell with config overrides and prints the
before/after roofline terms — the measurement step of the
hypothesis -> change -> measure -> validate loop.

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --cell olmo-1b:train_4k \
      --set act_sharding=dp train_microbatches=2 --tag no-sp
"""
import argparse
import json


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return k, v == "true"
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--set", nargs="*", default=[], dest="overrides")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="exp")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell

    arch, cell = args.cell.split(":")
    overrides = dict(parse_override(kv) for kv in args.overrides) or None
    rec = run_cell(arch, cell, args.multi_pod, variant=args.variant,
                   cfg_overrides=overrides)
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{arch}_{cell}_{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)

    if rec.get("ok") and not rec.get("skipped"):
        r = rec["roofline"]
        m = rec["memory"]
        print(f"cell={args.cell} overrides={overrides}")
        print(f"  compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s "
              f"collective={r['collective_s']:.3f}s dom={r['dominant']}")
        print(f"  bound_step={r['bound_step_s']:.3f}s "
              f"roofline_frac={rec['roofline_fraction']:.4f} "
              f"useful={rec['useful_flops_ratio']:.3f}")
        print(f"  mem={m['live_bytes_per_device']/1e9:.2f}GB "
              f"fits={m['fits_16gb_hbm']} compile={rec['compile_s']}s")
        print(f"  wire: " + ", ".join(
            f"{k}={v/1e9:.1f}GB"
            for k, v in rec["collectives"]["wire_bytes"].items() if v))
    else:
        print(json.dumps(rec, indent=1)[:2000])


if __name__ == "__main__":
    main()
