import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb harness (§Perf methodology).

Re-lowers ONE (arch x shape) cell with config overrides and prints the
before/after roofline terms — the measurement step of the
hypothesis -> change -> measure -> validate loop.

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --cell olmo-1b:train_4k \
      --set act_sharding=dp train_microbatches=2 --tag no-sp
"""
import argparse
import json


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return k, v == "true"
    return k, v


def gate_tune(n_frames: int = 240, objs_per_frame: int = 4,
              window_frames: int = 30, dup_rate: float = 0.9,
              seed: int = 0) -> dict:
    """Hillclimb the ingest gate: run the AdaptiveSampler against a
    static-camera synthetic stream, window by window, probing recall vs.
    ungated ingest at every step (the recall gate). Returns the stride /
    duplicate-rate / recall trajectory plus the final operating point.
    """
    import numpy as np

    from repro.core.ingest import IngestConfig, ingest
    from repro.core.params import AdaptiveSampler, SamplerConfig
    from repro.core.streaming import StreamingIngestor

    rng = np.random.default_rng(seed)
    n_classes, feat = 5, 16
    base = rng.random((8, 16, 16, 3)).astype(np.float32)

    def cheap(crops):
        b = len(crops)
        cls = (crops[:, 0, 0, 0] * n_classes).astype(int) % n_classes
        probs = np.eye(n_classes, dtype=np.float32)[cls] * 0.9 + 0.02
        feats = np.zeros((b, feat), np.float32)
        feats[np.arange(b), cls % feat] = 1.0
        return probs, feats

    crops, frames = [], []
    for f in range(n_frames):
        for k in rng.choice(len(base), objs_per_frame, replace=False):
            c = base[k]
            if rng.random() > dup_rate:      # fresh content, not a dup
                c = rng.random(c.shape).astype(np.float32)
            crops.append(c)
            frames.append(f)
    crops = np.stack(crops)
    frames = np.array(frames, np.int64)

    cfg = IngestConfig(K=3, batch_size=64, gate=True, gate_threshold=0.01)
    idx_un, _ = ingest(crops, frames, cheap, 1.0, cfg, n_local_classes=n_classes)

    def frames_by_class(idx):
        return {c: set(np.asarray(idx.frames_of(idx.lookup(c))).tolist())
                for c in range(n_classes)}

    ref = frames_by_class(idx_un)
    sampler = AdaptiveSampler(SamplerConfig())
    ing = StreamingIngestor(cheap, 1.0, cfg, n_local_classes=n_classes)
    steps = []
    for lo in range(0, n_frames, window_frames):
        sel = (frames >= lo) & (frames < lo + window_frames)
        before = (ing.stats.n_cnn_invocations, ing.stats.n_pixel_dedup,
                  ing.stats.n_gate_skipped, ing.stats.n_sampled_out)
        ing.feed(crops[sel], frames[sel])
        ing.flush()
        # recall probe vs ungated ingest, over everything fed so far
        got = frames_by_class(ing.index)
        hits = sum(len(got[c] & ref[c]) for c in range(n_classes))
        denom = sum(len({f for f in ref[c] if f < lo + window_frames})
                    for c in range(n_classes))
        recall = hits / denom if denom else 1.0
        ingested = ing.stats.n_cnn_invocations - before[0]
        # content redundancy only: gate + tracker skips among the objects
        # that survived the stride. Stride-filtered objects go in
        # separately (n_sampled_out) — folding them into the skip count
        # was the positive feedback loop that ratcheted the stride to
        # max_stride on its own signal (ISSUE 8 bugfix; see
        # AdaptiveSampler.observe).
        skipped = (ing.stats.n_pixel_dedup + ing.stats.n_gate_skipped
                   - before[1] - before[2])
        sampled_out = ing.stats.n_sampled_out - before[3]
        stride = sampler.observe(ingested, skipped, recall=recall,
                                 n_sampled_out=sampled_out)
        ing.set_frame_stride(stride)
        steps.append({"window_lo": lo, "stride": stride,
                      "ingested": int(ingested), "skipped": int(skipped),
                      "sampled_out": int(sampled_out),
                      "recall": round(recall, 4)})
    idx, stats = ing.finish()
    return {
        "mode": "gate_tune",
        "n_objects": int(stats.n_objects),
        "n_cnn_invocations": int(stats.n_cnn_invocations),
        "n_pixel_dedup": int(stats.n_pixel_dedup),
        "n_gate_skipped": int(stats.n_gate_skipped),
        "n_sampled_out": int(stats.n_sampled_out),
        "final_stride": sampler.stride,
        "steps": steps,
        "ok": True,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, help="arch:shape")
    ap.add_argument("--set", nargs="*", default=[], dest="overrides")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="tune the ingest redundancy gate / frame stride "
                         "with the AdaptiveSampler instead of re-lowering "
                         "a model cell")
    ap.add_argument("--tag", default="exp")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    if args.gate:
        rec = gate_tune()
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, f"gate_{args.tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        last = rec["steps"][-1] if rec["steps"] else {}
        print(f"gate tune: objects={rec['n_objects']} "
              f"cnn={rec['n_cnn_invocations']} "
              f"gate_skipped={rec['n_gate_skipped']} "
              f"sampled_out={rec['n_sampled_out']} "
              f"final_stride={rec['final_stride']} "
              f"last_recall={last.get('recall')}")
        print(f"wrote {path}")
        return
    if args.cell is None:
        ap.error("--cell is required unless --gate is given")

    from repro.launch.dryrun import run_cell

    arch, cell = args.cell.split(":")
    overrides = dict(parse_override(kv) for kv in args.overrides) or None
    rec = run_cell(arch, cell, args.multi_pod, variant=args.variant,
                   cfg_overrides=overrides)
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{arch}_{cell}_{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)

    if rec.get("ok") and not rec.get("skipped"):
        r = rec["roofline"]
        m = rec["memory"]
        print(f"cell={args.cell} overrides={overrides}")
        print(f"  compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s "
              f"collective={r['collective_s']:.3f}s dom={r['dominant']}")
        print(f"  bound_step={r['bound_step_s']:.3f}s "
              f"roofline_frac={rec['roofline_fraction']:.4f} "
              f"useful={rec['useful_flops_ratio']:.3f}")
        print(f"  mem={m['live_bytes_per_device']/1e9:.2f}GB "
              f"fits={m['fits_16gb_hbm']} compile={rec['compile_s']}s")
        print(f"  wire: " + ", ".join(
            f"{k}={v/1e9:.1f}GB"
            for k, v in rec["collectives"]["wire_bytes"].items() if v))
    else:
        print(json.dumps(rec, indent=1)[:2000])


if __name__ == "__main__":
    main()
