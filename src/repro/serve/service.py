"""Multi-tenant query service: request queue, admission control,
continuous batching, and ingest/query backpressure (DESIGN.md §12).

``QueryService`` turns the batched engines of PRs 2/4 into a serving
layer. Tenants ``submit()`` class queries; each ``step()`` runs one
**continuous-batch cycle** that merges every admitted in-flight request —
across all tenants — into ONE ``query_many`` call, deduping identical
``(class, Kx)`` pairs, so the engine pays one candidate union and at most
one GT-CNN pass per cycle no matter how many callers are waiting.
Results are byte-identical to serving each request alone: ``query_many``
computes per-query answers independently (the PR-2 equivalence property),
so riding a merged call can change only cost, never frames.

**Admission control** bounds the queue (``max_queue_depth``,
``max_inflight_per_tenant``): a submit over either bound is rejected
immediately (the caller sees ``None``) instead of growing an unbounded
backlog — under an ingest-priority policy this is where query load sheds.

**Backpressure**: the service may also own the stream's ingest work via
``offer_ingest`` (chunks destined for an attached ``StreamingIngestor``);
each ``step()`` arbitrates the device between ingest and queries per
``ServiceConfig.policy``:

* ``"query"`` (default) — pending queries always run first; ingest chunks
  wait in a bounded backlog and run only on idle cycles. When the backlog
  bound overflows, the OLDEST chunk is shed (freshest frames win), counted
  in ``n_ingest_shed_*`` — ingest is sacrificed, query SLOs are not.
* ``"ingest"`` — up to ``ingest_chunks_per_cycle`` backlog chunks ingest
  *before* the cycle's query batch; query latency absorbs the contention
  and admission control sheds the query overflow instead.

After each ingested chunk the flush's ``IngestDelta`` is prefetched into
the engine's GT-label cache (``prefetch=True``), keeping the GT cost of
new/moved centroids off the query path exactly as in query-while-ingest.

Everything is deterministic and single-threaded: a "cycle" is one
``step()`` call, so drivers (``launch/serve.py``), benchmarks, and tests
can replay exact schedules. Wall-clock enters only through the injectable
``clock`` (latency accounting), never through control flow.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.core.archive import ArchiveQueryEngine
from repro.core.engine import normalize_kx
from repro.serve.slo import LatencyTracker, TenantStats


@dataclass
class QueryRequest:
    """One admitted tenant request: a batch of class queries."""
    req_id: int
    tenant: str
    classes: Tuple[int, ...]
    Kx: Tuple[Optional[int], ...]        # normalized: one entry per class
    deadline_s: Optional[float]          # SLO deadline relative to submit
    t_submit: float


@dataclass
class QueryResponse:
    """A completed request: per-class results aligned to
    ``request.classes`` (``QueryResult`` or ``ArchiveQueryResult``)."""
    request: QueryRequest
    results: List[object]
    latency_s: float
    deadline_missed: bool
    cycle: int                           # service cycle that completed it


@dataclass(frozen=True)
class ServiceConfig:
    """Knobs for one ``QueryService`` (validated at construction)."""
    max_queue_depth: int = 256           # admission bound on queued requests
    max_inflight_per_tenant: Optional[int] = None
    max_batch_requests: int = 32         # requests merged per cycle
    policy: str = "query"                # "query" | "ingest" priority
    ingest_chunks_per_cycle: int = 1
    max_ingest_backlog: Optional[int] = None   # chunks; overflow sheds oldest
    prefetch: bool = True                # warm the GT cache after each chunk
    default_deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.policy not in ("query", "ingest"):
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"expected 'query' or 'ingest'")
        if self.max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1: "
                             f"{self.max_queue_depth}")
        if self.max_batch_requests < 1:
            raise ValueError(f"max_batch_requests must be >= 1: "
                             f"{self.max_batch_requests}")
        if self.ingest_chunks_per_cycle < 1:
            raise ValueError(f"ingest_chunks_per_cycle must be >= 1: "
                             f"{self.ingest_chunks_per_cycle}")
        if self.max_ingest_backlog is not None and self.max_ingest_backlog < 1:
            raise ValueError(f"max_ingest_backlog must be >= 1 or None: "
                             f"{self.max_ingest_backlog}")
        if self.max_inflight_per_tenant is not None \
                and self.max_inflight_per_tenant < 1:
            raise ValueError(f"max_inflight_per_tenant must be >= 1 or "
                             f"None: {self.max_inflight_per_tenant}")


@dataclass
class ServiceStats:
    """Cumulative counters over the service's lifetime."""
    n_cycles: int = 0
    n_query_cycles: int = 0          # cycles that ran a merged query_many
    n_completed: int = 0             # requests completed
    n_rejected: int = 0              # requests shed by admission control
    n_class_queries: int = 0         # class queries inside completed requests
    n_merged_calls: int = 0          # engine.query_many invocations
    n_merged_queries: int = 0        # unique (class, Kx) pairs sent down
    n_shared_queries: int = 0        # duplicate pairs served by sharing
    n_ingest_chunks: int = 0
    n_ingest_objects: int = 0
    n_ingest_deferred: int = 0       # chunk-cycles spent behind queries
    n_ingest_shed_chunks: int = 0
    n_ingest_shed_objects: int = 0
    n_prefetch_gt: int = 0           # GT calls moved off the query path


class QueryService:
    """Serves many tenants' class queries against one engine
    (``QueryEngine`` or ``ArchiveQueryEngine``), one merged
    ``query_many`` per cycle, with admission control and ingest/query
    backpressure.

    ``ingestor`` (optional) is the ``StreamingIngestor`` behind
    ``offer_ingest``; when the engine is an ``ArchiveQueryEngine`` it
    should be the same ingestor the engine queries as its live shard.
    ``clock`` is injectable so tests can pin latency/deadline accounting.
    """

    def __init__(self, engine, cfg: Optional[ServiceConfig] = None,
                 ingestor=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.engine = engine
        self.cfg = cfg if cfg is not None else ServiceConfig()
        self.ingestor = ingestor
        self.clock = clock
        self.slo = LatencyTracker()
        self.stats = ServiceStats()
        self.last_batch = None           # engine batch stats of the last cycle
        self._queue: Deque[QueryRequest] = deque()
        self._backlog: Deque[Tuple[np.ndarray, np.ndarray]] = deque()
        self._inflight: Dict[str, int] = {}
        self._next_id = 0

    # -- state -----------------------------------------------------------------

    @property
    def pending_queries(self) -> int:
        return len(self._queue)

    @property
    def pending_ingest(self) -> int:
        return len(self._backlog)

    def tenant_stats(self, tenant: str) -> TenantStats:
        return self.slo.tenant(tenant)

    # -- admission -------------------------------------------------------------

    def submit(self, tenant: str, classes: Sequence[int],
               Kx: Union[None, int, Sequence[Optional[int]]] = None,
               deadline_s: Optional[float] = None) -> Optional[int]:
        """Submit one request (a batch of class queries for ``tenant``).

        Returns the request id, or None when admission control sheds the
        request (queue full / tenant over its in-flight cap). ``Kx`` is
        validated here — a malformed request is the submitter's error and
        must never poison a merged batch cycle.
        """
        classes = tuple(int(c) for c in classes)
        kxs = tuple(normalize_kx(Kx, len(classes)))
        ts = self.slo.on_submit(tenant)
        if len(self._queue) >= self.cfg.max_queue_depth or (
                self.cfg.max_inflight_per_tenant is not None
                and self._inflight.get(tenant, 0)
                >= self.cfg.max_inflight_per_tenant):
            ts.n_rejected += 1
            self.stats.n_rejected += 1
            return None
        if deadline_s is None:
            deadline_s = self.cfg.default_deadline_s
        req = QueryRequest(req_id=self._next_id, tenant=tenant,
                           classes=classes, Kx=kxs, deadline_s=deadline_s,
                           t_submit=self.clock())
        self._next_id += 1
        self._queue.append(req)
        self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
        return req.req_id

    def offer_ingest(self, crops: np.ndarray, frames: np.ndarray) -> bool:
        """Queue one ingest chunk for the attached ingestor.

        The chunk always enters the backlog; when ``max_ingest_backlog``
        overflows, the OLDEST chunk is shed so the freshest frames
        survive (chunks arrive in stream order, so dropping a prefix
        keeps the non-decreasing-frame contract). Returns False when this
        offer caused a shed — the caller's backpressure signal.
        """
        if self.ingestor is None:
            raise ValueError("offer_ingest needs an attached ingestor")
        self._backlog.append((np.asarray(crops),
                              np.asarray(frames, np.int64)))
        shed = False
        if self.cfg.max_ingest_backlog is not None:
            while len(self._backlog) > self.cfg.max_ingest_backlog:
                old_crops, _ = self._backlog.popleft()
                self.stats.n_ingest_shed_chunks += 1
                self.stats.n_ingest_shed_objects += len(old_crops)
                shed = True
        return not shed

    # -- the batch cycle -------------------------------------------------------

    def step(self) -> List[QueryResponse]:
        """One service cycle: arbitrate ingest vs queries per the policy,
        then complete up to ``max_batch_requests`` queued requests in one
        merged ``query_many``. Returns the cycle's completed responses."""
        self.stats.n_cycles += 1
        if self.cfg.policy == "ingest" or not self._queue:
            self._run_ingest(self.cfg.ingest_chunks_per_cycle)
        else:
            # query priority under contention: the backlog waits
            self.stats.n_ingest_deferred += len(self._backlog)
        return self._run_batch()

    def run_until_idle(self, max_cycles: int = 100_000,
                       ) -> List[QueryResponse]:
        """Step until no queries or ingest chunks are pending."""
        out: List[QueryResponse] = []
        for _ in range(max_cycles):
            if not self._queue and not self._backlog:
                return out
            out.extend(self.step())
        raise RuntimeError(
            f"service did not go idle within {max_cycles} cycles "
            f"({len(self._queue)} queries / {len(self._backlog)} chunks "
            f"pending)")

    def drain_ingest(self) -> int:
        """Ingest every backlog chunk now, bypassing the policy (driver
        shutdown / round barrier). Returns chunks ingested."""
        n = len(self._backlog)
        while self._backlog:
            self._run_ingest(len(self._backlog))
        return n

    def _run_ingest(self, budget: int):
        for _ in range(budget):
            if not self._backlog:
                return
            crops, frames = self._backlog.popleft()
            self.ingestor.feed(crops, frames)
            delta = self.ingestor.flush()
            self.stats.n_ingest_chunks += 1
            self.stats.n_ingest_objects += len(crops)
            if self.cfg.prefetch:
                if isinstance(self.engine, ArchiveQueryEngine):
                    self.stats.n_prefetch_gt += self.engine.prefetch(delta)
                else:
                    self.stats.n_prefetch_gt += self.engine.prefetch(
                        delta.touched_cids)

    def _run_batch(self) -> List[QueryResponse]:
        if not self._queue:
            return []
        n = min(len(self._queue), self.cfg.max_batch_requests)
        reqs = [self._queue.popleft() for _ in range(n)]
        # continuous batch: the unique (class, Kx) pairs across every
        # admitted request, in first-appearance order; duplicates share
        # one engine query (identical answers — per-query results depend
        # only on (class, Kx) and engine state, never on batch-mates)
        pair_pos: Dict[Tuple[int, Optional[int]], int] = {}
        classes: List[int] = []
        kxs: List[Optional[int]] = []
        for req in reqs:
            for c, k in zip(req.classes, req.Kx):
                key = (c, None if k is None else int(k))
                if key not in pair_pos:
                    pair_pos[key] = len(classes)
                    classes.append(c)
                    kxs.append(k)
                else:
                    self.stats.n_shared_queries += 1
        results, batch = self.engine.query_many(classes, kxs)
        self.last_batch = batch
        self.stats.n_merged_calls += 1
        self.stats.n_merged_queries += len(classes)
        self.stats.n_query_cycles += 1
        t_done = self.clock()
        responses: List[QueryResponse] = []
        for req in reqs:
            res = [results[pair_pos[(c, None if k is None else int(k))]]
                   for c, k in zip(req.classes, req.Kx)]
            latency = t_done - req.t_submit
            missed = (req.deadline_s is not None
                      and latency > req.deadline_s)
            self.slo.on_complete(req.tenant, latency, missed)
            self._inflight[req.tenant] -= 1
            self.stats.n_completed += 1
            self.stats.n_class_queries += len(req.classes)
            responses.append(QueryResponse(
                request=req, results=res, latency_s=latency,
                deadline_missed=missed, cycle=self.stats.n_cycles))
        return responses
