"""Per-tenant latency-SLO accounting for the query service (DESIGN.md §12).

Each tenant accumulates the latency (submit -> completion) of every
completed request plus counters for admission rejections and deadline
misses. p50/p99 are percentiles over the completed-request latencies —
a rejected request never enters the distribution (it was shed, not
served), which keeps the latency numbers honest under overload: shedding
must show up in ``n_rejected``, not as an artificially good tail.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class TenantStats:
    """SLO counters and the latency distribution for one tenant."""
    tenant: str
    n_submitted: int = 0
    n_rejected: int = 0              # shed by admission control
    n_completed: int = 0
    n_deadline_missed: int = 0
    latencies_s: List[float] = field(default_factory=list)

    def percentile_s(self, q: float) -> float:
        """Latency percentile over completed requests (NaN if none)."""
        if not self.latencies_s:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_s), q))

    @property
    def p50_s(self) -> float:
        return self.percentile_s(50.0)

    @property
    def p99_s(self) -> float:
        return self.percentile_s(99.0)


class LatencyTracker:
    """tenant name -> ``TenantStats``, plus service-wide aggregates."""

    def __init__(self):
        self._tenants: Dict[str, TenantStats] = {}

    def tenant(self, name: str) -> TenantStats:
        ts = self._tenants.get(name)
        if ts is None:
            ts = self._tenants[name] = TenantStats(tenant=name)
        return ts

    def __iter__(self):
        return iter(sorted(self._tenants.values(), key=lambda t: t.tenant))

    def __len__(self) -> int:
        return len(self._tenants)

    def on_submit(self, name: str) -> TenantStats:
        ts = self.tenant(name)
        ts.n_submitted += 1
        return ts

    def on_reject(self, name: str):
        self.tenant(name).n_rejected += 1

    def on_complete(self, name: str, latency_s: float, missed: bool):
        ts = self.tenant(name)
        ts.n_completed += 1
        ts.latencies_s.append(float(latency_s))
        if missed:
            ts.n_deadline_missed += 1

    def all_latencies_s(self) -> np.ndarray:
        """Every completed-request latency across tenants (for service
        p50/p99)."""
        out: List[float] = []
        for ts in self._tenants.values():
            out.extend(ts.latencies_s)
        return np.asarray(out, np.float64)

    def percentile_s(self, q: float) -> float:
        lat = self.all_latencies_s()
        if len(lat) == 0:
            return float("nan")
        return float(np.percentile(lat, q))

    def summary(self) -> Dict[str, dict]:
        """JSON-friendly per-tenant summary (benchmark / driver output)."""
        return {
            ts.tenant: {
                "submitted": ts.n_submitted,
                "rejected": ts.n_rejected,
                "completed": ts.n_completed,
                "deadline_missed": ts.n_deadline_missed,
                "p50_ms": round(ts.p50_s * 1e3, 3) if ts.latencies_s else None,
                "p99_ms": round(ts.p99_s * 1e3, 3) if ts.latencies_s else None,
            }
            for ts in self
        }
