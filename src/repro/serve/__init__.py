"""Multi-tenant query serving layer (DESIGN.md §12): request queue +
admission control, a continuous batcher merging all in-flight queries
into one ``query_many`` per cycle, per-tenant latency-SLO accounting,
and ingest/query backpressure."""
from repro.serve.service import (QueryRequest, QueryResponse, QueryService,
                                 ServiceConfig, ServiceStats)
from repro.serve.slo import LatencyTracker, TenantStats

__all__ = [
    "LatencyTracker",
    "QueryRequest",
    "QueryResponse",
    "QueryService",
    "ServiceConfig",
    "ServiceStats",
    "TenantStats",
]
