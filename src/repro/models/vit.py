"""ViT / DeiT image classifier (encoder-only transformer, learned pos-emb,
CLS token, optional DeiT distillation token). Supports variable input
resolution via pos-emb interpolation (cls_384 finetune cell).

This family doubles as the Focus GT-CNN (vit-l16) and as the base for the
compressed cheap-CNN search space (vit-s16 with layers removed / input
rescaled), mirroring the paper's ResNet152 / ResNet18-variants split.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import ViTConfig
from repro.models import layers as L
from repro.distributed import constrain


def init(rng, cfg: ViTConfig):
    dt = L.compute_dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    n_tok = cfg.n_tokens()

    def layer_init(rng):
        k1, k2 = jax.random.split(rng)
        return {
            "ln1": L.layernorm_init(cfg.d_model),
            "attn": L.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_heads, dt),
            "ln2": L.layernorm_init(cfg.d_model),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", dt),
        }

    stacked = jax.vmap(layer_init)(jax.random.split(ks[0], cfg.n_layers))
    params = {
        "patch": L.patch_embed_init(ks[1], cfg.patch, cfg.in_channels,
                                    cfg.d_model, dt),
        "cls": jnp.zeros((1, 1, cfg.d_model), dt),
        "pos_embed": (jax.random.normal(ks[2], (1, n_tok, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dt),
        "layers": stacked,
        "final_ln": L.layernorm_init(cfg.d_model),
        "head": {"w": L.dense_init(ks[3], cfg.d_model, cfg.n_classes, dtype=dt),
                 "b": jnp.zeros((cfg.n_classes,), dt)},
    }
    if cfg.distill_token:
        params["dist"] = jnp.zeros((1, 1, cfg.d_model), dt)
        params["head_dist"] = {
            "w": L.dense_init(ks[4], cfg.d_model, cfg.n_classes, dtype=dt),
            "b": jnp.zeros((cfg.n_classes,), dt)}
    return params


def _interp_pos(pos, n_special: int, n_patches_new: int):
    """Bilinear pos-embedding interpolation for a new resolution."""
    n_patches_old = pos.shape[1] - n_special
    if n_patches_old == n_patches_new:
        return pos
    g_old = int(math.sqrt(n_patches_old))
    g_new = int(math.sqrt(n_patches_new))
    special, grid = pos[:, :n_special], pos[:, n_special:]
    grid = grid.reshape(1, g_old, g_old, -1)
    grid = jax.image.resize(grid.astype(jnp.float32),
                            (1, g_new, g_new, grid.shape[-1]), "bilinear")
    grid = grid.reshape(1, g_new * g_new, -1).astype(pos.dtype)
    return jnp.concatenate([special, grid], axis=1)


def forward(params, images, cfg: ViTConfig, mesh=None, *,
            features_only: bool = False):
    """images: (B, H, W, C) -> logits (B, n_classes) fp32.

    ``features_only`` returns the penultimate (pre-head) CLS representation —
    the Focus feature vector used for clustering (§2.2.3 of the paper).
    """
    dt = L.compute_dtype(cfg.dtype)
    images = images.astype(dt)
    x = L.patch_embed(params["patch"], images, cfg.patch)      # (B, N, D)
    B, N, D = x.shape
    toks = [jnp.broadcast_to(params["cls"], (B, 1, D))]
    n_special = 1
    if cfg.distill_token:
        toks.append(jnp.broadcast_to(params["dist"], (B, 1, D)))
        n_special = 2
    x = jnp.concatenate(toks + [x], axis=1)
    x = x + _interp_pos(params["pos_embed"], n_special, N)
    x = constrain(x, mesh, "hidden")

    def body(x, p):
        h = L.layernorm(p["ln1"], x)
        h = L.multihead_attention(p["attn"], h, n_heads=cfg.n_heads,
                                  n_kv_heads=cfg.n_heads, causal=False,
                                  use_rope=False, mesh=mesh)
        x = x + h
        h = L.layernorm(p["ln2"], x)
        x = constrain(x + L.mlp(p["mlp"], h, "gelu", mesh=mesh), mesh, "hidden")
        return x, ()

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy(cfg.remat_policy))
    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body(x, p)

    x = L.layernorm(params["final_ln"], x)
    cls = x[:, 0]
    if features_only:
        return cls.astype(jnp.float32)
    logits = (cls @ params["head"]["w"] + params["head"]["b"]).astype(jnp.float32)
    if cfg.distill_token:
        dist = x[:, 1]
        logits_d = (dist @ params["head_dist"]["w"]
                    + params["head_dist"]["b"]).astype(jnp.float32)
        logits = (logits + logits_d) / 2
    return logits


def loss_fn(params, images, labels, cfg: ViTConfig, mesh=None):
    logits = forward(params, images, cfg, mesh=mesh)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(nll), {"nll": jnp.mean(nll), "acc": acc}
