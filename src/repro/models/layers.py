"""Shared neural-net layers: norms, rotary GQA attention (train / prefill /
decode-with-cache), dense MLP, GShard-style MoE, patch embedding, conv/SE/BN
primitives. Pure functional: ``*_init`` builds param pytrees, the matching
apply function consumes them.

All matmuls are written so XLA SPMD can shard them with the rules in
``repro.distributed.sharding`` (TP over heads / hidden / experts, FSDP over
the d_model dim). Activation sharding constraints are applied by callers.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def remat_policy(name: str):
    """Named activation-checkpoint policies (cfg.remat_policy)."""
    import jax
    if name == "nothing":
        return None                      # save only layer inputs; recompute all
    if name == "dots_nobatch":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    raise ValueError(name)


def compute_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(rng, d_in: int, d_out: int, scale: Optional[float] = None,
               dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"]).astype(dt)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    if params:
        x = x * params["scale"] + params["bias"]
    return x.astype(dt)


def norm_init(kind: str, d: int):
    if kind == "rmsnorm":
        return rmsnorm_init(d)
    if kind == "layernorm":
        return layernorm_init(d)
    if kind == "nonparametric_ln":     # OLMo: LN without affine params
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, params, x):
    if kind == "rmsnorm":
        return rmsnorm(params, x)
    return layernorm(params, x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    angles = angles[..., None, :]                       # (..., S, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, full / sliding-window / decode with KV cache)
# ---------------------------------------------------------------------------

def attn_init(rng, d_model: int, n_heads: int, n_kv_heads: int, dtype):
    hd = d_model // n_heads
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * hd, dtype=dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * hd, dtype=dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * hd, dtype=dtype),
        "wo": dense_init(ks[3], n_heads * hd, d_model, dtype=dtype),
    }


def _gqa_scores(q, k):
    """q: (B,Sq,KV,G,dh)  k: (B,Sk,KV,dh) -> (B,KV,G,Sq,Sk) fp32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(w, v):
    """w: (B,KV,G,Sq,Sk)  v: (B,Sk,KV,dh) -> (B,Sq,KV,G,dh)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(w.dtype))


def multihead_attention(params, x, *, n_heads: int, n_kv_heads: int,
                        causal: bool, window: int = 0,
                        positions=None, theta: float = 10000.0,
                        use_rope: bool = True, mesh=None,
                        attn_impl: str = "einsum", out_kind: str = "hidden",
                        q_chunk: int = 4096, scores_dtype=jnp.float32):
    """Self attention over x: (B, S, D). Returns (B, S, D)."""
    from repro.distributed import constrain

    B, S, D = x.shape
    hd = D // n_heads
    g = n_heads // n_kv_heads
    q = (x @ params["wq"]).reshape(B, S, n_kv_heads, g, hd)
    k = (x @ params["wk"]).reshape(B, S, n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, n_kv_heads, hd)
    if use_rope:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q.reshape(B, S, n_kv_heads * g, hd), positions,
                       theta).reshape(B, S, n_kv_heads, g, hd)
        k = apply_rope(k, positions, theta)

    if attn_impl == "flash" and causal and window == 0:
        from repro.kernels import ops as kops
        qf = q.reshape(B, S, n_kv_heads * g, hd)
        kf = jnp.repeat(k, g, axis=2)
        vf = jnp.repeat(v, g, axis=2)
        out = kops.flash_attention(qf, kf, vf, causal=True)
        out = out.reshape(B, S, n_heads * hd)
        return out @ params["wo"]

    # Flat-head formulation: repeat KV heads to H so the head axis (H, which
    # every assigned arch makes divisible by the model axis) shards fully —
    # grouped (KV, G) scores would strand TP shards whenever KV < model
    # (dbrx KV=8, granite KV=1) and trigger involuntary resharding.
    qf = constrain(q.reshape(B, S, n_kv_heads * g, hd), mesh, "heads")
    kf = constrain(jnp.repeat(k, g, axis=2) if g > 1 else k, mesh, "heads")
    vf = constrain(jnp.repeat(v, g, axis=2) if g > 1 else v, mesh, "heads")

    neg = -1e30 if scores_dtype == jnp.float32 else -3e38

    def attend(q_blk, q0, Sq, k_end=None):
        """softmax(q_blk . k^T[:k_end]) . v[:k_end] for a query block at q0.

        When causal, callers pass k_end = q0 + Sq: keys beyond the block's
        last row are never attended, so they are SLICED off rather than
        masked — halves the causal FLOPs and shrinks the mask to the
        (Sq, Sq) diagonal block (a full (Sq, S) mask is loop-invariant and
        gets hoisted+materialized by XLA, ~1 GB per block at 32k).
        """
        kk = kf if k_end is None else kf[:, :k_end]
        vv = vf if k_end is None else vf[:, :k_end]
        Sk = kk.shape[1]
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, kk,
                       preferred_element_type=scores_dtype) / math.sqrt(hd)
        s = constrain(s, mesh, "scores")
        if causal or window:
            qpos = q0 + jnp.arange(Sq)[:, None]
            if causal and Sk == q0 + Sq and not window:
                diag = jnp.tril(jnp.ones((Sq, Sq), bool))    # (Sq, Sq) only
                s = jnp.concatenate(
                    [s[..., :q0],
                     jnp.where(diag, s[..., q0:], neg)], axis=-1)
            else:
                kpos = jnp.arange(Sk)[None, :]
                mask = jnp.ones((Sq, Sk), bool)
                if causal:
                    mask &= kpos <= qpos
                if window:
                    mask &= kpos > qpos - window
                s = jnp.where(mask, s, neg)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        w = constrain(w, mesh, "scores")
        return jnp.einsum("bhqk,bkhd->bqhd", w, vv)

    if q_chunk and S > q_chunk and S % q_chunk == 0:
        # Long-sequence prefill: unrolled query blocks keep the live score
        # tensor at (B, H, q_chunk, <=S) instead of (B, H, S, S). Unrolled
        # (not lax.map) so HLO cost analysis counts every block.
        outs = []
        prev = None
        for q0 in range(0, S, q_chunk):
            q_blk = qf[:, q0:q0 + q_chunk]
            if prev is not None:
                # chain block i+1 on block i so the scheduler cannot keep
                # every block's (B,H,Sq,Sk) score buffer alive at once
                q_blk, _ = jax.lax.optimization_barrier((q_blk, prev))
            k_end = q0 + q_chunk if (causal and not window) else None
            prev = attend(q_blk, q0, q_chunk, k_end=k_end)
            outs.append(prev)
        out = jnp.concatenate(outs, axis=1)
    else:
        out = attend(qf, 0, S)
    out = out.reshape(B, S, n_heads * hd)
    out = constrain(out, mesh, "ffn")       # heads TP-sharded before wo
    return constrain(out @ params["wo"], mesh, out_kind)


def decode_attention(params, x, cache_k, cache_v, cache_len, *,
                     n_heads: int, n_kv_heads: int, theta: float = 10000.0,
                     use_rope: bool = True, window: int = 0, mesh=None):
    """One-token decode. x: (B, 1, D); cache_{k,v}: (B, S_max, KV, dh).

    Returns (out, new_cache_k, new_cache_v). Attention over the cache is
    linear in cache length (no quadratic term).
    """
    B, _, D = x.shape
    hd = D // n_heads
    g = n_heads // n_kv_heads
    S_max = cache_k.shape[1]
    q = (x @ params["wq"]).reshape(B, 1, n_kv_heads, g, hd)
    k = (x @ params["wk"]).reshape(B, 1, n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, 1, n_kv_heads, hd)
    pos = jnp.full((B, 1), cache_len, dtype=jnp.int32)
    if use_rope:
        q = apply_rope(q.reshape(B, 1, n_kv_heads * g, hd), pos,
                       theta).reshape(B, 1, n_kv_heads, g, hd)
        k = apply_rope(k, pos, theta)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                              cache_len, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                              cache_len, axis=1)
    scores = _gqa_scores(q, cache_k) / math.sqrt(hd)    # (B,KV,G,1,S_max)
    kpos = jnp.arange(S_max)
    valid = kpos <= cache_len
    if window:
        valid &= kpos > cache_len - window
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(w, cache_v).reshape(B, 1, n_heads * hd)
    return out @ params["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(rng, 3)
    p = {"wi": dense_init(ks[0], d_model, d_ff, dtype=dtype),
         "wo": dense_init(ks[1], d_ff, d_model, dtype=dtype)}
    if act == "swiglu":
        p["wg"] = dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp(params, x, act: str, mesh=None, out_kind: str = "hidden"):
    from repro.distributed import constrain
    three_d = x.ndim == 3
    h = x @ params["wi"]
    if three_d:
        h = constrain(h, mesh, "ffn")       # keep the wide dim TP-sharded
    if act == "swiglu":
        g = x @ params["wg"]
        if three_d:
            g = constrain(g, mesh, "ffn")
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out = h @ params["wo"]
    return constrain(out, mesh, out_kind) if three_d else out


# ---------------------------------------------------------------------------
# MoE (GShard-style grouped dispatch; EP over the "model" axis)
# ---------------------------------------------------------------------------

def moe_init(rng, d_model: int, d_ff: int, n_experts: int, dtype):
    ks = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d_model)

    def ew(rng, a, b, sc):
        return (jax.random.normal(rng, (n_experts, a, b), jnp.float32)
                * sc).astype(dtype)

    return {
        "gate": dense_init(ks[0], d_model, n_experts, dtype=jnp.float32),
        "wi": ew(ks[1], d_model, d_ff, s),
        "wg": ew(ks[2], d_model, d_ff, s),
        "wo": ew(ks[3], d_ff, d_model, 1.0 / math.sqrt(d_ff)),
    }


def moe(params, x, *, n_experts: int, top_k: int, group_size: int,
        capacity_factor: float, mesh=None, out_kind: str = "hidden",
        dispatch: str = "einsum"):
    """Mixture-of-experts FFN. x: (B, S, D) -> (y, aux_loss).

    Tokens are partitioned into groups of ``group_size``; each group
    dispatches into per-expert capacity buffers via one-hot einsums (GShard).
    Capacity C = ceil(group_size * top_k * cf / E). Expert matmuls carry the
    expert dim so EP shards them over the "model" axis.
    """
    B, S, D = x.shape
    T = B * S
    gs = min(group_size, T)
    while T % gs:
        gs //= 2
    G = T // gs
    C = max(1, int(math.ceil(gs * top_k * capacity_factor / n_experts)))
    C = min(C, gs)
    xg = x.reshape(G, gs, D)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["gate"])                       # (G,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, top_k)             # (G,gs,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # Load-balancing auxiliary loss (Switch/GShard).
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], n_experts), axis=(0, 1))
    aux = n_experts * jnp.sum(me * ce)

    # Position of each token within its expert's capacity buffer.
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # (G,gs,k,E)
    # priority: choice 0 of all tokens first, then choice 1, ...
    oh = onehot.transpose(0, 2, 1, 3).reshape(G, top_k * gs, n_experts)
    pos = jnp.cumsum(oh, axis=1) - oh                          # (G,k*gs,E)
    pos = pos.reshape(G, top_k, gs, n_experts).transpose(0, 2, 1, 3)
    within = (onehot * pos).sum(-1)                            # (G,gs,k)
    keep = within < C
    gate_vals = gate_vals * keep

    if dispatch == "einsum":
        # GShard-style one-hot dispatch/combine einsums (baseline). Cost:
        # materializes (G,gs,k,E,C) intermediates and spends
        # 2·T·E·C·D dispatch FLOPs — see §Perf for the scatter variant.
        disp = (jax.nn.one_hot(gate_idx, n_experts, dtype=x.dtype)[..., None]
                * jax.nn.one_hot(jnp.where(keep, within, C), C + 1,
                                 dtype=x.dtype)[..., None, :-1]
                ).sum(2)                                       # (G,gs,E,C)
        comb = (gate_vals[..., None, None].astype(x.dtype)
                * jax.nn.one_hot(gate_idx, n_experts, dtype=x.dtype)[..., None]
                * jax.nn.one_hot(jnp.where(keep, within, C), C + 1,
                                 dtype=x.dtype)[..., None, :-1]).sum(2)
        exp_in = jnp.einsum("gsec,gsd->egcd", disp, xg)        # (E,G,C,D)
    else:
        # Scatter/gather dispatch: no (G,gs,E,C) one-hots, no dispatch
        # matmul FLOPs — tokens are scatter-added into the per-expert
        # capacity buffer and gathered back with their gate weights.
        g_ix = jnp.arange(G)[:, None, None]                    # (G,1,1)
        c_ix = jnp.where(keep, within, C)                      # (G,gs,k)
        exp_in = jnp.zeros((n_experts, G, C + 1, D), x.dtype)
        exp_in = exp_in.at[gate_idx, g_ix, c_ix].add(
            xg[:, :, None, :], mode="drop")                    # (E,G,C+1,D)
        exp_in = exp_in[:, :, :C]

    h = jnp.einsum("egcd,edf->egcf", exp_in, params["wi"])
    hg = jnp.einsum("egcd,edf->egcf", exp_in, params["wg"])
    h = jax.nn.silu(hg) * h
    exp_out = jnp.einsum("egcf,efd->egcd", h, params["wo"])    # (E,G,C,D)

    if dispatch == "einsum":
        y = jnp.einsum("egcd,gsec->gsd", exp_out, comb)
    else:
        picked = exp_out[gate_idx, g_ix, jnp.minimum(within, C - 1)]
        picked = picked * (gate_vals[..., None]).astype(x.dtype)  # (G,gs,k,D)
        y = jnp.sum(picked, axis=2)                            # (G,gs,D)
    y = y.reshape(B, S, D)
    if mesh is not None:
        from repro.distributed import constrain
        y = constrain(y, mesh, out_kind)
    return y, aux


# ---------------------------------------------------------------------------
# Vision primitives
# ---------------------------------------------------------------------------

def patch_embed_init(rng, patch: int, in_ch: int, d_model: int, dtype):
    k1, _ = jax.random.split(rng)
    fan_in = patch * patch * in_ch
    w = (jax.random.normal(k1, (patch, patch, in_ch, d_model), jnp.float32)
         / math.sqrt(fan_in)).astype(dtype)
    return {"w": w, "b": jnp.zeros((d_model,), dtype)}


def patch_embed(params, images, patch: int):
    """images: (B, H, W, C) -> (B, H/p * W/p, D)."""
    out = lax.conv_general_dilated(
        images, params["w"], window_strides=(patch, patch), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    out = out + params["b"]
    B, Hp, Wp, D = out.shape
    return out.reshape(B, Hp * Wp, D)


def conv_init(rng, kh: int, kw: int, cin: int, cout: int, dtype,
              groups: int = 1):
    fan_in = kh * kw * cin // groups
    w = (jax.random.normal(rng, (kh, kw, cin // groups, cout), jnp.float32)
         / math.sqrt(max(fan_in, 1))).astype(dtype)
    return {"w": w}


def conv(params, x, stride: int = 1, groups: int = 1, padding="SAME"):
    return lax.conv_general_dilated(
        x, params["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def bn_init(c: int):
    return ({"scale": jnp.ones((c,), jnp.float32),
             "bias": jnp.zeros((c,), jnp.float32)},
            {"mean": jnp.zeros((c,), jnp.float32),
             "var": jnp.ones((c,), jnp.float32)})


def batchnorm(params, state, x, train: bool, momentum: float = 0.99,
              eps: float = 1e-3):
    """Returns (y, new_state). x: (B, H, W, C)."""
    if train:
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=(0, 1, 2))
        var = jnp.var(xf, axis=(0, 1, 2))
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    y = (x.astype(jnp.float32) - mean) * lax.rsqrt(var + eps)
    y = y * params["scale"] + params["bias"]
    return y.astype(x.dtype), new_state


def se_init(rng, c: int, c_se: int, dtype):
    k1, k2 = jax.random.split(rng)
    return {"w1": dense_init(k1, c, c_se, dtype=dtype),
            "b1": jnp.zeros((c_se,), dtype),
            "w2": dense_init(k2, c_se, c, dtype=dtype),
            "b2": jnp.zeros((c,), dtype)}


def squeeze_excite(params, x):
    s = jnp.mean(x, axis=(1, 2))                  # (B, C)
    s = jax.nn.silu(s @ params["w1"] + params["b1"])
    s = jax.nn.sigmoid(s @ params["w2"] + params["b2"])
    return x * s[:, None, None, :]
