"""Focus cheap ingest-CNN family (compressed classifiers, §4.1 of the paper).

A small conv classifier parameterized by (n_blocks, width, input_res,
n_classes) — the paper's two compression axes are "remove conv layers"
(n_blocks) and "rescale input" (input_res); specialization shrinks
n_classes to Ls+1 (§4.3). The penultimate ``feature_dim`` vector is the
clustering feature (§2.2.3).

These models are intentionally CPU-trainable so the full Focus pipeline
(ingest -> index -> query) runs end-to-end in this container; the ViT family
plays the role of GT-CNN at datacenter scale (see configs/focus_pipeline.py).
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import CheapCNNConfig
from repro.models import layers as L


def _plan(cfg: CheapCNNConfig) -> List[Tuple[int, int, int]]:
    """(c_in, c_out, stride) per conv block."""
    plan = []
    c_in = cfg.in_channels
    c = cfg.width
    res = cfg.input_res
    for i in range(cfg.n_blocks):
        stride = 2 if (i % 2 == 0 and res > 4) else 1
        res = res // stride
        c_out = min(cfg.width * (2 ** (i // 2)), 4 * cfg.width)
        plan.append((c_in, c_out, stride))
        c_in = c_out
    return plan


def init(rng, cfg: CheapCNNConfig):
    dt = L.compute_dtype(cfg.dtype)
    plan = _plan(cfg)
    ks = jax.random.split(rng, len(plan) + 2)
    blocks = []
    for k, (ci, co, s) in zip(ks[: len(plan)], plan):
        blocks.append({
            "conv": L.conv_init(k, 3, 3, ci, co, dt),
            "scale": jnp.ones((co,), jnp.float32),
            "bias": jnp.zeros((co,), jnp.float32),
        })
    c_last = plan[-1][1]
    return {
        "blocks": blocks,
        "feat": {"w": L.dense_init(ks[-2], c_last, cfg.feature_dim, dtype=dt),
                 "b": jnp.zeros((cfg.feature_dim,), dt)},
        "head": {"w": L.dense_init(ks[-1], cfg.feature_dim, cfg.n_classes,
                                   dtype=dt),
                 "b": jnp.zeros((cfg.n_classes,), dt)},
    }


def _block_norm(p, x):
    """Cheap norm: per-channel RMS normalization + affine (stateless)."""
    xf = x.astype(jnp.float32)
    nu2 = jnp.mean(xf * xf, axis=(1, 2), keepdims=True)
    xf = xf * jax.lax.rsqrt(nu2 + 1e-6)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


def forward(params, images, cfg: CheapCNNConfig, mesh=None):
    """images (B, R, R, C) -> (logits (B, n_classes) fp32, features fp32).

    Returns logits AND the penultimate feature vector in one pass — exactly
    what Focus ingest needs (top-K classes + clustering features).
    """
    dt = L.compute_dtype(cfg.dtype)
    plan = _plan(cfg)
    x = images.astype(dt)
    for p, (ci, co, s) in zip(params["blocks"], plan):
        x = L.conv({"w": p["conv"]["w"]}, x, stride=s)
        x = jax.nn.relu(_block_norm(p, x))
    x = jnp.mean(x, axis=(1, 2))                         # (B, C)
    feats = jnp.tanh(x @ params["feat"]["w"] + params["feat"]["b"])
    logits = (feats @ params["head"]["w"]
              + params["head"]["b"]).astype(jnp.float32)
    return logits, feats.astype(jnp.float32)


def loss_fn(params, images, labels, cfg: CheapCNNConfig, mesh=None,
            label_weights=None):
    """Cross-entropy; optional per-class weights (OTHER-class reweighting,
    paper footnote 2)."""
    logits, _ = forward(params, images, cfg, mesh=mesh)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if label_weights is not None:
        nll = nll * jnp.take(label_weights, labels)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(nll), {"nll": jnp.mean(nll), "acc": acc}


def count_params(cfg: CheapCNNConfig) -> int:
    total = 0
    for ci, co, s in _plan(cfg):
        total += 3 * 3 * ci * co + 2 * co
    c_last = _plan(cfg)[-1][1]
    total += c_last * cfg.feature_dim + cfg.feature_dim
    total += cfg.feature_dim * cfg.n_classes + cfg.n_classes
    return total


def flops_per_image(cfg: CheapCNNConfig) -> int:
    """Forward FLOPs per image — the paper's ingest-cost unit."""
    total = 0
    res = cfg.input_res
    for ci, co, s in _plan(cfg):
        res = res // s
        total += 2 * res * res * 3 * 3 * ci * co
    c_last = _plan(cfg)[-1][1]
    total += 2 * c_last * cfg.feature_dim
    total += 2 * cfg.feature_dim * cfg.n_classes
    return total
