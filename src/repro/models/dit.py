"""DiT: latent diffusion transformer (adaLN-Zero conditioning) [arXiv:2212.09748].

Operates on VAE latents (img_res/8, 4 channels); the VAE is a stub — the
data pipeline / input_specs provide latents directly (see DESIGN.md).
Predicts (noise, sigma) per DiT's learn_sigma head; training uses the noise
MSE. Generation runs a DDIM sampler loop (one forward per step).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import DiTConfig
from repro.models import layers as L
from repro.distributed import constrain


def timestep_embedding(t, dim: int = 256, max_period: float = 10000.0):
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def init(rng, cfg: DiTConfig):
    dt = L.compute_dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    D = cfg.d_model
    p2c = cfg.patch * cfg.patch * cfg.latent_channels

    def layer_init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "attn": L.attn_init(k1, D, cfg.n_heads, cfg.n_heads, dt),
            "mlp": L.mlp_init(k2, D, cfg.d_ff, "gelu", dt),
            "adaln": {"w": jnp.zeros((D, 6 * D), dt),   # adaLN-Zero: init 0
                      "b": jnp.zeros((6 * D,), dt)},
        }

    stacked = jax.vmap(layer_init)(jax.random.split(ks[0], cfg.n_layers))
    return {
        "patch": L.patch_embed_init(ks[1], cfg.patch, cfg.latent_channels, D, dt),
        "pos_embed": (jax.random.normal(ks[2], (1, cfg.n_tokens(), D),
                                        jnp.float32) * 0.02).astype(dt),
        "t_embed": {"w1": L.dense_init(ks[3], 256, D, dtype=dt),
                    "b1": jnp.zeros((D,), dt),
                    "w2": L.dense_init(ks[4], D, D, dtype=dt),
                    "b2": jnp.zeros((D,), dt)},
        "label_embed": (jax.random.normal(ks[5], (cfg.n_classes + 1, D),
                                          jnp.float32) * 0.02).astype(dt),
        "layers": stacked,
        "final": {"adaln": {"w": jnp.zeros((D, 2 * D), dt),
                            "b": jnp.zeros((2 * D,), dt)},
                  "w": jnp.zeros((D, 2 * p2c), dt),     # noise + sigma
                  "b": jnp.zeros((2 * p2c,), dt)},
    }


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None, :]) + shift[:, None, :]


def forward(params, latents, t, labels, cfg: DiTConfig, mesh=None):
    """latents: (B, h, w, C); t: (B,) int32; labels: (B,) int32.

    Returns (noise_pred, sigma_pred), each (B, h, w, C).
    """
    dt = L.compute_dtype(cfg.dtype)
    B, h, w, C = latents.shape
    x = L.patch_embed(params["patch"], latents.astype(dt), cfg.patch)
    N = x.shape[1]
    pos = params["pos_embed"]
    if pos.shape[1] != N:    # higher-res cells: interpolate the pos table
        g_old = int(math.sqrt(pos.shape[1]))
        g_new = int(math.sqrt(N))
        pos = jax.image.resize(
            pos.reshape(1, g_old, g_old, -1).astype(jnp.float32),
            (1, g_new, g_new, pos.shape[-1]), "bilinear"
        ).reshape(1, N, -1).astype(pos.dtype)
    x = constrain(x + pos, mesh, "hidden")

    temb = timestep_embedding(t)
    te = params["t_embed"]
    c = jax.nn.silu(temb.astype(dt) @ te["w1"] + te["b1"]) @ te["w2"] + te["b2"]
    c = c + jnp.take(params["label_embed"], labels, axis=0).astype(dt)
    c_act = jax.nn.silu(c)

    def body(x, p):
        mod = c_act @ p["adaln"]["w"] + p["adaln"]["b"]
        (s1, sc1, g1, s2, sc2, g2) = jnp.split(mod, 6, axis=-1)
        h_ = _modulate(L.layernorm({}, x), s1, sc1)
        h_ = L.multihead_attention(p["attn"], h_, n_heads=cfg.n_heads,
                                   n_kv_heads=cfg.n_heads, causal=False,
                                   use_rope=False, mesh=mesh)
        x = x + g1[:, None, :] * h_
        h_ = _modulate(L.layernorm({}, x), s2, sc2)
        h_ = L.mlp(p["mlp"], h_, "gelu", mesh=mesh)
        x = constrain(x + g2[:, None, :] * h_, mesh, "hidden")
        return x, ()

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy(cfg.remat_policy))
    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body(x, p)

    fin = params["final"]
    mod = c_act @ fin["adaln"]["w"] + fin["adaln"]["b"]
    shift, scale = jnp.split(mod, 2, axis=-1)
    x = _modulate(L.layernorm({}, x), shift, scale)
    x = x @ fin["w"] + fin["b"]                      # (B, N, 2*p*p*C)

    # unpatchify
    g = int(math.sqrt(N))
    p_ = cfg.patch
    x = x.reshape(B, g, g, p_, p_, 2 * C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, g * p_, g * p_, 2 * C)
    noise, sigma = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return noise, sigma


# ---------------------------------------------------------------------------
# Diffusion process (linear schedule, DDIM sampling)
# ---------------------------------------------------------------------------

N_TRAIN_STEPS = 1000


def alpha_bars(n_steps: int = N_TRAIN_STEPS):
    betas = jnp.linspace(1e-4, 0.02, n_steps, dtype=jnp.float32)
    return jnp.cumprod(1.0 - betas)


def loss_fn(params, latents, labels, rng, cfg: DiTConfig, mesh=None):
    """Noise-prediction MSE at uniformly sampled timesteps."""
    B = latents.shape[0]
    k1, k2 = jax.random.split(rng)
    t = jax.random.randint(k1, (B,), 0, N_TRAIN_STEPS)
    eps = jax.random.normal(k2, latents.shape, jnp.float32)
    ab = jnp.take(alpha_bars(), t)[:, None, None, None]
    noisy = jnp.sqrt(ab) * latents + jnp.sqrt(1 - ab) * eps
    pred, _ = forward(params, noisy, t, labels, cfg, mesh=mesh)
    loss = jnp.mean(jnp.square(pred - eps))
    return loss, {"mse": loss}


def sample(params, rng, labels, cfg: DiTConfig, img_res: int, n_steps: int,
           mesh=None):
    """DDIM sampler: ``n_steps`` forwards via lax.scan (gen_* cells)."""
    B = labels.shape[0]
    res = img_res // cfg.vae_factor
    x = jax.random.normal(rng, (B, res, res, cfg.latent_channels), jnp.float32)
    ab = alpha_bars()
    ts = jnp.linspace(N_TRAIN_STEPS - 1, 0, n_steps).astype(jnp.int32)

    def step(x, i):
        t_cur = ts[i]
        t_prev = jnp.where(i + 1 < n_steps, ts[jnp.minimum(i + 1, n_steps - 1)], -1)
        eps, _ = forward(params, x, jnp.full((B,), t_cur), labels, cfg,
                         mesh=mesh)
        a_cur = ab[t_cur]
        a_prev = jnp.where(t_prev >= 0, ab[jnp.maximum(t_prev, 0)], 1.0)
        x0 = (x - jnp.sqrt(1 - a_cur) * eps) / jnp.sqrt(a_cur)
        x = jnp.sqrt(a_prev) * x0 + jnp.sqrt(1 - a_prev) * eps
        return x, ()

    x, _ = lax.scan(step, x, jnp.arange(n_steps))
    return x
