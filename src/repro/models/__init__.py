"""Model zoo: pure-functional JAX models (param pytrees + apply functions)."""
