"""EfficientNet [arXiv:1905.11946] — MBConv + SE, compound width/depth
scaling. b7 = (width 2.0, depth 3.1, native 600px). BatchNorm statistics are
threaded functionally as a separate ``state`` pytree.
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import EffNetConfig
from repro.models import layers as L

# B0 stage spec: (expand, channels, layers, stride, kernel)
_B0_STAGES = [
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
]
_STEM = 32
_HEAD = 1280


def _round_ch(c: float, mult: float, div: int = 8) -> int:
    c *= mult
    new = max(div, int(c + div / 2) // div * div)
    if new < 0.9 * c:
        new += div
    return new


def _round_depth(d: int, mult: float) -> int:
    return int(math.ceil(d * mult))


def block_specs(cfg: EffNetConfig) -> List[Tuple[int, int, int, int, int, int]]:
    """List of (c_in, c_mid, c_out, stride, kernel, se) per MBConv block."""
    specs = []
    c_in = _round_ch(_STEM, cfg.width_mult)
    for expand, c, n, stride, k in _B0_STAGES:
        c_out = _round_ch(c, cfg.width_mult)
        for i in range(_round_depth(n, cfg.depth_mult)):
            s = stride if i == 0 else 1
            c_mid = c_in * expand
            se = max(1, c_in // 4)
            specs.append((c_in, c_mid, c_out, s, k, se))
            c_in = c_out
    return specs


def init(rng, cfg: EffNetConfig):
    dt = L.compute_dtype(cfg.dtype)
    specs = block_specs(cfg)
    ks = jax.random.split(rng, len(specs) + 3)
    stem_c = _round_ch(_STEM, cfg.width_mult)
    head_c = _round_ch(_HEAD, max(1.0, cfg.width_mult))

    params, state = {}, {}
    params["stem"] = {"conv": L.conv_init(ks[0], 3, 3, 3, stem_c, dt)}
    params["stem"]["bn"], state["stem"] = L.bn_init(stem_c)

    blocks_p, blocks_s = [], []
    for i, (ci, cm, co, s, k, se) in enumerate(specs):
        kk = jax.random.split(ks[i + 1], 4)
        p, st = {}, {}
        if cm != ci:
            p["expand"] = {"conv": L.conv_init(kk[0], 1, 1, ci, cm, dt)}
            p["expand"]["bn"], st["expand"] = L.bn_init(cm)
        p["dwconv"] = {"w": L.conv_init(kk[1], k, k, cm, cm, dt,
                                        groups=cm)["w"]}
        p["bn_dw"], st["dw"] = L.bn_init(cm)
        p["se"] = L.se_init(kk[2], cm, se, dt)
        p["project"] = {"conv": L.conv_init(kk[3], 1, 1, cm, co, dt)}
        p["project"]["bn"], st["project"] = L.bn_init(co)
        blocks_p.append(p)
        blocks_s.append(st)
    params["blocks"] = blocks_p
    state["blocks"] = blocks_s

    params["head"] = {"conv": L.conv_init(ks[-2], 1, 1, specs[-1][2], head_c, dt)}
    params["head"]["bn"], state["head"] = L.bn_init(head_c)
    params["fc"] = {"w": L.dense_init(ks[-1], head_c, cfg.n_classes, dtype=dt),
                    "b": jnp.zeros((cfg.n_classes,), dt)}
    return params, state


def forward(params, state, images, cfg: EffNetConfig, train: bool = False,
            mesh=None, features_only: bool = False):
    """images (B,H,W,3) -> (logits fp32, new_state)."""
    dt = L.compute_dtype(cfg.dtype)
    specs = block_specs(cfg)
    x = images.astype(dt)
    new_state = {"blocks": []}

    x = L.conv(params["stem"]["conv"], x, stride=2)
    x, new_state["stem"] = L.batchnorm(params["stem"]["bn"], state["stem"], x,
                                       train)
    x = jax.nn.silu(x)

    for p, st, (ci, cm, co, s, k, se) in zip(params["blocks"],
                                             state["blocks"], specs):
        inp = x
        nst = {}
        if "expand" in p:
            x = L.conv(p["expand"]["conv"], x)
            x, nst["expand"] = L.batchnorm(p["expand"]["bn"], st["expand"], x,
                                           train)
            x = jax.nn.silu(x)
        x = L.conv({"w": p["dwconv"]["w"]}, x, stride=s, groups=cm)
        x, nst["dw"] = L.batchnorm(p["bn_dw"], st["dw"], x, train)
        x = jax.nn.silu(x)
        x = L.squeeze_excite(p["se"], x)
        x = L.conv(p["project"]["conv"], x)
        x, nst["project"] = L.batchnorm(p["project"]["bn"], st["project"], x,
                                        train)
        if s == 1 and ci == co:
            x = x + inp
        new_state["blocks"].append(nst)

    x = L.conv(params["head"]["conv"], x)
    x, new_state["head"] = L.batchnorm(params["head"]["bn"], state["head"], x,
                                       train)
    x = jax.nn.silu(x)
    feats = jnp.mean(x, axis=(1, 2))
    if features_only:
        return feats.astype(jnp.float32), new_state
    logits = (feats @ params["fc"]["w"] + params["fc"]["b"]).astype(jnp.float32)
    return logits, new_state


def loss_fn(params, state, images, labels, cfg: EffNetConfig, mesh=None):
    logits, new_state = forward(params, state, images, cfg, train=True,
                                mesh=mesh)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(nll), ({"nll": jnp.mean(nll), "acc": acc}, new_state)


def count_params(cfg: EffNetConfig) -> int:
    specs = block_specs(cfg)
    stem_c = _round_ch(_STEM, cfg.width_mult)
    head_c = _round_ch(_HEAD, max(1.0, cfg.width_mult))
    total = 3 * 3 * 3 * stem_c + 2 * stem_c
    for ci, cm, co, s, k, se in specs:
        if cm != ci:
            total += ci * cm + 2 * cm
        total += k * k * cm + 2 * cm
        total += cm * se + se + se * cm + cm
        total += cm * co + 2 * co
    total += specs[-1][2] * head_c + 2 * head_c
    total += head_c * cfg.n_classes + cfg.n_classes
    return total


def flops_per_image(cfg: EffNetConfig, img_res: int = None) -> int:
    """Analytic forward FLOPs (2*MACs) per image at the given resolution."""
    res = img_res or cfg.img_res
    specs = block_specs(cfg)
    stem_c = _round_ch(_STEM, cfg.width_mult)
    head_c = _round_ch(_HEAD, max(1.0, cfg.width_mult))
    r = res // 2                       # stem stride 2
    total = 2 * r * r * 3 * 3 * 3 * stem_c
    for ci, cm, co, stride, k, se in specs:
        if cm != ci:
            total += 2 * r * r * ci * cm          # expand 1x1
        r2 = r // stride
        total += 2 * r2 * r2 * k * k * cm         # depthwise
        total += 2 * (cm * se + se * cm)          # SE
        total += 2 * r2 * r2 * cm * co            # project 1x1
        r = r2
    total += 2 * r * r * specs[-1][2] * head_c
    total += 2 * head_c * cfg.n_classes
    return total
