"""Decoder-only LM (dense + MoE, GQA, rotary) with scan-over-layers,
activation checkpointing, a prefill path and a KV-cache decode path.

Params layout (leaves under "layers" are stacked on a leading L axis):
  tok_embed (V, D)
  layers/ln1/..., layers/attn/{wq,wk,wv,wo}, layers/ln2/...,
  layers/mlp/{wi,wg,wo} or layers/moe/{gate,wi,wg,wo}
  final_ln/..., head/w (D, V)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.config import LMConfig
from repro.models import layers as L
from repro.distributed import constrain


def init(rng, cfg: LMConfig):
    dt = L.compute_dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    emb = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
           * 0.02).astype(dt)

    def layer_init(rng):
        k1, k2 = jax.random.split(rng)
        p = {
            "ln1": L.norm_init(cfg.norm, cfg.d_model),
            "attn": L.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, dt),
            "ln2": L.norm_init(cfg.norm, cfg.d_model),
        }
        if cfg.moe:
            p["moe"] = L.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
        else:
            p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dt)
        return p

    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    stacked = jax.vmap(layer_init)(layer_keys)
    params = {
        "tok_embed": emb,
        "layers": stacked,
        "final_ln": L.norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(ks[2], cfg.d_model, cfg.vocab_size,
                                            dtype=dt)}
    return params


def _residual_kind(cfg: LMConfig, mesh, seq_len: int) -> str:
    """Residual-stream layout: sequence-parallel ("hidden_sp") shards the
    carry (and the remat-saved per-layer stack) over the model axis too —
    16x less activation memory per chip; XLA inserts the all-gather before
    attention and the reduce-scatter after (standard SP)."""
    if cfg.act_sharding == "dp" or mesh is None:
        return "hidden"
    if cfg.act_sharding == "sp":
        return "hidden_sp"
    m = mesh.shape.get("model", 1)
    dp_total = 1
    for name in ("pod", "data"):
        dp_total *= mesh.shape.get(name, 1)
    if dp_total >= 32:
        # enough DP shards: per-chip activations are already small, and SP's
        # sp->heads resharding costs more than it saves (multi-pod meshes)
        return "hidden"
    return "hidden_sp" if seq_len % m == 0 and seq_len >= m else "hidden"


def _layer(cfg: LMConfig, mesh, p, x, positions, res_kind: str):
    h = L.apply_norm(cfg.norm, p["ln1"], x)
    if res_kind == "hidden_sp":
        # Megatron-SP: explicit all-gather point at the attention input —
        # without it the partitioner faces an sp->heads reshard of k/v and
        # falls back to full rematerialization (replicates the activations).
        h = constrain(h, mesh, "hidden")
    h = L.multihead_attention(
        p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        causal=True, window=cfg.window if cfg.attention == "window" else 0,
        positions=positions, theta=cfg.rope_theta, mesh=mesh,
        out_kind=res_kind, q_chunk=getattr(cfg, "attn_q_chunk", 4096),
        scores_dtype=L.compute_dtype(
            getattr(cfg, "attn_scores_dtype", "f32")
            .replace("f32", "float32").replace("bf16", "bfloat16")))
    x = constrain(x + h, mesh, res_kind)
    h = L.apply_norm(cfg.norm, p["ln2"], x)
    if res_kind == "hidden_sp":
        h = constrain(h, mesh, "hidden")   # SP all-gather before wi
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        h, aux = L.moe(p["moe"], h, n_experts=cfg.n_experts,
                       top_k=cfg.moe_top_k, group_size=cfg.moe_group_size,
                       capacity_factor=cfg.moe_capacity_factor, mesh=mesh,
                       out_kind=res_kind,
                       dispatch=getattr(cfg, "moe_dispatch", "einsum"))
    else:
        h = L.mlp(p["mlp"], h, cfg.mlp_act, mesh=mesh, out_kind=res_kind)
    x = constrain(x + h, mesh, res_kind)
    return x, aux


def forward(params, tokens, cfg: LMConfig, mesh=None,
            last_logit_only: bool = False):
    """tokens: (B, S) int32 -> (logits (B,S,V) fp32, aux_loss).

    ``last_logit_only`` (prefill serving): the vocab projection — the
    largest single matmul — runs on the final position only.
    """
    dt = L.compute_dtype(cfg.dtype)
    B, S = tokens.shape
    res_kind = _residual_kind(cfg, mesh, S)
    x = jnp.take(params["tok_embed"], tokens, axis=0).astype(dt)
    x = constrain(x, mesh, res_kind)
    positions = jnp.arange(S)[None, :]

    def body(x, p):
        return _layer(cfg, mesh, p, x, positions, res_kind)

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy(cfg.remat_policy))

    if cfg.scan_layers:
        x, auxs = lax.scan(body, x, params["layers"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = body(x, p)
            aux = aux + a

    x = L.apply_norm(cfg.norm, params["final_ln"], x)
    if last_logit_only:
        x = x[:, -1:, :]
    head_w = params["tok_embed"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, head_w,
                        preferred_element_type=jnp.float32)
    return constrain(logits, mesh, "logits"), aux


def loss_fn(params, tokens, labels, cfg: LMConfig, mesh=None,
            aux_weight: float = 0.01):
    logits, aux = forward(params, tokens, cfg, mesh=mesh)
    # One-hot contraction instead of take_along_axis: with the vocab dim
    # sharded over "model", a gather would force an all-gather of the full
    # (B, S, V) logits; the einsum contracts locally + a small all-reduce.
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - picked
    loss = jnp.mean(nll) + aux_weight * aux
    return loss, {"nll": jnp.mean(nll), "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or L.compute_dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (cfg.n_layers, batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_step(params, cache, token, cache_len, cfg: LMConfig, mesh=None):
    """One decode step. token: (B, 1) int32; cache_len: scalar int32.

    Returns (logits (B, 1, V), new_cache). Attention is linear in cache
    length; the per-layer cache update is scanned so the HLO stays small.
    """
    dt = L.compute_dtype(cfg.dtype)
    x = jnp.take(params["tok_embed"], token, axis=0).astype(dt)

    def layer_fn(x, p, ck, cv):
        h = L.apply_norm(cfg.norm, p["ln1"], x)
        h, ck, cv = L.decode_attention(
            p["attn"], h, ck, cv, cache_len, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, theta=cfg.rope_theta,
            window=cfg.window if cfg.attention == "window" else 0, mesh=mesh)
        x = x + h
        h = L.apply_norm(cfg.norm, p["ln2"], x)
        if cfg.moe:
            h, _ = L.moe(p["moe"], h, n_experts=cfg.n_experts,
                         top_k=cfg.moe_top_k, group_size=cfg.moe_group_size,
                         capacity_factor=cfg.moe_capacity_factor, mesh=mesh,
                         dispatch=getattr(cfg, "moe_dispatch", "einsum"))
        else:
            h = L.mlp(p["mlp"], h, cfg.mlp_act, mesh=mesh)
        return x + h, ck, cv

    if cfg.scan_layers:
        # The stacked cache rides the scan CARRY with per-layer
        # dynamic-update-slice: XLA keeps loop carries in place, so the
        # multi-hundred-GB cache is updated without a second buffer
        # (scanning it as xs/ys would double-buffer it).
        def body(carry, inp):
            x, ck_all, cv_all = carry
            p, i = inp
            ck = jax.tree.map(lambda a: a[0],
                              lax.dynamic_slice_in_dim(ck_all, i, 1, 0))
            cv = jax.tree.map(lambda a: a[0],
                              lax.dynamic_slice_in_dim(cv_all, i, 1, 0))
            x, ck, cv = layer_fn(x, p, ck, cv)
            ck_all = lax.dynamic_update_slice_in_dim(
                ck_all, ck[None].astype(ck_all.dtype), i, 0)
            cv_all = lax.dynamic_update_slice_in_dim(
                cv_all, cv[None].astype(cv_all.dtype), i, 0)
            return (x, ck_all, cv_all), ()

        (x, ks, vs), _ = lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"k": ks, "v": vs}
    else:
        ks, vs = cache["k"], cache["v"]
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            x, ck, cv = layer_fn(x, p, ks[i], vs[i])
            ks = ks.at[i].set(ck)
            vs = vs.at[i].set(cv)
        new_cache = {"k": ks, "v": vs}

    x = L.apply_norm(cfg.norm, params["final_ln"], x)
    head_w = params["tok_embed"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, head_w,
                        preferred_element_type=jnp.float32)
    return logits, new_cache


def prefill(params, tokens, cfg: LMConfig, mesh=None):
    """Prefill forward (no cache write-back; returns last-position logits)."""
    logits, _ = forward(params, tokens, cfg, mesh=mesh,
                        last_logit_only=True)
    return logits
