"""Per-tensor sharding rules: FSDP + TP + EP + SP on a (pod, data, model) mesh.

Parameters are named with '/'-joined paths; rules are keyed on the leaf name
and tensor rank. The same rules serve the single-pod ("data", "model") and
multi-pod ("pod", "data", "model") meshes: the batch / FSDP axis is
``("pod", "data")`` when a pod axis exists.

Design (see DESIGN.md §5):
  * TP  : attention heads, MLP hidden, vocab        -> "model"
  * EP  : MoE expert dim                            -> "model"
  * FSDP: the non-TP major dim of every weight      -> "data" (+"pod")
  * DP  : batch                                     -> ("pod","data")
  * SP  : long-context KV cache sequence dim        -> "model" (when kv heads
          cannot fill the model axis, e.g. MQA)
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axes(mesh: Mesh):
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    mp = "model" if "model" in names else None
    return dp, mp


def _divisible(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None or dim <= 0:
        return False
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
    else:
        size = mesh.shape[axis]
    return dim % size == 0


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (regex on the param path, spec builder). Leading scan axis (layers) is
# handled by prepending None when the tensor has the extra rank.
# Builders receive (shape, dp, mp) for *unstacked* rank.
_RULES = [
    # token / positional embeddings: vocab|positions over model, d over fsdp
    (r"tok_embed$",        lambda s: ("model", "data")),
    (r"pos_embed$",        lambda s: (None, "data")),
    (r"label_embed$",      lambda s: (None, "data")),
    # attention projections
    (r"attn/wq$",          lambda s: ("data", "model")),
    (r"attn/wk$",          lambda s: ("data", "model")),
    (r"attn/wv$",          lambda s: ("data", "model")),
    (r"attn/wo$",          lambda s: ("model", "data")),
    # dense mlp
    (r"mlp/w(i|g)$",       lambda s: ("data", "model")),
    (r"mlp/wo$",           lambda s: ("model", "data")),
    # MoE: experts over model (EP), d_model over fsdp
    (r"moe/gate$",         lambda s: ("data", None)),
    (r"moe/w(i|g)$",       lambda s: ("model", "data", None)),
    (r"moe/wo$",           lambda s: ("model", None, "data")),
    # output head
    (r"head/w$",           lambda s: ("data", "model")),
    (r"head/b$",           lambda s: ("model",)),
    # DiT conditioning / modulation
    (r"adaln/w$",          lambda s: ("data", "model")),
    (r"adaln/b$",          lambda s: ("model",)),
    (r"t_embed/w\d$",      lambda s: ("data", "model") if s[-1] > s[0] else ("model", "data")),
    # patchify / conv stems: shard output channels over model
    (r"patch/w$",          lambda s: (None, None, "data", "model")),
    (r"patch/b$",          lambda s: ("model",)),
    (r"conv/w$",           lambda s: (None, None, "data", "model")),
    (r"dwconv/w$",         lambda s: (None, None, None, "model")),
    # norms / scalars / biases: replicated
    (r"(scale|bias|b|cls|dist)$", lambda s: tuple(None for _ in s)),
]


def spec_for_param(path: str, shape: tuple, mesh: Mesh,
                   stacked: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    ``stacked`` indicates a leading layer-stacking axis (scan over layers).
    Falls back to replicated when no rule matches or a dim is indivisible.
    """
    dp, mp = mesh_axes(mesh)
    rank = len(shape) - (1 if stacked else 0)
    base_shape = shape[1:] if stacked else shape
    spec: Optional[tuple] = None
    for pat, builder in _RULES:
        if re.search(pat, path):
            cand = builder(base_shape)
            if len(cand) == rank:
                spec = cand
                break
    if spec is None:
        spec = tuple(None for _ in range(rank))
    # map logical names to mesh axes, drop indivisible axes
    out = []
    for dim, ax in zip(base_shape, spec):
        if ax == "data":
            ax = dp
        elif ax == "model":
            ax = mp
        if ax is not None and not _divisible(dim, mesh, ax):
            ax = None
        out.append(ax)
    if stacked:
        out = [None] + out
    return P(*out)


def param_shardings(params_shape: Any, mesh: Mesh, scan_layers: bool = True):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStruct."""

    def visit(path, leaf):
        pstr = "/".join(_key_str(k) for k in path)
        stacked = scan_layers and "/layers/" in ("/" + pstr + "/")
        return NamedSharding(mesh, spec_for_param(pstr, leaf.shape, mesh,
                                                  stacked=stacked))

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ---------------------------------------------------------------------------
# Activation rules
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, extra_rank: int = 1) -> P:
    dp, _ = mesh_axes(mesh)
    return P(dp, *[None] * extra_rank)


def act_spec(mesh: Mesh, kind: str) -> P:
    """Common activation shardings."""
    dp, mp = mesh_axes(mesh)
    if kind == "tokens":          # (B, S)
        return P(dp, None)
    if kind == "hidden":          # (B, S, D)
        return P(dp, None, None)
    if kind == "hidden_sp":       # (B, S, D) sequence-parallel region
        return P(dp, mp, None)
    if kind == "ffn":             # (B, S, F) TP-sharded hidden/head width
        return P(dp, None, mp)
    if kind == "heads":           # (B, S, H, dh)
        return P(dp, None, mp, None)
    if kind == "scores":          # (B, H, Sq, Sk)
        return P(dp, mp, None, None)
    if kind == "kv_cache":        # (B, S, KV, dh): SP over sequence
        return P(dp, mp, None, None)
    if kind == "kv_cache_heads":  # (B, S, KV, dh): shard kv heads
        return P(dp, None, mp, None)
    if kind == "logits":          # (B, S, V)
        return P(dp, None, mp)
    if kind == "images":          # (B, H, W, C)
        return P(dp, None, None, None)
    if kind == "replicated":
        return P()
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Sharded ingest rules (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The multi-stream ingest megastep stacks per-stream tensors along a
# leading STREAM axis and shards only that axis over the 1-D ("data",)
# ingest mesh (`launch.mesh.make_ingest_mesh`). Every device owns a
# contiguous device-major block of stream slots — its streams' ClusterState
# rows live on it for the whole run, so the hot path moves no cluster
# state between devices; only the small per-stream (j, matched, top-K)
# rows cross to the host at the designed fold boundary.


def stream_spec(mesh: Mesh, extra_rank: int) -> P:
    """P(data, None * extra_rank) for a stream-major stacked tensor:
    (S, ...) with S = streams padded to a multiple of the mesh size."""
    dp, _ = mesh_axes(mesh)
    return P(dp, *[None] * extra_rank)


def ingest_batch_spec(mesh: Mesh) -> P:
    """Stacked bucket-padded crop batch (S, B, R, R, 3)."""
    return stream_spec(mesh, 4)


def cluster_state_specs(mesh: Mesh) -> tuple:
    """Per-stream ClusterState placement, stream-major stacked:
    centroids (S, M, D), counts (S, M), n (S,)."""
    return (stream_spec(mesh, 2), stream_spec(mesh, 1), stream_spec(mesh, 0))


def ingest_shardings(mesh: Mesh) -> dict:
    """The NamedShardings the sharded ingest pipeline places data with —
    built ONCE at pipeline construction (never per step; the per-step
    rebuild was the old MultiStreamRunner hot-path bug)."""
    cen, cnt, n = cluster_state_specs(mesh)
    return {
        "crops": NamedSharding(mesh, ingest_batch_spec(mesh)),
        "n_real": NamedSharding(mesh, stream_spec(mesh, 0)),
        "rows": NamedSharding(mesh, stream_spec(mesh, 1)),      # (S, B)
        "centroids": NamedSharding(mesh, cen),
        "counts": NamedSharding(mesh, cnt),
        "n": NamedSharding(mesh, n),
        "replicated": NamedSharding(mesh, P()),
    }


def constrain(x, mesh: Optional[Mesh], kind: str):
    """with_sharding_constraint if a mesh is given, else no-op (CPU tests)."""
    if mesh is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, act_spec(mesh, kind)))
    except (ValueError, RuntimeError):
        return x
