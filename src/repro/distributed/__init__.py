from repro.distributed.sharding import (  # noqa: F401
    act_spec,
    batch_spec,
    constrain,
    mesh_axes,
    param_shardings,
    spec_for_param,
)
