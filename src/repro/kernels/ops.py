"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
they run in interpret mode, which executes the kernel body with jax ops —
bit-for-bit the same BlockSpec tiling logic, validated against ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import centroid_assign as _ca
from repro.kernels import flash_attention as _fa
from repro.kernels import topk_mask as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def centroid_assign(feats, centroids, *, bb: int | None = None,
                    bm: int | None = None,
                    threshold: float | None = None):
    """(B, D), (M, D) -> (min squared-L2 (B,), argmin (B,)).

    With ``threshold`` set, also returns the fused ``matched (B,) bool``
    mask (``min_d2 <= threshold**2``), emitted by the kernel itself.

    Default tiles: 128x128 on TPU (sized for VMEM); in interpret mode the
    tiles cover the whole problem (the per-grid-step interpreter dispatch
    dominates there, and "VMEM" blocks are ordinary host arrays)."""
    interp = _interpret()
    if bb is None:
        bb = 4096 if interp else 128
    if bm is None:
        bm = 1024 if interp else 128
    return _ca.centroid_assign(feats, centroids, bb=bb, bm=bm,
                               threshold=threshold, interpret=interp)


def topk(logits, k: int, *, bb: int = 128):
    """(B, C) -> (values (B, k) f32, indices (B, k) i32), descending.

    Padding/trim contract (explicit — tiny batches included): the row
    tile is ``min(bb, max(8, B))``, so a batch smaller than 8 rows still
    runs one >= 8-row tile; B is padded up to a tile multiple and C up to
    a 128-lane multiple with ``-3e38`` sentinels, and outputs are trimmed
    back to ``[:B]``. Inputs must be > ``-3e38`` — the kernel reuses that
    sentinel to mask already-extracted entries, so a row containing
    ``-inf`` (e.g. masked log-probs) ties with the padding and yields
    duplicate indices; class probabilities/logits are always in range.
    For in-range inputs sentinel columns can never be selected because
    ``k <= C``; ``k > C`` (or ``k < 1``) raises — there are only C real
    classes to rank. ``B == 0`` short-circuits to empty outputs.
    """
    B, C = logits.shape
    if not 1 <= k <= C:
        raise ValueError(
            f"k must be in [1, C={C}], got {k}: the top-k of a (B, {C}) "
            f"logit matrix has at most {C} entries per row")
    if B == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    return _tk.topk(logits, k, bb=bb, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """q, k, v: (B, S, H, dh) -> (B, S, H, dh) fused attention."""
    B, S, H, dh = q.shape
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                              interpret=_interpret())
    return out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
