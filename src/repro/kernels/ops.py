"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
they run in interpret mode, which executes the kernel body with jax ops —
bit-for-bit the same BlockSpec tiling logic, validated against ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import centroid_assign as _ca
from repro.kernels import flash_attention as _fa
from repro.kernels import topk_mask as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def centroid_assign(feats, centroids, *, bb: int | None = None,
                    bm: int | None = None,
                    threshold: float | None = None):
    """(B, D), (M, D) -> (min squared-L2 (B,), argmin (B,)).

    With ``threshold`` set, also returns the fused ``matched (B,) bool``
    mask (``min_d2 <= threshold**2``), emitted by the kernel itself.

    Default tiles: 128x128 on TPU (sized for VMEM); in interpret mode the
    tiles cover the whole problem (the per-grid-step interpreter dispatch
    dominates there, and "VMEM" blocks are ordinary host arrays)."""
    interp = _interpret()
    if bb is None:
        bb = 4096 if interp else 128
    if bm is None:
        bm = 1024 if interp else 128
    return _ca.centroid_assign(feats, centroids, bb=bb, bm=bm,
                               threshold=threshold, interpret=interp)


def topk(logits, k: int, *, bb: int = 128):
    """(B, C) -> (values (B, k), indices (B, k)) in descending order."""
    return _tk.topk(logits, k, bb=bb, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """q, k, v: (B, S, H, dh) -> (B, S, H, dh) fused attention."""
    B, S, H, dh = q.shape
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                              interpret=_interpret())
    return out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
