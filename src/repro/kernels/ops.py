"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
they run in interpret mode, which executes the kernel body with jax ops —
bit-for-bit the same BlockSpec tiling logic, validated against ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import centroid_assign as _ca
from repro.kernels import dequant_topk as _dq
from repro.kernels import flash_attention as _fa
from repro.kernels import frame_gate as _fg
from repro.kernels import pixel_diff as _pd
from repro.kernels import topk_mask as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def centroid_assign(feats, centroids, *, bb: int | None = None,
                    bm: int | None = None,
                    threshold: float | None = None):
    """(B, D), (M, D) -> (min squared-L2 (B,), argmin (B,)).

    With ``threshold`` set, also returns the fused ``matched (B,) bool``
    mask (``min_d2 <= threshold**2``), emitted by the kernel itself.

    Default tiles: 128x128 on TPU (sized for VMEM); in interpret mode the
    tiles cover the whole problem (the per-grid-step interpreter dispatch
    dominates there, and "VMEM" blocks are ordinary host arrays)."""
    interp = _interpret()
    if bb is None:
        bb = 4096 if interp else 128
    if bm is None:
        bm = 1024 if interp else 128
    return _ca.centroid_assign(feats, centroids, bb=bb, bm=bm,
                               threshold=threshold, interpret=interp)


def topk(logits, k: int, *, bb: int = 128):
    """(B, C) -> (values (B, k) f32, indices (B, k) i32), descending.

    Padding/trim contract (explicit — tiny batches included): the row
    tile is ``min(bb, max(8, B))``, so a batch smaller than 8 rows still
    runs one >= 8-row tile; B is padded up to a tile multiple and C up to
    a 128-lane multiple with ``-3e38`` sentinels, and outputs are trimmed
    back to ``[:B]``. Inputs must be > ``-3e38`` — the kernel reuses that
    sentinel to mask already-extracted entries, so a row containing
    ``-inf`` (e.g. masked log-probs) ties with the padding and yields
    duplicate indices; class probabilities/logits are always in range.
    For in-range inputs sentinel columns can never be selected because
    ``k <= C``; ``k > C`` (or ``k < 1``) raises — there are only C real
    classes to rank. ``B == 0`` short-circuits to empty outputs.
    """
    B, C = logits.shape
    if not 1 <= k <= C:
        raise ValueError(
            f"k must be in [1, C={C}], got {k}: the top-k of a (B, {C}) "
            f"logit matrix has at most {C} entries per row")
    if B == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    return _tk.topk(logits, k, bb=bb, interpret=_interpret())


def dequant_topk(q, scales, k: int, *, global_scale=1.0, bm: int = 128):
    """q (M, C) int8/uint8, scales (M,) f32 ->
    (values (M, k) f32, indices (M, k) i32), descending.

    Fused dequant + top-k over quantized rows: ``values`` are the top-k of
    ``q * (global_scale * scales)[:, None]`` with ties to the LOWEST
    column index — the archive's lazy rank path over v4 shards, never
    materializing an fp32 copy of the probability matrix.
    ``global_scale`` is the format-level multiplier (SMEM operand, so
    per-shard variation never recompiles); ``scales`` are the stored
    per-row scales and must be positive.

    Pad/trim contract (explicit — tiny shard tails included): the row
    tile is ``min(bm, max(8, M))``, M is padded to a tile multiple and C
    to a 128-lane multiple with the input dtype's minimum (int8 pads at
    -128, strictly below the quantizer's range; uint8 pads at 0, which
    only ties and pad columns lose every tie-break), and outputs are
    trimmed back to ``[:M]``. ``k > C`` (or ``k < 1``) raises; ``M == 0``
    short-circuits to empty outputs. Float inputs raise — dequantizing an
    already-dequantized matrix is a bug, use ``topk`` instead.
    """
    M, C = q.shape
    if not 1 <= k <= C:
        raise ValueError(
            f"k must be in [1, C={C}], got {k}: the top-k of a (M, {C}) "
            f"quantized matrix has at most {C} entries per row")
    if not jnp.issubdtype(jnp.asarray(q).dtype, jnp.integer):
        raise ValueError(
            f"dequant_topk expects integer quantized rows, got "
            f"{jnp.asarray(q).dtype}; for fp32 inputs use topk")
    if scales.shape != (M,):
        raise ValueError(
            f"scales must be ({M},) to match q's rows, got {scales.shape}")
    if M == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    sg = jnp.asarray(global_scale, jnp.float32).reshape(1)
    return _dq.dequant_topk(sg, jnp.asarray(q), jnp.asarray(scales), k,
                            bm=bm, interpret=_interpret())


def pixel_match(a, b, threshold, *, ba: int | None = None,
                bn: int | None = None):
    """(Na, D), (Nb, D) -> (match (Na,) i32, min_d (Na,) f32).

    ``match[i]`` is the lowest index j minimizing ``mean |a_i - b_j|``
    when that minimum is STRICTLY below ``threshold`` (a diff exactly at
    the threshold does not match), else -1 — the §4.2 pixel-differencing
    decision, blocked so the (Na, Nb, D) broadcast never materializes.

    Pad/trim contract: Na and Nb are padded to tile multiples — reference
    pad rows are ``3e18`` sentinels whose mean-abs diff can never win the
    online argmin, crop pad rows compute garbage trimmed by ``[:Na]``.
    ``threshold`` may be a float or traced scalar (SMEM operand — sweeps
    never recompile). ``Na == 0`` or ``Nb == 0`` short-circuits to all
    ``-1`` (no references means nothing matches, mirroring
    ``data.bgsub.pixel_difference``).
    """
    Na = a.shape[0]
    if Na == 0 or b.shape[0] == 0:
        return (jnp.full((Na,), -1, jnp.int32),
                jnp.full((Na,), jnp.inf, jnp.float32))
    interp = _interpret()
    if ba is None:
        ba = 4096 if interp else 128
    if bn is None:
        bn = 1024 if interp else 128
    thr = jnp.asarray(threshold, jnp.float32).reshape(1)
    return _pd.pixel_match(thr, a, b, ba=ba, bn=bn, interpret=interp)


def motion_gate(frame, bg, alpha, threshold, *, tile: int = 8,
                bh: int | None = None):
    """frame/bg (H, W, 3) -> (new_bg (H, W, 3) f32, tiles (ty, tx) f32,
    hot (ty, tx) bool) where ty = H // tile, tx = W // tile.

    One fused pass per frame: EMA background update (``bg' = (1-α)bg +
    αf`` over EVERY pixel, remainder rows/cols included), channel-mean
    abs diff, (tile, tile) tile means over complete tiles only, and the
    strict ``tiles > threshold`` hot mask. H is padded to a row-block
    multiple and W to a tile multiple with zeros; padded EMA rows and
    partial-tile columns are trimmed from the outputs. Frames smaller
    than one tile (ty == 0 or tx == 0) short-circuit: the background
    still updates, the tile grid is empty.

    ``alpha``/``threshold`` may be floats or traced scalars (SMEM
    operands — per-stream gate tuning never recompiles).
    """
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    H, W = frame.shape[:2]
    ty, tx = H // tile, W // tile
    at = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(threshold, jnp.float32)])
    if ty == 0 or tx == 0:
        a = at[0]
        new_bg = ((1.0 - a) * bg.astype(jnp.float32)
                  + a * frame.astype(jnp.float32))
        return (new_bg, jnp.zeros((ty, tx), jnp.float32),
                jnp.zeros((ty, tx), bool))
    interp = _interpret()
    if bh is None:
        # interpret mode: one row block covers the frame (per-grid-step
        # interpreter dispatch dominates); TPU: 64-row blocks
        bh = H if interp else 64
    new_bg, tiles, hot = _fg.motion_gate(
        at, frame.reshape(H, W * 3), bg.reshape(H, W * 3),
        tile=tile, bh=bh, interpret=interp)
    return (new_bg[:H, : W * 3].reshape(H, W, 3),
            tiles[:ty, :tx], hot[:ty, :tx] != 0)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """q, k, v: (B, S, H, dh) -> (B, S, H, dh) fused attention."""
    B, S, H, dh = q.shape
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                              interpret=_interpret())
    return out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
