"""Pallas TPU kernel: nearest-centroid assignment for Focus clustering.

Computes, for a batch of feature vectors, the squared L2 distance to the
nearest of M centroids and its index — the inner loop of the paper's O(M·n)
incremental clustering (§4.2), re-tiled for the TPU:

  * the -2·f·Cᵀ cross term runs on the MXU (jnp.dot inside the kernel);
  * feature tiles (BB, D) and centroid tiles (BM, D) live in VMEM;
  * the grid's centroid axis revisits the same output block, carrying a
    running (min, argmin) in VMEM scratch — an online reduction, so the
    full (B, M) distance matrix is never materialized in HBM.

VMEM budget (BB=128, BM=128, D<=512, fp32):
  feats 128·512·4 = 256 KiB, cents 256 KiB, scores 64 KiB, scratch ~1 KiB
  << 16 MiB/core on v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(f_ref, c_ref, min_ref, arg_ref, *, bm: int, n_m: int):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    f = f_ref[...].astype(jnp.float32)          # (BB, D)
    c = c_ref[...].astype(jnp.float32)          # (BM, D)
    # d2(i, j) = |f_i|^2 - 2 f_i . c_j + |c_j|^2 ; the |f|^2 term is constant
    # per row and irrelevant to argmin, but kept so min_d2 is a true distance.
    cross = jax.lax.dot_general(
        f, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BB, BM) on the MXU
    d2 = (jnp.sum(f * f, axis=1, keepdims=True)
          - 2.0 * cross
          + jnp.sum(c * c, axis=1)[None, :])

    local_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)
    local_min = jnp.min(d2, axis=1)
    better = local_min < min_ref[...]
    min_ref[...] = jnp.where(better, local_min, min_ref[...])
    arg_ref[...] = jnp.where(better, local_arg + mi * bm, arg_ref[...])


@functools.partial(jax.jit, static_argnames=("bb", "bm", "interpret"))
def centroid_assign(feats, centroids, *, bb: int = 128, bm: int = 128,
                    interpret: bool = True):
    """feats (B, D), centroids (M, D) -> (min_d2 (B,) f32, argmin (B,) i32).

    B and M are padded to tile multiples; D is used whole (feature dims are
    128/256/512 in Focus configs — VMEM-resident).
    """
    B, D = feats.shape
    M, _ = centroids.shape
    bb = min(bb, max(8, B))
    bm = min(bm, max(8, M))
    Bp = (B + bb - 1) // bb * bb
    Mp = (M + bm - 1) // bm * bm
    f = jnp.pad(feats.astype(jnp.float32), ((0, Bp - B), (0, 0)))
    # pad centroids with +inf-distance rows (large values)
    c = jnp.pad(centroids.astype(jnp.float32), ((0, Mp - M), (0, 0)),
                constant_values=3e18)
    n_m = Mp // bm

    grid = (Bp // bb, n_m)
    min_d2, arg = pl.pallas_call(
        functools.partial(_kernel, bm=bm, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, D), lambda bi, mi: (bi, 0)),
            pl.BlockSpec((bm, D), lambda bi, mi: (mi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda bi, mi: (bi,)),
            pl.BlockSpec((bb,), lambda bi, mi: (bi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=interpret,
    )(f, c)
    return min_d2[:B], arg[:B]
