"""Pallas TPU kernel: nearest-centroid assignment for Focus clustering.

Computes, for a batch of feature vectors, the squared L2 distance to the
nearest of M centroids and its index — the inner loop of the paper's O(M·n)
incremental clustering (§4.2), re-tiled for the TPU:

  * the -2·f·Cᵀ cross term runs on the MXU (jnp.dot inside the kernel);
  * feature tiles (BB, D) and centroid tiles (BM, D) live in VMEM;
  * the grid's centroid axis revisits the same output block, carrying a
    running (min, argmin) in VMEM scratch — an online reduction, so the
    full (B, M) distance matrix is never materialized in HBM;
  * the per-row |f|² term is computed ONCE per feature tile (mi == 0) into
    VMEM scratch, not per centroid tile: the online argmin runs on the
    partial score |c|² - 2·f·c (|f|² is row-constant, so argmin is
    unchanged) and |f|² is added back in the final grid step so min_d2 is
    a true squared distance;
  * an optional fused threshold emits the ``matched = d2 <= T²`` mask
    directly from the kernel — one pass, no separate host-side compare.

VMEM budget (BB=128, BM=128, D<=512, fp32):
  feats 128·512·4 = 256 KiB, cents 256 KiB, scores 64 KiB, scratch ~1 KiB
  << 16 MiB/core on v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(t2_ref, f_ref, c_ref, min_ref, arg_ref, match_ref, fnorm_ref, *,
            bm: int, n_m: int):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)
        f0 = f_ref[...].astype(jnp.float32)
        fnorm_ref[...] = jnp.sum(f0 * f0, axis=1)

    f = f_ref[...].astype(jnp.float32)          # (BB, D)
    c = c_ref[...].astype(jnp.float32)          # (BM, D)
    # partial score |c_j|^2 - 2 f_i . c_j: the row-constant |f_i|^2 term is
    # hoisted to scratch (computed once at mi == 0) and added back at the
    # last grid step — argmin over j is unaffected by a row-constant shift.
    cross = jax.lax.dot_general(
        f, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BB, BM) on the MXU
    part = jnp.sum(c * c, axis=1)[None, :] - 2.0 * cross

    local_arg = jnp.argmin(part, axis=1).astype(jnp.int32)
    local_min = jnp.min(part, axis=1)
    better = local_min < min_ref[...]
    min_ref[...] = jnp.where(better, local_min, min_ref[...])
    arg_ref[...] = jnp.where(better, local_arg + mi * bm, arg_ref[...])

    @pl.when(mi == n_m - 1)
    def _finalize():
        d2 = min_ref[...] + fnorm_ref[...]
        min_ref[...] = d2
        match_ref[...] = (d2 <= t2_ref[0]).astype(jnp.int32)


def centroid_assign(feats, centroids, *, bb: int = 128, bm: int = 128,
                    threshold=None, interpret: bool = True):
    """feats (B, D), centroids (M, D) -> (min_d2 (B,) f32, argmin (B,) i32)
    or, with ``threshold``, (min_d2, argmin, matched (B,) bool) where
    ``matched = min_d2 <= threshold**2`` is fused into the kernel's final
    grid step.

    ``threshold`` may be a python float or a traced scalar — it enters the
    kernel as an SMEM operand, so sweeping thresholds does NOT recompile.

    B and M are padded to tile multiples; D is used whole (feature dims are
    128/256/512 in Focus configs — VMEM-resident).
    """
    t2 = (jnp.full((1,), jnp.inf, jnp.float32) if threshold is None
          else jnp.asarray(threshold, jnp.float32).reshape(1) ** 2)
    out = _assign_impl(t2, feats, centroids, bb=bb, bm=bm,
                       interpret=interpret)
    return out if threshold is not None else out[:2]


@functools.partial(jax.jit, static_argnames=("bb", "bm", "interpret"))
def _assign_impl(t2, feats, centroids, *, bb: int, bm: int,
                 interpret: bool):
    B, D = feats.shape
    M, _ = centroids.shape
    bb = min(bb, max(8, B))
    bm = min(bm, max(8, M))
    Bp = (B + bb - 1) // bb * bb
    Mp = (M + bm - 1) // bm * bm
    f = jnp.pad(feats.astype(jnp.float32), ((0, Bp - B), (0, 0)))
    # pad centroids with +inf-distance rows (large values)
    c = jnp.pad(centroids.astype(jnp.float32), ((0, Mp - M), (0, 0)),
                constant_values=3e18)
    n_m = Mp // bm

    grid = (Bp // bb, n_m)
    min_d2, arg, match = pl.pallas_call(
        functools.partial(_kernel, bm=bm, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, mi: (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bb, D), lambda bi, mi: (bi, 0)),
            pl.BlockSpec((bm, D), lambda bi, mi: (mi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda bi, mi: (bi,)),
            pl.BlockSpec((bb,), lambda bi, mi: (bi,)),
            pl.BlockSpec((bb,), lambda bi, mi: (bi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb,), jnp.float32),     # per-row |f|^2, computed once
        ],
        interpret=interpret,
    )(t2, f, c)
    return min_d2[:B], arg[:B], match[:B] != 0
