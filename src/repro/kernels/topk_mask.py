"""Pallas TPU kernel: top-K extraction over class logits (Focus top-K index).

The ingest index stores each object's top-K cheap-CNN classes (paper §4.1).
K is small (2–200) relative to C (~1000), so the kernel holds a (BB, C)
logit tile in VMEM and performs K online max-extract+mask passes on the VPU —
no full sort, no HBM round-trips between passes.

VMEM budget (BB=128, C=1024 padded, fp32): tile 512 KiB + outputs 200 KiB
<< 16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -3e38


def _kernel(x_ref, v_ref, i_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)              # (BB, C)
    C = x.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    def body(t, carry):
        x, = carry
        m = jnp.max(x, axis=1)                      # (BB,)
        # smallest column index attaining the max (ties -> lowest index)
        is_max = x == m[:, None]
        idx = jnp.min(jnp.where(is_max, cols, C), axis=1).astype(jnp.int32)
        v_ref[:, t] = m
        i_ref[:, t] = idx
        x = jnp.where(cols == idx[:, None], _NEG, x)
        return (x,)

    jax.lax.fori_loop(0, k, body, (x,))


@functools.partial(jax.jit, static_argnames=("k", "bb", "interpret"))
def topk(logits, k: int, *, bb: int = 128, interpret: bool = True):
    """logits (B, C) -> (values (B, k) f32, indices (B, k) i32), descending.

    Tiling: the row tile is clamped to ``min(bb, max(8, B))`` — a batch
    under 8 rows still runs one 8-row tile (the VPU floor), and ``bb``
    larger than the batch degrades to a single tile rather than an
    oversized grid. B is padded to a tile multiple and C to a 128-lane
    multiple with ``_NEG`` sentinel entries; padded rows compute garbage
    that is trimmed by the final ``[:B]``, and padded columns lose every
    max comparison for ``k <= C`` real passes (``kernels/ops.topk``
    validates ``1 <= k <= C``). Inputs must be > ``_NEG`` — extraction
    masks taken entries to the same sentinel, so values at or below it
    (``-inf``) tie with padding and break the unique-index guarantee.
    """
    B, C = logits.shape
    bb = min(bb, max(8, B))
    Bp = (B + bb - 1) // bb * bb
    Cp = (C + 127) // 128 * 128
    x = jnp.pad(logits.astype(jnp.float32), ((0, Bp - B), (0, Cp - C)),
                constant_values=_NEG)

    vals, idxs = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(Bp // bb,),
        in_specs=[pl.BlockSpec((bb, Cp), lambda bi: (bi, 0))],
        out_specs=[
            pl.BlockSpec((bb, k), lambda bi: (bi, 0)),
            pl.BlockSpec((bb, k), lambda bi: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return vals[:B], idxs[:B]
