"""Pallas TPU kernel: blockwise fused attention (online softmax).

Used by the backbone transformers (GT-CNN / LM archs). KV tiles stream
HBM->VMEM along the innermost grid axis; running (max, denom, acc) live in
VMEM scratch, so the (S, S) score matrix never exists in HBM — the memory
term drops from O(S^2) to O(S·dh).

Grid: (B·H, S/bq, S/bk); the kv axis is innermost and revisits the same
output block, accumulating online-softmax state. Causal tiles strictly above
the diagonal are skipped via pl.when (half the FLOPs at no accuracy cost).

VMEM budget (bq=bk=128, dh=128, fp32): q/k/v tiles 3·64 KiB, acc 64 KiB,
scores 64 KiB, m/l 1 KiB << 16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int,
            s_actual: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = True
    if causal:
        run = ki * bk <= qi * bq + bq - 1   # some kv col <= some q row

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)     # (bq, dh)
        k = k_ref[0].astype(jnp.float32)     # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < s_actual
        if causal:
            mask &= cols <= rows
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
# focuslint: disable=kernel-exact -- no bit-exact oracle exists: the
# online-softmax tile accumulation reorders fp32 sums vs the dense ref;
# pinned by assert_allclose at fp32 tolerances in test_kernels instead
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q, k, v: (BH, S, dh) -> (BH, S, dh)."""
    BH, S, dh = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    Sp = max((S + bq - 1) // bq * bq, (S + bk - 1) // bk * bk)
    # unify padding so both tilings divide
    import math
    lcm = bq * bk // math.gcd(bq, bk)
    Sp = (S + lcm - 1) // lcm * lcm
    pad = ((0, 0), (0, Sp - S), (0, 0))
    qp = jnp.pad(q, pad)
    kp = jnp.pad(k, pad)
    vp = jnp.pad(v, pad)
    nq, nk = Sp // bq, Sp // bk
    scale = 1.0 / (dh ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
                          nk=nk, s_actual=S),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S, :]
