"""Pallas TPU kernels for Focus hot spots.

centroid_assign — clustering inner loop (MXU distance + online argmin)
topk_mask       — top-K class extraction for the ingest index
flash_attention — blockwise fused attention for the CNN/LM backbones
pixel_diff      — blocked pairwise crop differencing (§4.2 redundancy gate)
frame_gate      — fused EMA + tile-diff + hot-tile motion gate (§6.1)

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper), ref.py (pure-jnp oracle). Validated in interpret mode on CPU.
"""
