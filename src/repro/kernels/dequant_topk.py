"""Pallas TPU kernel: fused int8/uint8 dequant + top-k extraction.

The v4 archive format (DESIGN.md §14) stores each shard's mean-probs as
uint8 with a per-row scale and a format-level global multiplier
(``core.index.PROB_GLOBAL_SCALE``). The archive rank path needs the top-K
class ids of every quantized row at shard load — this kernel applies the
per-row scale in VMEM and runs the same K online max-extract+mask passes
as ``topk_mask``, so a quantized shard's fp32 probability matrix is never
materialized (not in HBM, not on the host).

Scale staging: the global multiplier enters through SMEM (the
``pixel_diff``/``frame_gate`` scalar pattern — per-format/per-shard
constants are traced operands, so sweeping them never recompiles) and the
per-row scales ride alongside the quantized rows as a (BM, 1) VMEM block.
The effective scale is ``sg * s_row`` computed in f32, in that order —
``TopKIndex.load``'s eager dequant mirrors the exact op order, so eager
and lazy rank paths agree bitwise, ties included.

VMEM budget (BM=128, C=1024 padded): int8 tile 128 KiB + f32 dequant copy
512 KiB + outputs ~200 KiB << 16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -3e38


def _kernel(sg_ref, q_ref, s_ref, v_ref, i_ref, *, k: int):
    scale = sg_ref[0] * s_ref[...]                    # (BM, 1) f32
    x = q_ref[...].astype(jnp.float32) * scale        # dequant, VMEM only
    C = x.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    def body(t, carry):
        x, = carry
        m = jnp.max(x, axis=1)                        # (BM,)
        # smallest column index attaining the max (ties -> lowest index,
        # matching jax.lax.top_k and the eager stable-argsort ranks)
        is_max = x == m[:, None]
        idx = jnp.min(jnp.where(is_max, cols, C), axis=1).astype(jnp.int32)
        v_ref[:, t] = m
        i_ref[:, t] = idx
        x = jnp.where(cols == idx[:, None], _NEG, x)
        return (x,)

    jax.lax.fori_loop(0, k, body, (x,))


@functools.partial(jax.jit, static_argnames=("k", "bm", "interpret"))
def dequant_topk(sg, q, scales, k: int, *, bm: int = 128,
                 interpret: bool = True):
    """sg (1,) f32, q (M, C) int, scales (M,) f32 ->
    (values (M, k) f32, indices (M, k) i32), descending.

    ``values = top_k(q * (sg * scales)[:, None])`` with ties to the lowest
    column index. M is padded to a ``min(bm, max(8, M))`` tile multiple
    (pad scales are 1, pad rows compute garbage trimmed by ``[:M]``) and C
    to a 128-lane multiple with the dtype's minimum: for int8 that is -128,
    strictly below the quantizer's [-127, 127] range; for uint8 it is 0,
    which can tie with real zero entries but always loses the tie-break —
    pad columns sit at the highest indices, so for ``k <= C`` real passes
    a padded column is never extracted. Scales must be positive (the v4
    quantizer's all-zero-row sentinel is 1, never 0 or negative).
    """
    M, C = q.shape
    bm = min(bm, max(8, M))
    Mp = (M + bm - 1) // bm * bm
    Cp = (C + 127) // 128 * 128
    qp = jnp.pad(q, ((0, Mp - M), (0, Cp - C)),
                 constant_values=jnp.iinfo(q.dtype).min)
    sp = jnp.pad(scales.astype(jnp.float32).reshape(M, 1),
                 ((0, Mp - M), (0, 0)), constant_values=1.0)

    vals, idxs = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((1,), lambda mi: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, Cp), lambda mi: (mi, 0)),
            pl.BlockSpec((bm, 1), lambda mi: (mi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda mi: (mi, 0)),
            pl.BlockSpec((bm, k), lambda mi: (mi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, k), jnp.float32),
            jax.ShapeDtypeStruct((Mp, k), jnp.int32),
        ],
        interpret=interpret,
    )(sg, qp, sp)
    return vals[:M], idxs[:M]
