"""Pallas TPU kernel: fused frame-difference motion gate (paper §6.1).

``BackgroundSubtractor`` ran three numpy passes per frame on the host:
channel-mean abs diff against the background model, an EMA background
update, and an (H/t, W/t) tile-mean + threshold to label hot tiles. This
kernel fuses all three into one device pass over row blocks of the frame:

    frame, bg ──► |frame - bg| channel mean ──► (t, t) tile means ──► hot
        │
        └──► bg' = (1 - α)·bg + α·frame          (EMA, same pass)

  * the frame enters as a 2-D ``(H, W·3)`` view (channels flattened into
    lanes) so row blocks tile cleanly; the kernel reshapes a block to
    ``(bh, W, 3)`` for the channel mean and to ``(bh/t, t, W/t, t)`` for
    the tile reduction — all VPU work on VMEM-resident data;
  * α and the hot threshold enter through SMEM, so per-stream gate tuning
    (the adaptive sampler sweeps thresholds) never recompiles;
  * only complete tiles are labeled: the wrapper trims the hot grid to
    ``(H//t, W//t)`` exactly like the host path trimmed
    ``diff[:ty*t, :tx*t]`` — remainder rows/cols still get their EMA
    update, they just belong to no tile.

VMEM budget (bh=64, W=1280 → 3840 lanes, fp32): frame + bg + bg' blocks
3·64·3840·4 = 3.8 MiB, diff/tiles scratch < 1 MiB << 16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(at_ref, f_ref, bg_ref, nbg_ref, til_ref, hot_ref, *, t: int):
    alpha = at_ref[0]
    thr = at_ref[1]
    f = f_ref[...].astype(jnp.float32)          # (bh, W3)
    bg = bg_ref[...].astype(jnp.float32)
    nbg_ref[...] = (1.0 - alpha) * bg + alpha * f
    bh, w3 = f.shape
    w = w3 // 3
    d = jnp.abs(f - bg).reshape(bh, w, 3).mean(-1)            # (bh, W)
    tiles = d.reshape(bh // t, t, w // t, t).mean((1, 3))     # (bh/t, W/t)
    til_ref[...] = tiles
    hot_ref[...] = (tiles > thr).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile", "bh", "interpret"))
def motion_gate(at, frame2d, bg2d, *, tile: int = 8, bh: int = 64,
                interpret: bool = True):
    """frame2d/bg2d (H, W·3), at (2,) = (alpha, threshold) ->
    (new_bg (H, W·3) f32, tiles (typ, txp) f32, hot (typ, txp) i32).

    H is padded to a row-block multiple and W to a tile multiple (zero
    rows/cols: their EMA output is zero and their tiles are garbage — the
    ``ops`` wrapper trims both back to the real extent). ``bh`` must be a
    multiple of ``tile``; the wrapper guarantees it.
    """
    H, W3 = frame2d.shape
    W = W3 // 3
    bh = min(max(bh - bh % tile, tile), (H + tile - 1) // tile * tile)
    Hp = (H + bh - 1) // bh * bh
    Wp = (W + tile - 1) // tile * tile
    f = jnp.pad(frame2d.astype(jnp.float32),
                ((0, Hp - H), (0, (Wp - W) * 3)))
    bg = jnp.pad(bg2d.astype(jnp.float32),
                 ((0, Hp - H), (0, (Wp - W) * 3)))
    th, tw = bh // tile, Wp // tile

    new_bg, tiles, hot = pl.pallas_call(
        functools.partial(_kernel, t=tile),
        grid=(Hp // bh,),
        in_specs=[
            pl.BlockSpec((2,), lambda hi: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((bh, Wp * 3), lambda hi: (hi, 0)),
            pl.BlockSpec((bh, Wp * 3), lambda hi: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bh, Wp * 3), lambda hi: (hi, 0)),
            pl.BlockSpec((th, tw), lambda hi: (hi, 0)),
            pl.BlockSpec((th, tw), lambda hi: (hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Hp, Wp * 3), jnp.float32),
            jax.ShapeDtypeStruct((Hp // tile, tw), jnp.float32),
            jax.ShapeDtypeStruct((Hp // tile, tw), jnp.int32),
        ],
        interpret=interpret,
    )(at, f, bg)
    return new_bg, tiles, hot
