"""Pallas TPU kernel: blocked pairwise crop pixel-differencing (paper §4.2).

Focus's "Pixel Differencing of Objects" matches each detected crop against
a reference set (the previous frame's crops, or the redundancy gate's ring
of recent CNN-bound uniques) by mean absolute pixel difference. The host
implementation materialized the full ``(Na, Nb, D)`` broadcast tensor per
frame pair; this kernel is the device-side replacement, re-tiled like
``centroid_assign``:

  * crop tiles (BA, D) and reference tiles (BN, D) live in VMEM;
  * the grid's reference axis revisits the same output block, carrying a
    running (min, argmin) — the (Na, Nb) difference matrix is never
    materialized in HBM, let alone the (Na, Nb, D) broadcast;
  * within a tile the reference rows are walked with a ``fori_loop``; the
    per-step work ``mean |a - b_j|`` is a (BA, D) VPU op, so VMEM holds
    only the two input tiles plus the (BA,) running reductions;
  * the match decision ``min_d < threshold`` (STRICT, matching the host
    ``pixel_difference`` contract) is fused into the final grid step, and
    the threshold enters through SMEM so sweeping it never recompiles.

The reference axis is walked in ascending order with a strict ``<``
running compare, so ties resolve to the lowest reference index — exactly
``np.argmin`` semantics.

VMEM budget (BA=128, BN=128, D<=3072 for 32px crops, fp32):
  crops 128·3072·4 = 1.5 MiB, refs 1.5 MiB, reductions ~2 KiB
  << 16 MiB/core on v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# b-row pad sentinel: |a - 3e18| averages to ~3e18, so a padded reference
# row can never win the online argmin against any real crop
_PAD = 3e18


def _kernel(t_ref, a_ref, b_ref, min_ref, arg_ref, match_ref, *,
            bn: int, n_n: int):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    a = a_ref[...].astype(jnp.float32)          # (BA, D)
    b = b_ref[...].astype(jnp.float32)          # (BN, D)

    def body(j, carry):
        mn, ag = carry
        row = jax.lax.dynamic_slice_in_dim(b, j, 1, axis=0)     # (1, D)
        d = jnp.mean(jnp.abs(a - row), axis=1)                  # (BA,)
        better = d < mn                  # strict: ties keep the lowest j
        return (jnp.where(better, d, mn),
                jnp.where(better, j + ni * bn, ag))

    mn, ag = jax.lax.fori_loop(0, bn, body,
                               (min_ref[...], arg_ref[...]))
    min_ref[...] = mn
    arg_ref[...] = ag

    @pl.when(ni == n_n - 1)
    def _finalize():
        # strict <, mirroring the host pixel_difference contract: a diff
        # exactly at the threshold is NOT a match
        match_ref[...] = jnp.where(min_ref[...] < t_ref[0],
                                   arg_ref[...], -1)


@functools.partial(jax.jit, static_argnames=("ba", "bn", "interpret"))
def pixel_match(thr, a, b, *, ba: int = 128, bn: int = 128,
                interpret: bool = True):
    """a (Na, D), b (Nb, D), thr (1,) -> (match (Na,) i32, min_d (Na,) f32).

    ``match[i]`` is the lowest-index minimizer j of ``mean |a_i - b_j|``
    when that minimum is STRICTLY below ``thr``, else -1. Na and Nb are
    padded to tile multiples; b's pad rows are ``3e18`` sentinels (never
    the argmin), a's pad rows compute garbage trimmed by ``[:Na]``.
    """
    Na, D = a.shape
    Nb, _ = b.shape
    ba = min(ba, max(8, Na))
    bn = min(bn, max(8, Nb))
    Nap = (Na + ba - 1) // ba * ba
    Nbp = (Nb + bn - 1) // bn * bn
    af = jnp.pad(a.astype(jnp.float32), ((0, Nap - Na), (0, 0)))
    bf = jnp.pad(b.astype(jnp.float32), ((0, Nbp - Nb), (0, 0)),
                 constant_values=_PAD)
    n_n = Nbp // bn

    grid = (Nap // ba, n_n)
    min_d, arg, match = pl.pallas_call(
        functools.partial(_kernel, bn=bn, n_n=n_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ai, ni: (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((ba, D), lambda ai, ni: (ai, 0)),
            pl.BlockSpec((bn, D), lambda ai, ni: (ni, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ba,), lambda ai, ni: (ai,)),
            pl.BlockSpec((ba,), lambda ai, ni: (ai,)),
            pl.BlockSpec((ba,), lambda ai, ni: (ai,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Nap,), jnp.float32),
            jax.ShapeDtypeStruct((Nap,), jnp.int32),
            jax.ShapeDtypeStruct((Nap,), jnp.int32),
        ],
        interpret=interpret,
    )(thr, af, bf)
    return match[:Na], min_d[:Na]
