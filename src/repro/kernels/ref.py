"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def centroid_assign_ref(feats, centroids, threshold=None):
    """feats (B, D), centroids (M, D) -> (min_d2 (B,) f32, argmin (B,) i32).

    Squared L2 distance to the nearest centroid row. With ``threshold``,
    also returns ``matched = min_d2 <= threshold**2`` (B,) bool.
    """
    f = feats.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = (jnp.sum(f * f, axis=1)[:, None]
          - 2.0 * f @ c.T
          + jnp.sum(c * c, axis=1)[None, :])
    j = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2 = jnp.take_along_axis(d2, j[:, None].astype(jnp.int32), 1)[:, 0]
    if threshold is None:
        return mind2, j
    return mind2, j, mind2 <= jnp.float32(threshold) ** 2


def pixel_match_ref(a, b, threshold):
    """a (Na, D), b (Nb, D) -> (match (Na,) i32, min_d (Na,) f32).

    ``match[i]`` is the index of the b row minimizing the mean absolute
    difference against ``a_i`` (ties -> lowest index) when that minimum is
    STRICTLY below ``threshold``, else -1 — the §4.2 pixel-differencing
    decision of ``data.bgsub.pixel_difference``.
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    d = jnp.mean(jnp.abs(af[:, None, :] - bf[None, :, :]), axis=-1)
    j = jnp.argmin(d, axis=1).astype(jnp.int32)
    min_d = jnp.take_along_axis(d, j[:, None].astype(jnp.int32), 1)[:, 0]
    return jnp.where(min_d < jnp.float32(threshold), j, -1), min_d


def motion_gate_ref(frame, bg, alpha, threshold, tile: int):
    """frame/bg (H, W, 3) -> (new_bg (H, W, 3) f32, tiles (ty, tx) f32,
    hot (ty, tx) bool) with ty = H // tile, tx = W // tile.

    The fused ``BackgroundSubtractor`` step: EMA background update,
    channel-mean abs diff, (tile, tile) tile means, and the strict
    ``tiles > threshold`` hot mask. Only complete tiles are labeled —
    remainder rows/cols are trimmed exactly like the host path's
    ``diff[:ty*tile, :tx*tile]``.
    """
    a = jnp.float32(alpha)
    f = frame.astype(jnp.float32)
    b = bg.astype(jnp.float32)
    new_bg = (1.0 - a) * b + a * f
    d = jnp.abs(f - b).mean(-1)                       # (H, W)
    ty, tx = d.shape[0] // tile, d.shape[1] // tile
    tiles = d[: ty * tile, : tx * tile].reshape(ty, tile, tx, tile
                                                ).mean((1, 3))
    return new_bg, tiles, tiles > jnp.float32(threshold)


def topk_ref(logits, k: int):
    """logits (B, C) -> (values (B, k) f32, indices (B, k) i32), desc order."""
    v, i = jax.lax.top_k(logits.astype(jnp.float32), k)
    return v, i.astype(jnp.int32)


def dequant_topk_ref(q, scales, k: int, global_scale=1.0):
    """q (M, C) int, scales (M,) f32 -> (values (M, k) f32,
    indices (M, k) i32), descending, ties to the lowest column.

    Dequantizes ``q * (global_scale * scales)[:, None]`` in f32 — the same
    op order as the kernel's in-VMEM dequant, so values compare exactly.
    """
    scale = (jnp.float32(global_scale)
             * scales.astype(jnp.float32))[:, None]
    v, i = jax.lax.top_k(q.astype(jnp.float32) * scale, k)
    return v, i.astype(jnp.int32)


def flash_attention_ref(q, k, v, causal: bool = True):
    """q,k,v: (B, S, H, dh) -> (B, S, H, dh). Plain softmax attention."""
    S = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
