"""AdamW + LR schedules + global-norm clipping, pure JAX over pytrees."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"      # "cosine" | "linear" | "constant"
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
            * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(params, grads, state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    b2c = 1 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    def upd(p, m, v):
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
