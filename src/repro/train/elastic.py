"""Elastic scaling, preemption handling, straggler mitigation.

At 1000+ nodes the failure model is: pods preempt (SIGTERM), hosts die
(missing heartbeat), and individual chips straggle (thermal / HBM ECC).
The JAX-level responses implemented here:

  * PreemptionHandler — SIGTERM/SIGINT -> synchronous checkpoint + clean exit
    (the train loop checks ``triggered`` each step).
  * choose_mesh / reshard — rebuild the mesh from the devices that remain
    and ``jax.device_put`` every array to its new NamedSharding; a (2,16,16)
    pod-failure degrades to (16,16) without changing model code because all
    sharding rules are axis-name based.
  * StepTimer — EMA step-time tracker; steps slower than
    ``straggler_factor``x the EMA are counted and surfaced. On a real pod
    this feeds the controller that re-slices the job (here: observable
    metric + hook, exercised by tests).
"""
from __future__ import annotations

import contextlib
import signal
import time
from typing import Optional

import jax
from jax.sharding import Mesh

from repro.distributed import param_shardings


class PreemptionHandler:
    """Registers SIGTERM/SIGINT; sets ``triggered`` instead of dying."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.triggered = False
        self._old = {}
        for s in signals:
            try:
                self._old[s] = signal.signal(s, self._handle)
            except ValueError:          # non-main thread (tests)
                pass

    def _handle(self, signum, frame):
        self.triggered = True

    def restore(self):
        for s, h in self._old.items():
            signal.signal(s, h)


def choose_mesh(devices=None, model_parallelism: int = 1,
                pods: int = 1) -> Mesh:
    """Largest (pod, data, model) mesh the surviving devices support."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    mp = model_parallelism
    while n % (mp * pods) and mp > 1:
        mp //= 2
    dp = n // (mp * pods)
    import numpy as np
    arr = np.array(devices[: pods * dp * mp]).reshape(pods, dp, mp)
    return Mesh(arr, ("pod", "data", "model"))


def reshard(tree, new_mesh: Mesh, scan_layers: bool = True):
    """Move every array in ``tree`` onto ``new_mesh`` shardings (elastic
    re-scale path: same rules, new axis sizes)."""
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    shardings = param_shardings(shapes, new_mesh, scan_layers=scan_layers)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


class StepTimer:
    """EMA step timing + straggler counting."""

    def __init__(self, alpha: float = 0.1, straggler_factor: float = 2.0):
        self.alpha = alpha
        self.factor = straggler_factor
        self.ema: Optional[float] = None
        self.last: float = 0.0
        self.n_steps = 0
        self.n_stragglers = 0

    @contextlib.contextmanager
    def measure(self):
        t0 = time.perf_counter()
        yield
        self.observe(time.perf_counter() - t0)

    def observe(self, dt: float):
        self.last = dt
        self.n_steps += 1
        if self.ema is None:
            self.ema = dt
            return
        if dt > self.factor * self.ema:
            self.n_stragglers += 1
        self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
