"""Gradient compression for the data-parallel reduce (1000+-node trick).

Two schemes, both usable inside the train step:
  * ``cast_bf16``   — all-reduce in bf16 (2x wire saving, ~free accuracy)
  * ``int8_ef``     — per-tensor int8 quantization with error feedback:
                      residuals are carried in a state pytree so the bias
                      introduced by quantization cancels over steps.

``compressed_psum`` is the shard_map building block that performs the actual
quantized collective over a named axis; ``apply_ef`` is the mesh-agnostic
numerics (used by the CPU tests and inside pjit, where XLA owns the
collective and we compress what the collective sees).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_int8(g) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def apply_ef(grads, ef_state):
    """Error-feedback int8 compression of a grad pytree.

    Returns (dequantized grads as seen after the wire, new ef_state).
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq

    flat = jax.tree.map(one, grads, ef_state)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_e


def cast_bf16(grads):
    """bf16 wire-format round-trip (what a bf16 all-reduce sees)."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)


def compressed_psum(x, axis_name: str):
    """int8-quantized psum over a named axis (use inside shard_map).

    Each participant quantizes locally; int32 accumulation avoids overflow;
    scales are maxed across the axis so dequantization is consistent.
    """
    q, scale = _quant_int8(x.astype(jnp.float32))
    scale = jax.lax.pmax(scale, axis_name)      # shared scale
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
