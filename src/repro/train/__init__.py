from repro.train.optimizer import OptConfig  # noqa: F401
from repro.train.train_loop import TrainConfig, make_train_step, train  # noqa: F401
from repro.train.checkpoint import CheckpointManager  # noqa: F401
