"""Sharded checkpoint save/restore with resharding — the fault-tolerance
substrate.

Layout per step:  <dir>/step_<N>/
    manifest.json     step, names, shapes, dtypes, extra (rng, data state)
    leaves.npz        flattened leaves keyed leaf_<i>
    treedef.pkl       pytree structure

Multi-host note: on a real pod each process writes only its addressable
shards (per-process npz keyed by shard index) and restore re-assembles via
``jax.make_array_from_single_device_arrays``; this container is single-host
so leaves are written whole. The restore path takes target shardings so a
checkpoint written on one mesh restores onto a *different* mesh (elastic
re-scale / failure recovery).

Saves are atomic (write to tmp dir + rename) and pruned to ``keep`` newest.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Checkpoint a pytree (params/opt state bundled by the caller)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]   # device->host copy
        if self._thread is not None:
            self._thread.join()                          # one save in flight
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef, extra))
            self._thread.start()
        else:
            self._write(step, host_leaves, treedef, extra)

    def _write(self, step, host_leaves, treedef, extra):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_ckpt_")
        try:
            np.savez(os.path.join(tmp, "leaves.npz"),
                     **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
            with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                pickle.dump(treedef, f)
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "shapes": [list(l.shape) for l in host_leaves],
                "dtypes": [str(l.dtype) for l in host_leaves],
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._prune()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings: Any = None):
        """Returns (step, tree, extra). ``shardings``: optional pytree (or
        prefix) of NamedSharding for resharded restore onto a new mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        z = np.load(os.path.join(d, "leaves.npz"))
        leaves = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda l, s: jax.device_put(l, s) if s is not None else l,
                tree, shardings,
                is_leaf=lambda x: isinstance(x, np.ndarray))
        return step, tree, manifest["extra"]
