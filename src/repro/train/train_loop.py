"""Generic pjit train loop: grad accumulation, mixed precision, gradient
compression, checkpoint/restart, preemption handling.

``loss_fn(params, batch, rng) -> (loss, metrics)`` is the model contract;
``batch`` is a dict of arrays with a leading global-batch dim.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import compression as comp
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import PreemptionHandler, StepTimer


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 300
    log_every: int = 50
    ckpt_every: int = 0                 # 0 = only on preemption/final
    n_microbatches: int = 1             # grad accumulation
    compression: str = "none"           # none | bf16 | int8_ef
    seed: int = 0


def make_train_step(loss_fn: Callable, opt_cfg: opt.OptConfig,
                    train_cfg: TrainConfig, mesh=None, donate: bool = True):
    """Build the jitted (params, opt_state, ef, batch, rng) -> ... step."""

    def grads_of(params, batch, rng):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, rng)

    def step_fn(params, opt_state, ef_state, batch, rng):
        n_mb = train_cfg.n_microbatches
        if n_mb > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]),
                batch)
            rngs = jax.random.split(rng, n_mb)

            def acc(carry, inp):
                g_acc, loss_acc = carry
                mb, r = inp
                (loss, metrics), g = grads_of(params, mb, r)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), metrics = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), (mbs, rngs))
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            loss = loss / n_mb
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grads_of(params, batch, rng)

        if train_cfg.compression == "bf16":
            grads = comp.cast_bf16(grads)
        elif train_cfg.compression == "int8_ef":
            grads, ef_state = comp.apply_ef(grads, ef_state)

        params, opt_state, om = opt.update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, ef_state, metrics

    donate_args = (0, 1, 2) if donate else ()
    return jax.jit(step_fn, donate_argnums=donate_args)


def train(loss_fn: Callable, params, data_iter: Iterator[Dict[str, Any]],
          opt_cfg: opt.OptConfig, train_cfg: TrainConfig,
          ckpt: Optional[CheckpointManager] = None, mesh=None,
          resume: bool = True, hooks=()):
    """Run the loop; returns (params, history list of metric dicts).

    Fault tolerance: restores the newest checkpoint if present (resume=True);
    checkpoints on SIGTERM/SIGINT (preemption) and every ckpt_every steps;
    the data-iterator position is part of the checkpoint extras.
    """
    step_fn = make_train_step(loss_fn, opt_cfg, train_cfg, mesh=mesh)
    opt_state = opt.init(params)
    ef_state = (comp.init_ef_state(params)
                if train_cfg.compression == "int8_ef" else 0)
    start_step = 0

    if ckpt is not None and resume and ckpt.latest_step() is not None:
        start_step, tree, extra = ckpt.restore()
        params, opt_state, ef_state = tree
        for _ in range(int(extra.get("batches_consumed", start_step))):
            next(data_iter)                      # replay iterator position

    rng = jax.random.PRNGKey(train_cfg.seed)
    preempt = PreemptionHandler()
    timer = StepTimer()
    history = []

    step = start_step
    for step in range(start_step, train_cfg.steps):
        batch = next(data_iter)
        rng, sub = jax.random.split(rng)
        with timer.measure():
            params, opt_state, ef_state, metrics = step_fn(
                params, opt_state, ef_state, batch, sub)
        if (step + 1) % train_cfg.log_every == 0 or step == start_step:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step + 1
            m["step_time_s"] = timer.last
            history.append(m)
            for h in hooks:
                h(m)
        if ckpt is not None and (
                preempt.triggered
                or (train_cfg.ckpt_every
                    and (step + 1) % train_cfg.ckpt_every == 0)):
            ckpt.save(step + 1, (params, opt_state, ef_state),
                      extra={"batches_consumed": step + 1,
                             "preempted": preempt.triggered})
            if preempt.triggered:
                ckpt.wait()
                return params, history

    if ckpt is not None:
        ckpt.save(train_cfg.steps, (params, opt_state, ef_state),
                  extra={"batches_consumed": step + 1})
        ckpt.wait()
    return params, history
