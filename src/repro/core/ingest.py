"""Focus ingest-time pipeline (paper Fig. 4, left; §4.1-§4.3).

detected objects -> pixel-diff dedup -> cheap CNN (top-K probs + features)
                 -> incremental clustering -> top-K index

The CNN and clustering run batched on the accelerator (Pallas kernels on
TPU); cluster bookkeeping (member lists, frame ids, eviction) is host-side
and fully batched through the SoA ``ClusterStore`` — there is no per-object
Python loop anywhere on the hot path, mirroring the paper's CPU/GPU
pipelining (§6.3: clustering runs on CPUs of the ingest machine, fully
pipelined with the GPUs running the CNN).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core import clustering as C
from repro.core.index import ClassMap, TopKIndex
from repro.data.bgsub import pixel_difference


@dataclass(frozen=True)
class IngestConfig:
    K: int = 10
    threshold: float = 0.8          # clustering distance T (L2)
    max_clusters: int = 4096        # M
    batch_size: int = 512
    pixel_diff: bool = True
    pixel_diff_threshold: float = 0.02
    evict_frac: float = 0.25
    high_water: float = 0.95        # evict when n >= high_water * M
    clustering: str = "fused"       # "scan" | "batched" | "fused"


@dataclass
class IngestStats:
    n_objects: int = 0
    n_cnn_invocations: int = 0
    n_pixel_dedup: int = 0
    cheap_flops: float = 0.0
    n_evictions: int = 0
    wall_s: float = 0.0


def pixel_tracks(crops: np.ndarray, frames: np.ndarray,
                 threshold: float) -> np.ndarray:
    """Root object id per object under §4.2 pixel differencing.

    Objects in frame t whose pixels nearly match an object in frame t-1
    join that object's track (and will share its cluster) without a CNN pass.
    """
    n = len(crops)
    roots = np.arange(n)
    if n == 0:
        return roots
    order = np.argsort(frames, kind="stable")
    prev_ids: np.ndarray = np.array([], dtype=np.int64)
    prev_frame = -1
    i = 0
    while i < len(order):
        f = frames[order[i]]
        j = i
        while j < len(order) and frames[order[j]] == f:
            j += 1
        cur_ids = order[i:j]
        if prev_frame == f - 1 and len(prev_ids):
            match = pixel_difference(crops[cur_ids], crops[prev_ids],
                                     threshold)
            for local, m in enumerate(match):
                if m >= 0:
                    roots[cur_ids[local]] = roots[prev_ids[m]]
        prev_ids, prev_frame = cur_ids, f
        i = j
    return roots


def ingest(crops: np.ndarray, frames: np.ndarray,
           cheap_apply: Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]],
           cheap_flops_per_image: float, cfg: IngestConfig,
           class_map: Optional[ClassMap] = None,
           n_local_classes: Optional[int] = None,
           ) -> Tuple[TopKIndex, IngestStats]:
    """Build the top-K index for a stream of detected objects.

    cheap_apply(crops (B,R,R,3)) -> (probs (B, C_local), feats (B, D)).
    Feature/class dims are derived from the first real batch — no extra
    shape-probe CNN invocation, and every CNN pass is counted in the stats.
    """
    t0 = time.perf_counter()
    stats = IngestStats(n_objects=len(crops))

    roots = (pixel_tracks(crops, frames, cfg.pixel_diff_threshold)
             if cfg.pixel_diff else np.arange(len(crops)))
    unique_ids = np.nonzero(roots == np.arange(len(crops)))[0]
    stats.n_pixel_dedup = len(crops) - len(unique_ids)

    index: Optional[TopKIndex] = None
    state = None                               # lazy: dims from first batch
    slot_cid = np.full(cfg.max_clusters, -1, np.int64)   # slot -> cid
    obj_cid = np.full(len(crops), -1, np.int64)          # object -> cid
    next_cid = 0
    try:
        cluster_fn = C.CLUSTER_FNS[cfg.clustering]
    except KeyError:
        raise ValueError(
            f"unknown clustering variant {cfg.clustering!r}; "
            f"expected one of {sorted(C.CLUSTER_FNS)}") from None

    for start in range(0, len(unique_ids), cfg.batch_size):
        batch_ids = unique_ids[start:start + cfg.batch_size]
        batch_crops = crops[batch_ids]
        probs, feats = cheap_apply(batch_crops)
        probs = np.asarray(probs)
        feats = np.asarray(feats, np.float32)
        stats.n_cnn_invocations += len(batch_ids)
        stats.cheap_flops += len(batch_ids) * cheap_flops_per_image

        if index is None:
            if n_local_classes is None:
                n_local_classes = probs.shape[1]
            index = TopKIndex(cfg.K, n_local_classes, class_map)
            state = C.init_state(cfg.max_clusters, feats.shape[1])

        state, slots = cluster_fn(state, feats, cfg.threshold)
        slots = np.asarray(slots)

        # slot -> cid, assigning fresh cids in first-appearance order
        unmapped = slot_cid[slots] < 0
        if unmapped.any():
            new_slots, first_pos = np.unique(slots[unmapped],
                                             return_index=True)
            order = np.argsort(first_pos, kind="stable")
            slot_cid[new_slots[order]] = next_cid + np.arange(len(new_slots))
            next_cid += len(new_slots)
        cids = slot_cid[slots]
        obj_cid[batch_ids] = cids

        index.add_batch(cids, feats, probs, batch_ids, frames[batch_ids],
                        crops=batch_crops)

        # eviction keeps the live table at M (paper: evict smallest)
        if int(state.n) >= int(cfg.high_water * cfg.max_clusters):
            state, evicted, remap = C.evict_smallest(state, cfg.evict_frac)
            stats.n_evictions += len(evicted)
            new_slot_cid = np.full_like(slot_cid, -1)
            live = remap >= 0
            new_slot_cid[remap[live]] = slot_cid[live]
            slot_cid = new_slot_cid

    if index is None:        # empty stream
        index = TopKIndex(cfg.K, n_local_classes or 0, class_map)

    # attach pixel-diff duplicates to their root's cluster (batched)
    dup = np.nonzero(roots != np.arange(len(crops)))[0]
    if len(dup):
        root_cids = obj_cid[roots[dup]]
        valid = root_cids >= 0
        index.attach(root_cids[valid], dup[valid], frames[dup[valid]])

    stats.wall_s = time.perf_counter() - t0
    return index, stats
