"""Focus ingest-time pipeline (paper Fig. 4, left; §4.1-§4.3).

detected objects -> pixel-diff dedup -> cheap CNN (top-K probs + features)
                 -> incremental clustering -> top-K index

The CNN and clustering run batched on the accelerator (Pallas kernels on
TPU); cluster bookkeeping (member lists, frame ids, eviction) is host-side
and fully batched through the SoA ``ClusterStore`` — there is no per-object
Python loop anywhere on the hot path, mirroring the paper's CPU/GPU
pipelining (§6.3: clustering runs on CPUs of the ingest machine, fully
pipelined with the GPUs running the CNN).

The chunk-step itself (CNN batch -> clustering -> slot/cid bookkeeping ->
index fold -> eviction) lives in ``core.streaming.StreamingIngestor``;
``ingest()`` is the one-shot wrapper feeding a single chunk.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core.index import ClassMap, TopKIndex


@dataclass(frozen=True)
class IngestConfig:
    K: int = 10
    threshold: float = 0.8          # clustering distance T (L2)
    max_clusters: int = 4096        # M
    batch_size: int = 512
    pixel_diff: bool = True
    pixel_diff_threshold: float = 0.02
    evict_frac: float = 0.25
    high_water: float = 0.95        # evict when n >= high_water * M
    clustering: str = "fused"       # "scan" | "batched" | "fused"
    # redundancy gate (DESIGN.md §10): match CNN-bound uniques against a
    # ring of recent uniques from earlier frames; hits skip the CNN and
    # attach to their ring root's cluster
    gate: bool = False
    gate_threshold: float = 0.02
    gate_capacity: int = 512        # ring size (recent CNN-bound uniques)
    # keep only frames with frame_id % frame_stride == 0 (absolute grid,
    # so the kept set is a function of the stream alone, never chunking)
    frame_stride: int = 1


@dataclass
class IngestStats:
    n_objects: int = 0
    n_cnn_invocations: int = 0
    n_pixel_dedup: int = 0          # §4.2 prev-frame tracker matches
    n_gate_skipped: int = 0         # redundancy-gate ring matches
    n_sampled_out: int = 0          # dropped by the frame stride
    cheap_flops: float = 0.0
    n_evictions: int = 0
    wall_s: float = 0.0


def pixel_tracks(crops: np.ndarray, frames: np.ndarray,
                 threshold: float) -> np.ndarray:
    """Root object id per object under §4.2 pixel differencing.

    Objects in frame t whose pixels nearly match an object in frame t-1
    join that object's track (and will share its cluster) without a CNN
    pass. Thin one-shot view over the streaming ``_PixelTracker`` — the
    same code path ingest uses — so its tests pin the live tracker.
    """
    from repro.core.streaming import _PixelTracker
    n = len(crops)
    roots = np.arange(n)
    if n == 0:
        return roots
    order = np.argsort(frames, kind="stable")
    tracker = _PixelTracker(threshold)
    i = 0
    while i < n:
        f = int(frames[order[i]])
        j = i
        while j < n and frames[order[j]] == f:
            j += 1
        ids = order[i:j]
        roots[ids] = tracker.resolve(f, crops[ids], ids.astype(np.int64))
        i = j
    return roots


def ingest(crops: np.ndarray, frames: np.ndarray,
           cheap_apply: Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]],
           cheap_flops_per_image: float, cfg: IngestConfig,
           class_map: Optional[ClassMap] = None,
           n_local_classes: Optional[int] = None,
           pipeline=None) -> Tuple[TopKIndex, IngestStats]:
    """Build the top-K index for a stream of detected objects — the
    one-shot (single-chunk) wrapper over ``streaming.StreamingIngestor``.

    cheap_apply(crops (B,R,R,3)) -> (probs (B, C_local), feats (B, D)).
    Feature/class dims are derived from the first real batch — no extra
    shape-probe CNN invocation, and every CNN pass is counted in the stats.
    Objects are processed in (stable) frame order; for time-ordered
    streams — every stream here — that is exactly the array order the
    pre-streaming implementation used, and a chunked ``StreamingIngestor``
    run over the same stream saves a byte-identical index.

    With ``pipeline`` (a ``core.pipeline.IngestPipeline``) the CNN +
    clustering fast path runs as the fused device megastep instead of
    host-staged ``cheap_apply`` calls; pass ``cheap_apply=None`` then.
    """
    from repro.core.streaming import StreamingIngestor
    ing = StreamingIngestor(cheap_apply, cheap_flops_per_image, cfg,
                            class_map=class_map,
                            n_local_classes=n_local_classes,
                            pipeline=pipeline)
    ing.feed(np.asarray(crops), np.asarray(frames, np.int64))
    return ing.finish()
