"""The Focus top-K ingest index (paper §3, §4.1).

Structure (exactly the paper's):
    object class -> <cluster ID>
    cluster ID   -> [centroid object, <objects> in cluster, <frame IDs>]

Clusters carry a running mean of the cheap CNN's class probabilities; the
cluster's top-K class set is the top-K of that mean, which supports the
"dynamically adjusting K at query-time" enhancement (§5): lookup with any
Kx <= K uses rank information stored at ingest.

When the ingest CNN is *specialized* (§4.3), the index stores local class ids
(0..Ls-1 plus OTHER) and a ClassMap translates query-time global classes;
querying a class outside the specialized set routes to the OTHER clusters.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

OTHER = -1   # sentinel for the OTHER class in *global* space


@dataclass
class ClassMap:
    """Global class id <-> local specialized id. Local Ls is OTHER."""
    global_ids: np.ndarray        # (Ls,) global ids of specialized classes

    @property
    def n_local(self) -> int:     # Ls + 1 (OTHER)
        return len(self.global_ids) + 1

    @property
    def other_local(self) -> int:
        return len(self.global_ids)

    def to_local(self, global_id: int) -> int:
        hits = np.nonzero(self.global_ids == global_id)[0]
        return int(hits[0]) if len(hits) else self.other_local

    def to_global(self, local_id: int) -> int:
        if local_id == self.other_local:
            return OTHER
        return int(self.global_ids[local_id])


@dataclass
class Cluster:
    cluster_id: int
    centroid: np.ndarray                 # feature vector (D,)
    rep_crop: np.ndarray                 # centroid object's crop (for GT-CNN)
    mean_probs: np.ndarray               # (C_local,) running mean class probs
    count: int = 0
    members: List[int] = field(default_factory=list)   # object ids
    frames: List[int] = field(default_factory=list)    # frame ids

    def add(self, obj_id: int, frame_id: int, feat: np.ndarray,
            probs: np.ndarray, crop: Optional[np.ndarray] = None):
        self.count += 1
        a = 1.0 / self.count
        self.centroid = (1 - a) * self.centroid + a * feat
        self.mean_probs = (1 - a) * self.mean_probs + a * probs
        self.members.append(obj_id)
        self.frames.append(frame_id)
        if crop is not None and self.count == 1:
            self.rep_crop = crop

    def topk(self, k: int) -> np.ndarray:
        k = min(k, len(self.mean_probs))
        part = np.argpartition(-self.mean_probs, k - 1)[:k]
        return part[np.argsort(-self.mean_probs[part])]


class TopKIndex:
    """class -> clusters inverted index, built at ingest time."""

    def __init__(self, K: int, n_local_classes: int,
                 class_map: Optional[ClassMap] = None):
        self.K = K
        self.n_local_classes = n_local_classes
        self.class_map = class_map
        self.clusters: Dict[int, Cluster] = {}
        self._inverted: Optional[Dict[int, List[int]]] = None

    # -- ingest-side -----------------------------------------------------------

    def add_cluster(self, cluster: Cluster):
        self.clusters[cluster.cluster_id] = cluster
        self._inverted = None

    # -- query-side ------------------------------------------------------------

    def _build(self):
        inv: Dict[int, List[int]] = {}
        ranks: Dict[int, Dict[int, int]] = {}
        for cid, cl in self.clusters.items():
            for rank, c in enumerate(cl.topk(self.K)):
                inv.setdefault(int(c), []).append(cid)
                ranks.setdefault(cid, {})[int(c)] = rank
        self._inverted = inv
        self._ranks = ranks

    def lookup(self, global_class: int, Kx: Optional[int] = None) -> List[int]:
        """Cluster ids whose top-Kx (local) classes include the queried class."""
        if self._inverted is None:
            self._build()
        Kx = Kx or self.K
        local = (self.class_map.to_local(global_class)
                 if self.class_map is not None else global_class)
        cids = self._inverted.get(local, [])
        return [cid for cid in cids if self._ranks[cid][local] < Kx]

    def frames_of(self, cids: Sequence[int]) -> np.ndarray:
        out = set()
        for cid in cids:
            out.update(self.clusters[cid].frames)
        return np.array(sorted(out), dtype=np.int64)

    def rep_crops(self, cids: Sequence[int]) -> np.ndarray:
        return np.stack([self.clusters[cid].rep_crop for cid in cids])

    # -- stats / persistence ---------------------------------------------------

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)

    @property
    def n_objects(self) -> int:
        return sum(c.count for c in self.clusters.values())

    def summary(self) -> dict:
        if self._inverted is None:
            self._build()
        return {
            "K": self.K,
            "n_clusters": self.n_clusters,
            "n_objects": self.n_objects,
            "n_classes_indexed": len(self._inverted),
            "specialized": self.class_map is not None,
        }

    def save(self, path: str):
        """Persist index metadata + arrays (MongoDB stand-in, §5)."""
        meta = {
            "K": self.K,
            "n_local_classes": self.n_local_classes,
            "class_map": (self.class_map.global_ids.tolist()
                          if self.class_map else None),
            "clusters": {
                str(cid): {"count": c.count, "members": c.members,
                           "frames": c.frames}
                for cid, c in self.clusters.items()
            },
        }
        arrays = {}
        for cid, c in self.clusters.items():
            arrays[f"centroid_{cid}"] = c.centroid
            arrays[f"probs_{cid}"] = c.mean_probs
            arrays[f"crop_{cid}"] = c.rep_crop
        np.savez_compressed(path + ".npz", **arrays)
        with open(path + ".json", "w") as f:
            json.dump(meta, f)

    @classmethod
    def load(cls, path: str) -> "TopKIndex":
        with open(path + ".json") as f:
            meta = json.load(f)
        arrays = np.load(path + ".npz")
        cmap = (ClassMap(np.array(meta["class_map"]))
                if meta["class_map"] is not None else None)
        idx = cls(meta["K"], meta["n_local_classes"], cmap)
        for cid_s, info in meta["clusters"].items():
            cid = int(cid_s)
            cl = Cluster(cid, arrays[f"centroid_{cid}"],
                         arrays[f"crop_{cid}"], arrays[f"probs_{cid}"],
                         count=info["count"], members=info["members"],
                         frames=info["frames"])
            idx.clusters[cid] = cl
        return idx
