"""The Focus top-K ingest index (paper §3, §4.1).

Structure (exactly the paper's):
    object class -> <cluster ID>
    cluster ID   -> [centroid object, <objects> in cluster, <frame IDs>]

Clusters carry a running mean of the cheap CNN's class probabilities; the
cluster's top-K class set is the top-K of that mean, which supports the
"dynamically adjusting K at query-time" enhancement (§5): lookup with any
Kx <= K uses rank information stored at ingest.

When the ingest CNN is *specialized* (§4.3), the index stores local class ids
(0..Ls-1 plus OTHER) and a ClassMap translates query-time global classes;
querying a class outside the specialized set routes to the OTHER clusters.

Storage is an array-backed SoA ``ClusterStore`` (DESIGN.md §4): centroids
(M, D), mean_probs (M, C), counts (M,), rep_crops (M, R, R, 3), plus an
append-only member/frame log compiled lazily into CSR form. Ingest-side
bookkeeping is batched (``add_batch``/``attach``) — no per-object Python
loop — and query-side ``_build``/``lookup`` are one vectorized
``argpartition`` over the (M, C) mean-probs matrix. The per-object
``Cluster`` dataclass remains as a compatibility view (``index.clusters``)
and as the unit of ``add_cluster``.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Mapping, Optional, Sequence

import numpy as np

OTHER = -1   # sentinel for the OTHER class in *global* space

INDEX_FORMAT = 4            # default save format (v4: quantized columnar)

# Format-level dequant multipliers for the v4 quantized columns. The stored
# per-row scale is the row's max magnitude; the effective dequant scale is
# ``GLOBAL * row_scale`` computed in float32, in that order — the lazy
# archive path stages GLOBAL through SMEM in the ``dequant_topk`` kernel
# and the eager loader mirrors the same op order, so both produce bitwise
# identical float32 values from the same quantized bytes.
PROB_GLOBAL_SCALE = np.float32(1.0 / 255.0)     # uint8 mean-probs
CENT_GLOBAL_SCALE = np.float32(1.0 / 127.0)     # int8 centroids


def _resolve_kx(Kx: Optional[int], K: int) -> int:
    """Validate a query-time Kx against the ingest-time K (shared by the
    eager ``TopKIndex.lookup`` and the archive's lazy shard lookup)."""
    if Kx is None:
        return K
    if Kx < 0:
        raise ValueError(f"Kx must be >= 0, got {Kx}")
    if Kx > K:
        raise ValueError(
            f"Kx={Kx} exceeds the ingest-time K={K}; ranks beyond "
            f"the top-K were not stored at ingest (re-ingest with a "
            f"larger K to query deeper)")
    return Kx


def _shrink_ints(a: np.ndarray) -> np.ndarray:
    """Narrowest of int16/int32/int64 holding ``a`` — chosen purely from
    the value range, so equal arrays always serialize identically (the
    byte-identity invariants depend on it)."""
    a = np.asarray(a, np.int64)
    if a.size == 0:
        return a.astype(np.int16)
    lo, hi = int(a.min()), int(a.max())
    for dt in (np.int16, np.int32):
        info = np.iinfo(dt)
        if info.min <= lo and hi <= info.max:
            return a.astype(dt)
    return a


def _quant_rows_uint8(x: Optional[np.ndarray], n_rows: int):
    """Non-negative rows (M, C) -> (q uint8, row_scales f32 (M,)) with
    dequant ``q * (PROB_GLOBAL_SCALE * row_scales)``. All-zero rows get a
    sentinel scale of 1 so dequant stays exact (0)."""
    if x is None:
        return (np.zeros((n_rows, 0), np.uint8),
                np.ones((n_rows,), np.float32))
    x = np.asarray(x, np.float32)
    if x.size == 0:
        return x.astype(np.uint8), np.ones((x.shape[0],), np.float32)
    rowmax = x.max(axis=1)
    row_scales = np.where(rowmax > 0, rowmax, 1.0).astype(np.float32)
    scale = (PROB_GLOBAL_SCALE * row_scales).astype(np.float32)
    q = np.clip(np.round(x / scale[:, None]), 0, 255).astype(np.uint8)
    return q, row_scales


def _quant_rows_int8(x: Optional[np.ndarray], n_rows: int):
    """Signed rows (M, D) -> (q int8 in [-127, 127], row_scales f32 (M,))
    with dequant ``q * (CENT_GLOBAL_SCALE * row_scales)``."""
    if x is None:
        return (np.zeros((n_rows, 0), np.int8),
                np.ones((n_rows,), np.float32))
    x = np.asarray(x, np.float32)
    if x.size == 0:
        return x.astype(np.int8), np.ones((x.shape[0],), np.float32)
    rowmax = np.abs(x).max(axis=1)
    row_scales = np.where(rowmax > 0, rowmax, 1.0).astype(np.float32)
    scale = (CENT_GLOBAL_SCALE * row_scales).astype(np.float32)
    q = np.clip(np.round(x / scale[:, None]), -127, 127).astype(np.int8)
    return q, row_scales


def _quant_global_uint8(x: np.ndarray):
    """Bounded array -> (q uint8, qparams f32 (2,) = [scale, lo]) with
    dequant ``q * scale + lo`` — one affine grid per shard for rep-crops,
    which are bounded post-normalization."""
    x = np.asarray(x, np.float32)
    if x.size == 0:
        return x.astype(np.uint8), np.array([1.0, 0.0], np.float32)
    lo = np.float32(x.min())
    scale = np.float32((np.float32(x.max()) - lo) / np.float32(255.0))
    if scale <= 0:
        scale = np.float32(1.0)
    q = np.clip(np.round((x - lo) / scale), 0, 255).astype(np.uint8)
    return q, np.array([scale, lo], np.float32)


def dequant_crops(q: np.ndarray, qparams: np.ndarray) -> np.ndarray:
    """Invert ``_quant_global_uint8`` — shared by the eager loader and the
    archive's lazy per-row crop gather so both dequantize bitwise alike."""
    return (q.astype(np.float32) * np.float32(qparams[0])
            + np.float32(qparams[1]))


def saved_files(prefix: str) -> List[str]:
    """Suffixes (deterministic order) of the files ``TopKIndex.save``
    wrote at ``prefix``. THE enumeration unit for byte-identity
    comparisons and on-disk size accounting — formats <= 3 are
    ``.json`` + ``.npz``; v4 is ``.json`` plus one ``.npy`` per column."""
    with open(prefix + ".json") as f:
        meta = json.load(f)
    if meta.get("format", 1) >= 4:
        return [".json"] + [f".{c}.npy" for c in meta["columns"]]
    return [".json", ".npz"]


def saved_file_bytes(prefix: str) -> tuple:
    """((suffix, bytes), ...) of a saved index — the comparison unit used
    by every equivalence harness (rollover, chunked/one-shot, mesh)."""
    out = []
    for suf in saved_files(prefix):
        with open(prefix + suf, "rb") as f:
            out.append((suf, f.read()))
    return tuple(out)


def saved_nbytes(prefix: str) -> int:
    """Total on-disk bytes of a saved index."""
    return sum(os.path.getsize(prefix + suf) for suf in saved_files(prefix))


@dataclass
class ClassMap:
    """Global class id <-> local specialized id. Local Ls is OTHER."""
    global_ids: np.ndarray        # (Ls,) global ids of specialized classes

    @property
    def n_local(self) -> int:     # Ls + 1 (OTHER)
        return len(self.global_ids) + 1

    @property
    def other_local(self) -> int:
        return len(self.global_ids)

    def to_local(self, global_id: int) -> int:
        hits = np.nonzero(self.global_ids == global_id)[0]
        return int(hits[0]) if len(hits) else self.other_local

    def to_global(self, local_id: int) -> int:
        if local_id == self.other_local:
            return OTHER
        return int(self.global_ids[local_id])


@dataclass
class Cluster:
    """Per-cluster record. Still the unit of ``add_cluster`` and the
    materialization type of the ``index.clusters`` view; bulk ingest goes
    through ``ClusterStore.add_batch`` instead of per-object ``add``."""
    cluster_id: int
    centroid: np.ndarray                 # feature vector (D,)
    rep_crop: np.ndarray                 # centroid object's crop (for GT-CNN)
    mean_probs: np.ndarray               # (C_local,) running mean class probs
    count: int = 0
    members: List[int] = field(default_factory=list)   # object ids
    frames: List[int] = field(default_factory=list)    # frame ids

    def add(self, obj_id: int, frame_id: int, feat: np.ndarray,
            probs: np.ndarray, crop: Optional[np.ndarray] = None):
        self.count += 1
        a = 1.0 / self.count
        self.centroid = (1 - a) * self.centroid + a * feat
        self.mean_probs = (1 - a) * self.mean_probs + a * probs
        self.members.append(obj_id)
        self.frames.append(frame_id)
        if crop is not None and self.count == 1:
            self.rep_crop = crop

    def topk(self, k: int) -> np.ndarray:
        k = min(k, len(self.mean_probs))
        part = np.argpartition(-self.mean_probs, k - 1)[:k]
        return part[np.argsort(-self.mean_probs[part])]


def _grow(arr: Optional[np.ndarray], need: int, row_shape, dtype):
    """Amortized doubling of the leading axis; returns array with >= need
    rows (contents of live rows preserved)."""
    cap = 0 if arr is None else arr.shape[0]
    if cap >= need:
        return arr
    new_cap = max(64, cap * 2)
    while new_cap < need:
        new_cap *= 2
    out = np.zeros((new_cap, *row_shape), dtype)
    if arr is not None:
        out[:cap] = arr
    return out


class ClusterStore:
    """SoA cluster storage: all per-cluster scalars/vectors live in flat
    arrays indexed by row, with a dict only for the cid -> row map. The
    member/frame log is append-only (one entry per object) and compiled to
    CSR on demand for member listing; ``frames_of`` works straight off the
    flat log."""

    def __init__(self):
        self.n_rows = 0
        self.centroids: Optional[np.ndarray] = None     # (cap, D) f32
        self.mean_probs: Optional[np.ndarray] = None    # (cap, C) f32
        self.counts = np.zeros((0,), np.int64)          # (cap,) all members
        # CNN-folded members only: the running-mean weight. Attached
        # pixel-diff duplicates (never CNN'd) count toward ``counts`` but
        # must not change how later folds are weighted — otherwise the
        # centroid would depend on *when* the streaming driver attached
        # them, breaking chunked/one-shot equivalence.
        self.fold_counts = np.zeros((0,), np.int64)     # (cap,)
        self.rep_crops: Optional[np.ndarray] = None     # (cap, *crop_shape)
        self.first_objs = np.zeros((0,), np.int64)      # first member id
        self.row_cids = np.zeros((0,), np.int64)        # row -> cid
        self.versions = np.zeros((0,), np.int64)        # centroid generation
        self._cid_to_row: Dict[int, int] = {}
        # member/frame log for CNN-folded objects (append order is canonical:
        # it follows the batch partition, which is chunking-invariant)
        self.m_n = 0
        self._m_rows = np.zeros((0,), np.int64)
        self._m_objs = np.zeros((0,), np.int64)
        self._m_frames = np.zeros((0,), np.int64)
        # separate log for attached pixel-diff duplicates: their *timing*
        # depends on when the streaming driver flushed, so they are kept
        # apart and canonicalized by (obj, frame) order whenever read or
        # saved — a chunked ingest and a one-shot ingest produce the same
        # bytes regardless of when duplicates were attached
        self.a_n = 0
        self._a_rows = np.zeros((0,), np.int64)
        self._a_objs = np.zeros((0,), np.int64)
        self._a_frames = np.zeros((0,), np.int64)
        self._csr = None                       # (order, indptr, objs, frames)
        self._sorter = None                             # argsort of row_cids

    # -- rows ------------------------------------------------------------------

    def row_of(self, cid: int) -> int:
        return self._cid_to_row[cid]

    def rows_of(self, cids) -> np.ndarray:
        """Vectorized cid -> row map; raises KeyError on unknown cids (the
        dict-era contract)."""
        cids = np.asarray(cids, np.int64)
        if len(cids) == 0:
            return np.zeros((0,), np.int64)
        if self.n_rows == 0:
            raise KeyError(f"unknown cluster ids: {cids.tolist()[:5]}")
        rc = self.row_cids[:self.n_rows]
        if self._sorter is None:
            self._sorter = np.argsort(rc, kind="stable")
        pos = np.searchsorted(rc, cids, sorter=self._sorter)
        rows = self._sorter[np.minimum(pos, self.n_rows - 1)]
        bad = rc[rows] != cids
        if bad.any():
            raise KeyError(f"unknown cluster ids: "
                           f"{np.unique(cids[bad]).tolist()[:5]}")
        return rows

    def _new_rows(self, cids: np.ndarray, feat_dim: int, n_classes: int,
                  crop_shape) -> np.ndarray:
        """Allocate rows for cids (must be unseen); returns row ids.
        ``crop_shape=None`` defers rep_crop storage until a crop-bearing
        add supplies the shape (rows allocated before that read as
        zero crops)."""
        k = len(cids)
        need = self.n_rows + k
        self.centroids = _grow(self.centroids, need, (feat_dim,), np.float32)
        self.mean_probs = _grow(self.mean_probs, need, (n_classes,),
                                np.float32)
        self.counts = _grow(self.counts, need, (), np.int64)
        self.fold_counts = _grow(self.fold_counts, need, (), np.int64)
        if crop_shape is not None or self.rep_crops is not None:
            if crop_shape is None:
                crop_shape = self.rep_crops.shape[1:]
            self.rep_crops = _grow(self.rep_crops, need, crop_shape,
                                   np.float32)
        self.first_objs = _grow(self.first_objs, need, (), np.int64)
        self.row_cids = _grow(self.row_cids, need, (), np.int64)
        self.versions = _grow(self.versions, need, (), np.int64)
        rows = np.arange(self.n_rows, need, dtype=np.int64)
        self.row_cids[rows] = cids
        for c, r in zip(cids.tolist(), rows.tolist()):
            self._cid_to_row[c] = r
        self.n_rows = need
        self._sorter = None
        self._csr = None          # indptr must cover the new rows
        return rows

    def _append_log(self, rows: np.ndarray, obj_ids: np.ndarray,
                    frame_ids: np.ndarray):
        k = len(rows)
        need = self.m_n + k
        self._m_rows = _grow(self._m_rows, need, (), np.int64)
        self._m_objs = _grow(self._m_objs, need, (), np.int64)
        self._m_frames = _grow(self._m_frames, need, (), np.int64)
        self._m_rows[self.m_n:need] = rows
        self._m_objs[self.m_n:need] = obj_ids
        self._m_frames[self.m_n:need] = frame_ids
        self.m_n = need
        self._csr = None

    def _append_attach_log(self, rows: np.ndarray, obj_ids: np.ndarray,
                           frame_ids: np.ndarray):
        k = len(rows)
        need = self.a_n + k
        self._a_rows = _grow(self._a_rows, need, (), np.int64)
        self._a_objs = _grow(self._a_objs, need, (), np.int64)
        self._a_frames = _grow(self._a_frames, need, (), np.int64)
        self._a_rows[self.a_n:need] = rows
        self._a_objs[self.a_n:need] = obj_ids
        self._a_frames[self.a_n:need] = frame_ids
        self.a_n = need
        self._csr = None

    def _attach_canonical(self):
        """Attach-log entries in canonical (obj, frame) order — independent
        of when the streaming driver attached them."""
        rows = self._a_rows[:self.a_n]
        objs = self._a_objs[:self.a_n]
        frames = self._a_frames[:self.a_n]
        if self.a_n == 0:
            return rows, objs, frames
        order = np.lexsort((frames, objs))
        return rows[order], objs[order], frames[order]

    # -- batched ingest --------------------------------------------------------

    def add_batch(self, cids: np.ndarray, feats: np.ndarray,
                  probs: np.ndarray, obj_ids: np.ndarray,
                  frame_ids: np.ndarray, crops: Optional[np.ndarray] = None,
                  ) -> np.ndarray:
        """Fold a batch of objects into their clusters — vectorized.

        cids (B,) may repeat; unseen cids get fresh rows whose rep_crop is
        the first occurrence's crop. Running means are updated with one
        segment-sum per array: for a row with prior count c receiving k new
        values, new_mean = (c·mean + Σx) / (c + k) — exactly k sequential
        running-mean folds.

        Returns the sorted row ids whose centroid/mean_probs changed; their
        ``versions`` entries are bumped so label caches keyed on
        (cid, version) invalidate precisely.
        """
        cids = np.asarray(cids, np.int64)
        if len(cids) == 0:
            return np.zeros((0,), np.int64)
        obj_ids = np.asarray(obj_ids, np.int64)
        frame_ids = np.asarray(frame_ids, np.int64)
        feats = np.asarray(feats, np.float32)
        probs = np.asarray(probs, np.float32)

        # allocate rows for first-seen cids, in first-occurrence order
        uniq, first_pos = np.unique(cids, return_index=True)
        fresh_mask = np.array([c not in self._cid_to_row
                               for c in uniq.tolist()])
        if fresh_mask.any():
            order = np.argsort(first_pos[fresh_mask], kind="stable")
            fresh_cids = uniq[fresh_mask][order]
            fresh_first = first_pos[fresh_mask][order]
            if crops is not None:
                crop_shape = crops.shape[1:]
            elif self.rep_crops is not None:
                crop_shape = self.rep_crops.shape[1:]   # keep existing shape
            else:
                crop_shape = None                       # defer until known
            rows = self._new_rows(fresh_cids, feats.shape[1], probs.shape[1],
                                  crop_shape)
            if crops is not None:
                self.rep_crops[rows] = crops[fresh_first]
            self.first_objs[rows] = obj_ids[fresh_first]

        b_rows = self.rows_of(cids)
        # segment-sum over the *touched* rows only: O(B + k·(D+C)) per
        # batch, independent of total store size (evicted clusters stay in
        # the index, so n_rows grows without bound over a long stream)
        touched, inv = np.unique(b_rows, return_inverse=True)
        k = len(touched)
        add_cnt = np.bincount(inv, minlength=k).astype(np.int64)
        feat_sum = np.zeros((k, feats.shape[1]), np.float64)
        np.add.at(feat_sum, inv, feats.astype(np.float64))
        prob_sum = np.zeros((k, probs.shape[1]), np.float64)
        np.add.at(prob_sum, inv, probs.astype(np.float64))

        old_cnt = self.fold_counts[touched]
        new_cnt = old_cnt + add_cnt
        denom = new_cnt.astype(np.float64)[:, None]
        self.centroids[touched] = (
            (self.centroids[touched] * old_cnt[:, None] + feat_sum)
            / denom).astype(np.float32)
        self.mean_probs[touched] = (
            (self.mean_probs[touched] * old_cnt[:, None] + prob_sum)
            / denom).astype(np.float32)
        self.fold_counts[touched] = new_cnt
        self.counts[touched] += add_cnt
        self.versions[touched] += 1
        self._append_log(b_rows, obj_ids, frame_ids)
        return touched

    def attach(self, cids: np.ndarray, obj_ids: np.ndarray,
               frame_ids: np.ndarray):
        """Attach members without moving centroids/probs (pixel-diff
        duplicates share their root's cluster, §4.2)."""
        cids = np.asarray(cids, np.int64)
        if len(cids) == 0:
            return
        rows = self.rows_of(cids)
        uniq, cnt = np.unique(rows, return_counts=True)
        # focuslint: disable=cache-version -- intentional exemption:
        # attach only bumps counts; GT labels key on (cid, version) over
        # centroids/mean_probs, which attach leaves untouched
        self.counts[uniq] += cnt
        self._append_attach_log(rows, np.asarray(obj_ids, np.int64),
                                np.asarray(frame_ids, np.int64))

    # -- reads -----------------------------------------------------------------

    def _build_csr(self):
        """CSR over the combined log: fold entries (append order) followed
        by attach entries in canonical order, so per-row member lists are
        identical however the stream was chunked."""
        if self._csr is None:
            a_rows, a_objs, a_frames = self._attach_canonical()
            rows = np.concatenate([self._m_rows[:self.m_n], a_rows])
            objs = np.concatenate([self._m_objs[:self.m_n], a_objs])
            frames = np.concatenate([self._m_frames[:self.m_n], a_frames])
            order = np.argsort(rows, kind="stable")
            counts = np.bincount(rows, minlength=self.n_rows)
            indptr = np.zeros(self.n_rows + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            self._csr = (order, indptr, objs, frames)
        return self._csr

    def drop_log_of(self, row: int):
        """Remove a row's member/frame log entries (cluster replacement —
        rare, O(log size))."""
        keep = self._m_rows[:self.m_n] != row
        kept = int(keep.sum())
        self._m_rows[:kept] = self._m_rows[:self.m_n][keep]
        self._m_objs[:kept] = self._m_objs[:self.m_n][keep]
        self._m_frames[:kept] = self._m_frames[:self.m_n][keep]
        self.m_n = kept
        a_keep = self._a_rows[:self.a_n] != row
        a_kept = int(a_keep.sum())
        self._a_rows[:a_kept] = self._a_rows[:self.a_n][a_keep]
        self._a_objs[:a_kept] = self._a_objs[:self.a_n][a_keep]
        self._a_frames[:a_kept] = self._a_frames[:self.a_n][a_keep]
        self.a_n = a_kept
        self._csr = None

    def members_of(self, row: int):
        order, indptr, objs, frames = self._build_csr()
        sel = order[indptr[row]:indptr[row + 1]]
        return objs[sel], frames[sel]

    def frames_of_rows(self, rows: np.ndarray) -> np.ndarray:
        """Union of frame ids over the given rows — O(selected members) via
        the cached CSR, not a scan of the whole log."""
        order, indptr, _, frames = self._build_csr()
        if len(rows) == 0:
            return np.array([], np.int64)
        sel = np.concatenate([order[indptr[r]:indptr[r + 1]] for r in rows])
        return np.unique(frames[sel]).astype(np.int64)

    def frames_of_each(self, rows: np.ndarray) -> List[np.ndarray]:
        """Per-row sorted unique frame ids (one array per row) — lets a
        caller detach from the store before it knows which rows it will
        keep (archive fan-out under an LRU-bounded loader)."""
        order, indptr, _, frames = self._build_csr()
        return [np.unique(frames[order[indptr[r]:indptr[r + 1]]]
                          ).astype(np.int64) for r in rows]


class _ViewCluster(Cluster):
    """Materialized snapshot handed out by ``index.clusters``; writes do not
    reach the store, so the mutating entry point fails loudly."""

    def add(self, *a, **kw):
        raise TypeError(
            "index.clusters[...] is a read-only snapshot; ingest through "
            "TopKIndex.add_batch/attach/add_cluster instead")


class _ClustersView(Mapping):
    """Read-only dict-like view materializing ``Cluster`` records from the
    SoA store on access (compat for ``index.clusters[cid].members[0]``-style
    callers; hot paths should use the vectorized TopKIndex methods)."""

    def __init__(self, store: ClusterStore):
        self._store = store

    def __getitem__(self, cid: int) -> Cluster:
        s = self._store
        row = s._cid_to_row[cid]
        members, frames = s.members_of(row)
        return _ViewCluster(
            cluster_id=int(cid),
            centroid=s.centroids[row],
            rep_crop=(s.rep_crops[row] if s.rep_crops is not None
                      else np.zeros((0,), np.float32)),
            mean_probs=s.mean_probs[row],
            count=int(s.counts[row]),
            members=members.tolist(),
            frames=frames.tolist(),
        )

    def __len__(self) -> int:
        return self._store.n_rows

    def __iter__(self) -> Iterator[int]:
        return iter(self._store.row_cids[:self._store.n_rows].tolist())


class TopKIndex:
    """class -> clusters inverted index, built at ingest time."""

    def __init__(self, K: int, n_local_classes: int,
                 class_map: Optional[ClassMap] = None):
        self.K = K
        self.n_local_classes = n_local_classes
        self.class_map = class_map
        self.store = ClusterStore()
        self._ranks: Optional[np.ndarray] = None   # (M, C) int32; K = miss

    @property
    def clusters(self) -> _ClustersView:
        return _ClustersView(self.store)

    # -- ingest-side -----------------------------------------------------------

    def add_cluster(self, cluster: Cluster):
        s = self.store
        if cluster.cluster_id in s._cid_to_row:
            # dict-era semantics: re-adding a cid replaces the cluster
            row = s._cid_to_row[cluster.cluster_id]
            s.drop_log_of(row)
        else:
            row = s._new_rows(np.array([cluster.cluster_id], np.int64),
                              len(cluster.centroid),
                              len(cluster.mean_probs),
                              cluster.rep_crop.shape)[0]
        s.centroids[row] = cluster.centroid
        s.mean_probs[row] = cluster.mean_probs
        s.rep_crops[row] = cluster.rep_crop
        s.counts[row] = cluster.count
        s.fold_counts[row] = cluster.count
        s.versions[row] += 1
        if cluster.members:
            s.first_objs[row] = cluster.members[0]
            s._append_log(np.full(len(cluster.members), row, np.int64),
                          np.asarray(cluster.members, np.int64),
                          np.asarray(cluster.frames, np.int64))
        self._refresh_ranks(np.array([row], np.int64))

    def add_batch(self, cids, feats, probs, obj_ids, frame_ids, crops=None):
        touched = self.store.add_batch(cids, feats, probs, obj_ids,
                                       frame_ids, crops)
        self._refresh_ranks(touched)
        return touched

    def attach(self, cids, obj_ids, frame_ids):
        self.store.attach(cids, obj_ids, frame_ids)

    # -- query-side ------------------------------------------------------------

    def _rank_rows(self, P: np.ndarray) -> np.ndarray:
        """Rank matrix (m, C) for probability rows P: rank of class c in the
        row's top-K mean probs, or K when c is outside the top-K — one
        vectorized sort over the rows instead of a per-cluster Python loop.

        Ties break to the LOWEST class index (stable argsort on the negated
        rows) — the same tie order as ``jax.lax.top_k`` and the
        ``dequant_topk`` kernel's extraction loop, so the archive's lazy
        quantized rank path agrees with this eager path even where
        quantization collapses nearby probabilities into exact ties."""
        m, C = P.shape
        K = min(self.K, C)
        top = np.argsort(-P, axis=1, kind="stable")[:, :K]     # (m, K)
        ranks = np.full((m, C), K, np.int32)
        np.put_along_axis(ranks, top,
                          np.broadcast_to(np.arange(K, dtype=np.int32),
                                          (m, K)), 1)
        return ranks

    def _build(self):
        s = self.store
        M = s.n_rows
        if M == 0:
            self._ranks = np.zeros((0, 0), np.int32)
            return
        self._ranks = self._rank_rows(s.mean_probs[:M])

    def _refresh_ranks(self, rows: np.ndarray):
        """Incrementally maintain the rank matrix for the touched rows only,
        so interleaved ingest/query streaming pays O(touched · C) per batch
        instead of a full O(M · C) rebuild on the next lookup."""
        if self._ranks is None:
            return                       # built lazily on the next lookup
        s = self.store
        M = s.n_rows
        C = s.mean_probs.shape[1] if s.mean_probs is not None else 0
        if self._ranks.shape != (M, C):
            if self._ranks.shape[1] != C:
                self._ranks = None       # class width changed: full rebuild
                return
            grown = np.full((M, C), min(self.K, C), np.int32)
            grown[:self._ranks.shape[0]] = self._ranks
            self._ranks = grown
        rows = np.asarray(rows, np.int64)
        if len(rows):
            self._ranks[rows] = self._rank_rows(s.mean_probs[rows])

    def lookup(self, global_class: int, Kx: Optional[int] = None) -> List[int]:
        """Cluster ids whose top-Kx (local) classes include the queried
        class. ``Kx=None`` means the ingest-time K; ``Kx=0`` selects no
        clusters; negative Kx is an error, and so is ``Kx > K`` — rank
        information beyond the ingest-time top-K was never stored, so
        silently clamping would drop clusters whose class sits at rank
        K..Kx-1 with no signal to the caller."""
        if self._ranks is None:
            self._build()
        Kx = _resolve_kx(Kx, self.K)
        local = (self.class_map.to_local(global_class)
                 if self.class_map is not None else global_class)
        if self._ranks.size == 0 or not 0 <= local < self._ranks.shape[1]:
            return []
        rows = np.nonzero(self._ranks[:, local] < Kx)[0]
        return self.store.row_cids[rows].tolist()

    def frames_of(self, cids: Sequence[int]) -> np.ndarray:
        if len(cids) == 0:
            return np.array([], np.int64)
        return self.store.frames_of_rows(self.store.rows_of(cids))

    def rep_crops(self, cids: Sequence[int]) -> np.ndarray:
        if self.store.rep_crops is None:
            raise ValueError("no representative crops were stored "
                             "(add_batch was called without crops)")
        return self.store.rep_crops[self.store.rows_of(cids)]

    def first_members(self, cids: Sequence[int]) -> np.ndarray:
        """First (centroid-representative) object id per cluster —
        vectorized fast path for ``clusters[cid].members[0]``."""
        return self.store.first_objs[self.store.rows_of(cids)]

    # -- stats / persistence ---------------------------------------------------

    @property
    def n_clusters(self) -> int:
        return self.store.n_rows

    @property
    def n_objects(self) -> int:
        return int(self.store.counts[:self.store.n_rows].sum())

    def summary(self) -> dict:
        if self._ranks is None:
            self._build()
        if self._ranks.size:
            K = min(self.K, self._ranks.shape[1])
            n_indexed = int((self._ranks < K).any(axis=0).sum())
        else:
            n_indexed = 0
        return {
            "K": self.K,
            "n_clusters": self.n_clusters,
            "n_objects": self.n_objects,
            "n_classes_indexed": n_indexed,
            "specialized": self.class_map is not None,
        }

    def save(self, path: str, *, format: int = INDEX_FORMAT):
        """Persist index metadata + arrays (MongoDB stand-in, §5).

        Format v4 (default) is quantized columnar: one mmap-able ``.npy``
        per field — centroids int8 + per-row scales, mean-probs uint8 +
        per-row scales, rep-crops uint8 on one per-shard affine grid, and
        log/int columns narrowed to the smallest int dtype holding their
        range. Every quantization parameter is a pure function of the
        array values, so equal indexes still save byte-identically (the
        rollover / chunked-one-shot / mesh invariants carry over to v4
        unchanged). Format v3 (``format=3``) keeps the fp32 single-npz
        columnar layout for baselines and migration tests; the attach log
        is written in canonical (obj, frame) order in both. ``load`` reads
        all four layouts (v1 dict-era, v2 single-log, v3, v4).
        """
        if format not in (3, 4):
            raise ValueError(f"unsupported save format {format}")
        s = self.store
        M = s.n_rows
        log_rows = s._m_rows[:s.m_n]
        att_rows, att_objs, att_frames = s._attach_canonical()
        meta = {
            "format": format,
            "K": self.K,
            "n_local_classes": self.n_local_classes,
            "class_map": (self.class_map.global_ids.tolist()
                          if self.class_map else None),
        }
        if format == 3:
            arrays = {
                "row_cids": s.row_cids[:M],
                "centroids": (s.centroids[:M] if s.centroids is not None
                              else np.zeros((M, 0), np.float32)),
                "mean_probs": (s.mean_probs[:M] if s.mean_probs is not None
                               else np.zeros((M, 0), np.float32)),
                "rep_crops": (s.rep_crops[:M] if s.rep_crops is not None
                              else np.zeros((M, 0), np.float32)),
                "counts": s.counts[:M],
                "first_objs": s.first_objs[:M],
                "versions": s.versions[:M],
                "log_cids": s.row_cids[log_rows],
                "log_objs": s._m_objs[:s.m_n],
                "log_frames": s._m_frames[:s.m_n],
                "att_cids": s.row_cids[att_rows],
                "att_objs": att_objs,
                "att_frames": att_frames,
            }
            np.savez_compressed(path + ".npz", **arrays)
            with open(path + ".json", "w") as f:
                json.dump(meta, f)
            return

        cents_q, cent_scales = _quant_rows_int8(
            s.centroids[:M] if s.centroids is not None else None, M)
        probs_q, prob_scales = _quant_rows_uint8(
            s.mean_probs[:M] if s.mean_probs is not None else None, M)
        crops = (s.rep_crops[:M] if s.rep_crops is not None
                 else np.zeros((M, 0), np.float32))
        crops_q, crop_qparams = _quant_global_uint8(crops)
        columns = {
            "row_cids": _shrink_ints(s.row_cids[:M]),
            "counts": _shrink_ints(s.counts[:M]),
            "first_objs": _shrink_ints(s.first_objs[:M]),
            "versions": _shrink_ints(s.versions[:M]),
            "log_cids": _shrink_ints(s.row_cids[log_rows]),
            "log_objs": _shrink_ints(s._m_objs[:s.m_n]),
            "log_frames": _shrink_ints(s._m_frames[:s.m_n]),
            "att_cids": _shrink_ints(s.row_cids[att_rows]),
            "att_objs": _shrink_ints(att_objs),
            "att_frames": _shrink_ints(att_frames),
            "centroids_q": cents_q,
            "centroid_scales": cent_scales,
            "mean_probs_q": probs_q,
            "prob_scales": prob_scales,
            "rep_crops_q": crops_q,
            "crop_qparams": crop_qparams,
        }
        meta["columns"] = list(columns)
        meta["n_rows"] = int(M)
        meta["crop_shape"] = list(crops.shape[1:])
        # column files first, manifest last: a crash mid-save leaves at
        # worst orphan .npy files that no manifest references
        for name, arr in columns.items():
            np.save(path + f".{name}.npy", arr)
        with open(path + ".json", "w") as f:
            json.dump(meta, f)

    def save_bytes(self, *, format: int = INDEX_FORMAT) -> tuple:
        """((suffix, bytes), ...) of this index as ``save`` writes it —
        THE byte-identity comparison unit pinned by the streaming /
        pipeline / mesh equivalence harnesses and the ingest bench gate.
        One implementation (via ``saved_file_bytes``), so a save-format
        change cannot silently diverge what the harnesses compare."""
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "idx")
            self.save(path, format=format)
            return saved_file_bytes(path)

    def _load_columnar(self, arrays: Mapping):
        s = self.store
        cids = np.asarray(arrays["row_cids"], np.int64)
        if len(cids) == 0:
            return
        cents = np.asarray(arrays["centroids"], np.float32)
        probs = np.asarray(arrays["mean_probs"], np.float32)
        crops = np.asarray(arrays["rep_crops"], np.float32)
        crop_shape = crops.shape[1:] if crops.shape[1:] != (0,) else None
        rows = s._new_rows(cids, cents.shape[1], probs.shape[1], crop_shape)
        s.centroids[rows] = cents
        s.mean_probs[rows] = probs
        if crop_shape is not None:
            s.rep_crops[rows] = crops
        s.counts[rows] = np.asarray(arrays["counts"], np.int64)
        s.fold_counts[rows] = s.counts[rows]     # attach share removed below
        s.first_objs[rows] = np.asarray(arrays["first_objs"], np.int64)
        s.versions[rows] = np.asarray(arrays["versions"], np.int64)
        log_cids = np.asarray(arrays["log_cids"], np.int64)
        if len(log_cids):
            s._append_log(s.rows_of(log_cids),
                          np.asarray(arrays["log_objs"], np.int64),
                          np.asarray(arrays["log_frames"], np.int64))
        if "att_cids" in arrays:        # v3: separate attach log
            att_cids = np.asarray(arrays["att_cids"], np.int64)
            if len(att_cids):
                att_rows = s.rows_of(att_cids)
                s._append_attach_log(
                    att_rows,
                    np.asarray(arrays["att_objs"], np.int64),
                    np.asarray(arrays["att_frames"], np.int64))
                s.fold_counts[:s.n_rows] -= np.bincount(
                    att_rows, minlength=s.n_rows).astype(np.int64)

    @classmethod
    def load(cls, path: str) -> "TopKIndex":
        with open(path + ".json") as f:
            meta = json.load(f)
        cmap = (ClassMap(np.array(meta["class_map"]))
                if meta["class_map"] is not None else None)
        idx = cls(meta["K"], meta["n_local_classes"], cmap)
        fmt = meta.get("format", 1)
        if fmt >= 4:
            cols = {name: np.load(path + f".{name}.npy")
                    for name in meta["columns"]}
            idx._load_columnar(_dequant_v4(meta, cols))
            return idx
        arrays = np.load(path + ".npz")
        if fmt >= 2:
            idx._load_columnar(arrays)
        else:                      # dict-era layout: per-cid npz keys
            for cid_s, info in meta["clusters"].items():
                cid = int(cid_s)
                idx.add_cluster(Cluster(
                    cid, arrays[f"centroid_{cid}"], arrays[f"crop_{cid}"],
                    arrays[f"probs_{cid}"], count=info["count"],
                    members=info["members"], frames=info["frames"]))
        return idx

    @property
    def nbytes(self) -> int:
        """Heap bytes of the store's arrays (allocated capacity) plus the
        rank matrix — the resident-size unit the archive's bytes-bounded
        ``ShardLoader`` accounts eagerly loaded shards with."""
        s = self.store
        total = 0
        for a in (s.centroids, s.mean_probs, s.rep_crops):
            if a is not None:
                total += a.nbytes
        for a in (s.counts, s.fold_counts, s.first_objs, s.row_cids,
                  s.versions, s._m_rows, s._m_objs, s._m_frames,
                  s._a_rows, s._a_objs, s._a_frames):
            total += a.nbytes
        if s._csr is not None:
            total += sum(int(x.nbytes) for x in s._csr)
        if s._sorter is not None:
            total += s._sorter.nbytes
        if self._ranks is not None:
            total += self._ranks.nbytes
        return total


def _dequant_v4(meta: Mapping, cols: Mapping) -> Dict[str, np.ndarray]:
    """Reconstruct the v3-shaped column mapping from v4 quantized columns
    (the shared dequant math — bitwise identical to the lazy archive
    path's in-kernel / per-row dequantization)."""
    M = int(meta["n_rows"])
    cents = (cols["centroids_q"].astype(np.float32)
             * (CENT_GLOBAL_SCALE
                * cols["centroid_scales"].astype(np.float32))[:, None])
    probs = (cols["mean_probs_q"].astype(np.float32)
             * (PROB_GLOBAL_SCALE
                * cols["prob_scales"].astype(np.float32))[:, None])
    crop_shape = tuple(meta["crop_shape"])
    crops = dequant_crops(cols["rep_crops_q"],
                          cols["crop_qparams"]).reshape((M, *crop_shape))
    out = {"centroids": cents, "mean_probs": probs, "rep_crops": crops}
    for name in ("row_cids", "counts", "first_objs", "versions",
                 "log_cids", "log_objs", "log_frames",
                 "att_cids", "att_objs", "att_frames"):
        out[name] = np.asarray(cols[name], np.int64)
    return out
