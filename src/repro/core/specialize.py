"""Video-specific CNN specialization (paper §4.3).

Periodically sample the stream, classify the sample with GT-CNN to estimate
the class distribution, pick the Ls most frequent classes, and retrain a
cheap CNN on (Ls + OTHER) with the training data re-weighted so OTHER does
not dominate (paper footnote 2). Specialized models are smaller and more
accurate on their stream, which lets Focus use a much smaller K.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import CheapCNNConfig
from repro.core.index import ClassMap
from repro.models import cnn
from repro.train import OptConfig, TrainConfig, train


@dataclass
class SpecializedModel:
    params: dict
    cfg: CheapCNNConfig
    class_map: ClassMap
    history: list

    def make_apply(self, batch_pad: int = 64):
        """Returns apply(crops) -> (probs (B, Ls+1), feats (B, D)), jitted
        with shape bucketing so ingest batches of ragged size reuse the
        compiled executable."""
        cfg = self.cfg
        params = self.params

        @jax.jit
        def fwd(crops):
            logits, feats = cnn.forward(params, crops, cfg)
            return jax.nn.softmax(logits, axis=-1), feats

        # focuslint: disable=host-sync -- staged boundary by contract:
        # make_apply returns host arrays to the numpy fold
        def apply(crops: np.ndarray):
            n = len(crops)
            if n == 0:
                return (np.zeros((0, cfg.n_classes), np.float32),
                        np.zeros((0, cfg.feature_dim), np.float32))
            pad = (-n) % batch_pad
            if pad:
                crops = np.concatenate(
                    [crops, np.zeros((pad,) + crops.shape[1:], crops.dtype)])
            probs, feats = fwd(jnp.asarray(crops))
            return np.asarray(probs)[:n], np.asarray(feats)[:n]

        return apply

    def make_traceable(self) -> Callable:
        """The bare jax-traceable forward ``crops -> (probs, feats)`` —
        what a fused ``IngestPipeline``/``ShardedIngestPipeline`` inlines
        into its megastep (``make_apply`` wraps the same computation in a
        host pad/unpad boundary, which cannot be traced)."""
        cfg = self.cfg
        params = self.params

        def fwd(crops):
            logits, feats = cnn.forward(params, crops, cfg)
            return jax.nn.softmax(logits, axis=-1), feats

        return fwd


def estimate_distribution(gt_labels: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(classes, counts) sorted by decreasing frequency."""
    vals, counts = np.unique(gt_labels, return_counts=True)
    order = np.argsort(-counts)
    return vals[order], counts[order]


def specialize(sample_crops: np.ndarray, sample_gt_labels: np.ndarray,
               Ls: int, base_cfg: CheapCNNConfig, steps: int = 300,
               batch_size: int = 128, lr: float = 3e-3, seed: int = 0,
               ) -> SpecializedModel:
    """Retrain ``base_cfg`` on the stream's top-Ls classes + OTHER."""
    classes, _ = estimate_distribution(sample_gt_labels)
    keep = np.sort(classes[:Ls])
    cmap = ClassMap(global_ids=keep)

    local = np.full(len(sample_gt_labels), cmap.other_local, np.int32)
    for li, g in enumerate(keep):
        local[sample_gt_labels == g] = li

    # equal-class re-weighting (paper footnote 2). ``Ls`` may exceed the
    # number of observed classes (keep is then just the observed set) and a
    # sample may contain a single class — the normalizer below must stay
    # finite in both cases, so guard the empty-positive edge.
    counts = np.bincount(local, minlength=cmap.n_local).astype(np.float64)
    w = np.where(counts > 0, counts.sum() / np.maximum(counts, 1), 0.0)
    pos = counts > 0
    w = w / w[pos].mean() if pos.any() else np.ones_like(w)
    weights = jnp.asarray(w, jnp.float32)

    cfg = dataclasses.replace(base_cfg,
                              name=f"{base_cfg.name}-spec{Ls}",
                              n_classes=cmap.n_local)
    rng = jax.random.PRNGKey(seed)
    params = cnn.init(rng, cfg)

    def loss_fn(params, batch, rng):
        return cnn.loss_fn(params, batch["x"], batch["y"], cfg,
                           label_weights=weights)

    def data_iter():
        r = np.random.default_rng(seed)
        n = len(sample_crops)
        while True:
            idx = r.integers(0, n, size=batch_size)
            yield {"x": jnp.asarray(sample_crops[idx]),
                   "y": jnp.asarray(local[idx])}

    opt_cfg = OptConfig(lr=lr, warmup_steps=min(50, steps // 5),
                        total_steps=steps, weight_decay=1e-4)
    params, history = train(loss_fn, params, data_iter(), opt_cfg,
                            TrainConfig(steps=steps, log_every=max(steps // 4, 1)))
    return SpecializedModel(params, cfg, cmap, history)


def train_generic(sample_crops: np.ndarray, sample_gt_labels: np.ndarray,
                  base_cfg: CheapCNNConfig, steps: int = 300,
                  batch_size: int = 128, lr: float = 3e-3, seed: int = 0):
    """Train a *generic* (non-specialized) cheap CNN over the full global
    class space — the "Compressed model" rung of Fig. 8."""
    cfg = base_cfg
    rng = jax.random.PRNGKey(seed)
    params = cnn.init(rng, cfg)

    def loss_fn(params, batch, rng):
        return cnn.loss_fn(params, batch["x"], batch["y"], cfg)

    def data_iter():
        r = np.random.default_rng(seed)
        n = len(sample_crops)
        while True:
            idx = r.integers(0, n, size=batch_size)
            yield {"x": jnp.asarray(sample_crops[idx]),
                   "y": jnp.asarray(sample_gt_labels[idx].astype(np.int32))}

    opt_cfg = OptConfig(lr=lr, warmup_steps=min(50, steps // 5),
                        total_steps=steps, weight_decay=1e-4)
    params, history = train(loss_fn, params, data_iter(), opt_cfg,
                            TrainConfig(steps=steps, log_every=max(steps // 4, 1)))
    return SpecializedModel(params, cfg, None, history)
