"""Focus query-time pipeline (paper Fig. 4, right; §4.2, §5).

query(class X) -> top-K index lookup -> GT-CNN on cluster *centroids only*
               -> keep clusters whose centroid classifies as X
               -> return all member frames of kept clusters

Also provides the two baseline cost models the paper compares against
(Ingest-all / Query-all, both strengthened with motion detection) and the
frame-level precision/recall metrics relative to GT-CNN ground truth.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.index import OTHER, TopKIndex


@dataclass
class QueryResult:
    queried_class: int
    frames: np.ndarray                 # frame ids returned to the user
    matched_clusters: List[int]
    n_candidate_clusters: int
    n_gt_invocations: int
    gt_flops: float
    wall_s: float


def pad_to_bucket(crops: np.ndarray, bucket: int = 64) -> np.ndarray:
    """Zero-pad the leading axis up to the next multiple of ``bucket`` (the
    same shape-bucketing ``SpecializedModel.make_apply`` uses), so a jitted
    GT-CNN sees O(batch/bucket) distinct shapes instead of recompiling on
    every ragged final chunk."""
    pad = (-len(crops)) % bucket
    if pad:
        crops = np.concatenate(
            [crops, np.zeros((pad,) + crops.shape[1:], crops.dtype)])
    return crops


def query(index: TopKIndex, global_class: int,
          gt_apply: Callable[[np.ndarray], np.ndarray],
          gt_flops_per_image: float, Kx: Optional[int] = None,
          batch_size: int = 256, batch_pad: int = 64) -> QueryResult:
    """gt_apply(crops (B,R,R,3)) -> predicted *global* class ids (B,)."""
    t0 = time.perf_counter()
    cids = index.lookup(global_class, Kx)
    matched: List[int] = []
    n_gt = 0
    for start in range(0, len(cids), batch_size):
        chunk = np.asarray(cids[start:start + batch_size])
        padded = pad_to_bucket(index.rep_crops(chunk), batch_pad)
        labels = np.asarray(gt_apply(padded))[:len(chunk)]
        n_gt += len(chunk)                 # only real crops are accounted
        matched.extend(chunk[labels == global_class].tolist())
    frames = index.frames_of(matched)
    return QueryResult(
        queried_class=global_class, frames=frames, matched_clusters=matched,
        n_candidate_clusters=len(cids), n_gt_invocations=n_gt,
        gt_flops=n_gt * gt_flops_per_image,
        wall_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Ground truth + metrics (frame-level, GT-CNN as oracle — §6.1)
# ---------------------------------------------------------------------------

def gt_frames_by_class(gt_labels: np.ndarray,
                       frames: np.ndarray) -> Dict[int, np.ndarray]:
    """For each class, the sorted frame ids where GT-CNN saw that class —
    one lexsort over (label, frame) pairs, no per-object Python loop."""
    gt_labels = np.asarray(gt_labels, np.int64)
    frames = np.asarray(frames, np.int64)
    if len(gt_labels) == 0:
        return {}
    order = np.lexsort((frames, gt_labels))
    labs, fs = gt_labels[order], frames[order]
    keep = np.ones(len(labs), bool)         # drop duplicate (label, frame)
    keep[1:] = (labs[1:] != labs[:-1]) | (fs[1:] != fs[:-1])
    labs, fs = labs[keep], fs[keep]
    starts = np.nonzero(np.r_[True, labs[1:] != labs[:-1]])[0]
    bounds = np.r_[starts, len(labs)]
    return {int(labs[starts[i]]): fs[bounds[i]:bounds[i + 1]]
            for i in range(len(starts))}


def precision_recall(result_frames: np.ndarray,
                     gt_frames: np.ndarray) -> tuple:
    rs, gs = set(result_frames.tolist()), set(gt_frames.tolist())
    tp = len(rs & gs)
    precision = tp / len(rs) if rs else 1.0
    recall = tp / len(gs) if gs else 1.0
    return precision, recall


def dominant_classes(gt_labels: np.ndarray, top_frac: float = 0.95,
                     max_classes: int = 20) -> List[int]:
    """The most frequent classes covering ``top_frac`` of objects (§6.1
    evaluates all dominant classes of each stream)."""
    vals, counts = np.unique(gt_labels, return_counts=True)
    order = np.argsort(-counts)
    cum = np.cumsum(counts[order]) / counts.sum()
    cut = int(np.searchsorted(cum, top_frac)) + 1
    return [int(v) for v in vals[order[:min(cut, max_classes)]]]


# ---------------------------------------------------------------------------
# Baseline cost models (paper §6.1 Baselines)
# ---------------------------------------------------------------------------

@dataclass
class BaselineCosts:
    """Costs in FLOPs (device-independent) for the two baselines.

    Both are strengthened with motion detection: only frames with moving
    objects are processed (the n_objects stream is already post-detection).
    """
    n_objects: int
    gt_flops_per_image: float

    @property
    def ingest_all_flops(self) -> float:    # GT-CNN on everything at ingest
        return self.n_objects * self.gt_flops_per_image

    @property
    def query_all_flops(self) -> float:     # GT-CNN on everything at query
        return self.n_objects * self.gt_flops_per_image


def gpu_seconds(flops: float, peak_flops: float = 6.1e12,
                utilization: float = 0.35) -> float:
    """Convert model FLOPs to GPU-seconds on the paper's GTX Titan X
    (~6.1 TFLOP/s fp32, ~35% achieved utilization on CNN inference)."""
    return flops / (peak_flops * utilization)
