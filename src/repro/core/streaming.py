"""Streaming multi-stream ingest with query-while-ingest (paper §5, Fig. 4).

Focus's deployment shape is a fleet of cameras ingested *continuously*
while "after the fact" queries arrive mid-stream. ``StreamingIngestor``
accepts chunked ``(crops, frames)`` feeds for one stream and maintains
clustering state + the top-K index incrementally across calls — carrying
``slot_cid``, pixel-track roots, and eviction remaps over chunk
boundaries. ``MultiStreamRunner`` round-robins N streams through one
shared bucket-padded cheap-CNN executable.

Determinism contract (pinned by ``tests/test_streaming.py``): chunk
boundaries are invisible. Unique objects are buffered and cut into CNN
batches of exactly ``cfg.batch_size``, so the batch partition — and with
it the clustering fold order, slot -> cid assignment, and eviction points
— is a function of the concatenated stream only. Pixel-diff duplicates
go to the index's separate attach log, canonicalized at read/save time,
so *when* the driver flushed them is equally invisible. One-shot
``ingest()`` is the single-chunk special case, and a chunked run saves
byte-identically to it.

Freshness model for query-while-ingest: ``feed`` folds every complete
batch immediately; ``flush`` attaches the pixel-diff duplicates whose
root's batch has folded and publishes an ``IngestDelta`` naming the
new/moved clusters, which is exactly what a ``QueryEngine`` needs to
``prefetch`` so warm queries between chunks stay off the GT-CNN path.
The only objects a query cannot see yet are the < ``batch_size`` uniques
still waiting for a full batch and the duplicates chained to them.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core import clustering as C
from repro.core.index import ClassMap, TopKIndex
from repro.core.ingest import IngestConfig, IngestStats
from repro.data.bgsub import match_flat, pixel_difference


@dataclass
class IngestDelta:
    """What one ``flush()`` made newly visible to queries."""
    n_objects_published: int         # uniques folded + duplicates attached
    new_cids: List[int]              # clusters created since the last flush
    touched_cids: List[int]          # live-shard clusters whose centroid
                                     # moved (sorted, includes the new ones)
    n_evictions: int
    n_pending_unique: int            # buffered, awaiting a full CNN batch
    n_pending_dups: int              # awaiting their root's batch
    sealed_shards: List[int] = field(default_factory=list)
    touched_sealed: List[Tuple[int, int]] = field(default_factory=list)
    # (shard_id, cid) for clusters touched since the last flush whose
    # shard has since been sealed — what an ArchiveQueryEngine prefetches


class _PixelTracker:
    """Streaming §4.2 pixel differencing.

    Mirrors ``ingest.pixel_tracks`` exactly, but over an unbounded stream:
    a frame group may arrive split across chunks (the *open* frame keeps
    accepting members until a later frame appears), while the previous
    frame's completed group — crops and resolved root ids — is retained
    for matching. Requires frames to arrive in non-decreasing order.
    """

    def __init__(self, threshold: float):
        self.threshold = threshold
        self._open_frame: Optional[int] = None
        self._open_crops: List[np.ndarray] = []
        self._open_roots: List[np.ndarray] = []
        self._prev_frame: Optional[int] = None
        self._prev_crops: Optional[np.ndarray] = None
        self._prev_roots: Optional[np.ndarray] = None

    def resolve(self, f: int, crops: np.ndarray,
                obj_ids: np.ndarray) -> np.ndarray:
        """Root object ids for one (possibly partial) frame-``f`` group."""
        if self._open_frame is not None and f < self._open_frame:
            raise ValueError(
                f"frames must be non-decreasing across feeds: got frame {f} "
                f"after frame {self._open_frame}")
        if self._open_frame is None or f > self._open_frame:
            if self._open_crops:
                self._prev_frame = self._open_frame
                self._prev_crops = np.concatenate(self._open_crops)
                self._prev_roots = np.concatenate(self._open_roots)
            self._open_frame = f
            self._open_crops, self._open_roots = [], []
        roots = obj_ids.copy()
        if self._prev_frame == f - 1 and self._prev_crops is not None \
                and len(self._prev_crops):
            match = pixel_difference(crops, self._prev_crops, self.threshold)
            m = match >= 0
            roots[m] = self._prev_roots[match[m]]
        self._open_crops.append(crops)
        self._open_roots.append(roots)
        return roots

    def amend_last(self, roots: np.ndarray):
        """Replace the roots of the most recent ``resolve`` segment.

        The redundancy gate rewrites roots *after* the tracker resolved a
        group; the tracker must see the rewrite, or a next-frame tracker
        match would chain to the crop's own (never-CNN'd, never-folded)
        id and its duplicate record could never attach.
        """
        self._open_roots[-1] = np.asarray(roots, np.int64)


class _RedundancyGate:
    """Cross-frame redundancy gate in front of the CNN (DESIGN.md §10).

    The §4.2 tracker only matches consecutive frames; on a static camera
    the same object re-surfaces for minutes. This gate keeps a bounded
    FIFO ring of the most recent *CNN-bound* unique crops (flattened)
    with their root ids; a new crop matching a ring entry (mean abs diff
    STRICTLY below ``threshold``, via ``bgsub.match_flat`` — the Pallas
    ``pixel_diff`` kernel on accelerators) skips the CNN and attaches to
    the ring root's cluster through the duplicate/attach log.

    Chunk invariance: matching only sees entries from strictly earlier
    frames — a frame's own uniques are queued and admitted to the ring
    when the frame *closes* (a later frame arrives), mirroring the
    tracker's open/prev machinery, so a frame group split across chunks
    gates identically to an unsplit feed. Ring admission and trimming
    happen per closed frame group, a function of the stream alone.
    """

    def __init__(self, threshold: float, capacity: int,
                 backend: str = "auto"):
        if capacity < 1:
            raise ValueError(f"gate_capacity must be >= 1, got {capacity}")
        self.threshold = threshold
        self.capacity = capacity
        self.backend = backend
        self._ring_crops: List[np.ndarray] = []    # per-frame (k, D) groups
        self._ring_roots: List[np.ndarray] = []
        self._n = 0
        self._open_frame: Optional[int] = None
        self._open_crops: List[np.ndarray] = []
        self._open_roots: List[np.ndarray] = []

    def match(self, f: int, crops2d: np.ndarray) -> np.ndarray:
        """Ring root id per crop (or -1) for one frame-``f`` segment.
        Also advances the open-frame bookkeeping, so call it once per
        resolved segment even when ``crops2d`` is empty."""
        if self._open_frame is None or f > self._open_frame:
            if self._open_crops:
                self._push(np.concatenate(self._open_crops),
                           np.concatenate(self._open_roots))
                self._open_crops, self._open_roots = [], []
            self._open_frame = f
        out = np.full((len(crops2d),), -1, np.int64)
        if self._n == 0 or len(crops2d) == 0:
            return out
        m = match_flat(crops2d, np.concatenate(self._ring_crops),
                       self.threshold, backend=self.backend)
        hit = m >= 0
        if hit.any():
            roots = np.concatenate(self._ring_roots)
            out[hit] = roots[m[hit]]
        return out

    def admit(self, crops2d: np.ndarray, roots: np.ndarray):
        """Queue frame-``f`` CNN-bound uniques (f = the frame of the last
        ``match`` call); they join the ring when the frame closes."""
        if len(crops2d):
            self._open_crops.append(crops2d)
            self._open_roots.append(np.asarray(roots, np.int64))

    def _push(self, crops: np.ndarray, roots: np.ndarray):
        self._ring_crops.append(crops)
        self._ring_roots.append(roots)
        self._n += len(roots)
        # trim whole frame groups while the remainder still covers the
        # capacity: ring size stays in [capacity, capacity + group)
        while len(self._ring_roots) > 1 \
                and self._n - len(self._ring_roots[0]) >= self.capacity:
            self._n -= len(self._ring_roots[0])
            self._ring_crops.pop(0)
            self._ring_roots.pop(0)

    def live_roots(self) -> set:
        """Root ids a future gate match may still return (ring + open) —
        their ``_root_cid`` entries must survive pruning."""
        keep: set = set()
        for seg in self._ring_roots:
            keep.update(seg.tolist())
        for seg in self._open_roots:
            keep.update(seg.tolist())
        return keep


class _ChunkBuffer:
    """Unique-object buffer as a list of chunks: appends are O(1) and
    ``take`` concatenates only the rows taken, replacing the old
    O(n²) ``np.concatenate`` growth. ``take`` on an empty buffer returns
    correctly-shaped empties (the old array-growth buffer crashed with
    ``None[:0]`` before the first unique arrived)."""

    def __init__(self):
        self._crops: List[np.ndarray] = []
        self._objs: List[np.ndarray] = []
        self._frames: List[np.ndarray] = []
        self._n = 0
        self._crop_shape: Optional[tuple] = None
        self._dtype = np.float32

    def __len__(self) -> int:
        return self._n

    def append(self, crops: np.ndarray, objs: np.ndarray,
               frames: np.ndarray):
        if self._crop_shape is None and crops.ndim > 1:
            self._crop_shape = crops.shape[1:]
            self._dtype = crops.dtype
        if len(objs) == 0:
            return
        self._crops.append(crops)
        self._objs.append(np.asarray(objs, np.int64))
        self._frames.append(np.asarray(frames, np.int64))
        self._n += len(objs)

    def _empty(self):
        shape = (0,) + (self._crop_shape if self._crop_shape is not None
                        else (0, 0, 3))
        return (np.zeros(shape, self._dtype), np.zeros((0,), np.int64),
                np.zeros((0,), np.int64))

    def take(self, k: int):
        """Pop the first ``k`` rows (all rows if ``k`` exceeds the
        buffer)."""
        if k <= 0 or self._n == 0:
            return self._empty()
        k = min(k, self._n)
        crops, objs, frames, got = [], [], [], 0
        while got < k:
            c, o, f = self._crops[0], self._objs[0], self._frames[0]
            need = k - got
            if len(o) <= need:
                self._crops.pop(0)
                self._objs.pop(0)
                self._frames.pop(0)
            else:
                self._crops[0] = c[need:]
                self._objs[0] = o[need:]
                self._frames[0] = f[need:]
                c, o, f = c[:need], o[:need], f[:need]
            crops.append(c)
            objs.append(o)
            frames.append(f)
            got += len(o)
        self._n -= k
        if len(objs) == 1:
            return crops[0], objs[0], frames[0]
        return (np.concatenate(crops), np.concatenate(objs),
                np.concatenate(frames))


class StreamingIngestor:
    """Incremental Focus ingest for one stream, fed in chunks.

    ``cheap_apply(crops (B,R,R,3)) -> (probs (B, C_local), feats (B, D))``
    may be ``None`` when the ingestor is driven by a ``MultiStreamRunner``
    (which supplies CNN outputs for stacked device batches) or when a
    fused ``core.pipeline.IngestPipeline`` is given via ``pipeline=`` —
    the pipeline then runs CNN forward + top-K + clustering as one
    device-resident megastep and routes the host fold back through
    ``_fold_rows`` (DESIGN.md §9). ``feed`` / ``flush`` / ``finish`` are
    the lifecycle; ``ingest()`` in ``core.ingest`` is the single-chunk
    wrapper.

    With a ``catalog`` (``core.archive.ShardCatalog``) the ingestor rolls
    the live index over into time shards: after ``shard_objects`` fed
    objects and/or at absolute ``shard_frames``-wide frame-window
    boundaries, the live index is *sealed* — drained, saved through the
    catalog, and replaced by a fresh one with all clustering/tracker state
    reset. Object ids restart per shard, so every sealed shard is
    byte-identical to a one-shot ``ingest()`` of its window (the rollover
    invariant; ``ShardMeta.obj_base`` maps ids back to global positions).
    ``finish()`` seals the tail shard. Rollover requires a self-driven
    ingestor (``cheap_apply`` given): sealing must drain the tail batch.
    """

    def __init__(self, cheap_apply: Optional[Callable] = None,
                 cheap_flops_per_image: float = 0.0,
                 cfg: Optional[IngestConfig] = None,
                 class_map: Optional[ClassMap] = None,
                 n_local_classes: Optional[int] = None,
                 catalog=None, shard_objects: Optional[int] = None,
                 shard_frames: Optional[int] = None,
                 shard_format: Optional[int] = None, pipeline=None):
        if pipeline is not None and cheap_apply is not None:
            raise ValueError(
                "pass either cheap_apply (host-staged) or pipeline "
                "(fused megastep), not both")
        self.cheap_apply = cheap_apply
        self.cheap_flops_per_image = cheap_flops_per_image
        self.cfg = cfg if cfg is not None else IngestConfig()
        self.class_map = class_map
        self.n_local_classes = n_local_classes
        self.stats = IngestStats()
        self.pipeline = pipeline
        if catalog is not None and cheap_apply is None and pipeline is None:
            raise ValueError(
                "shard rollover needs a self-driven ingestor (cheap_apply "
                "or pipeline); runner-driven ingestors cannot seal")
        if catalog is None and (shard_objects is not None
                                or shard_frames is not None):
            raise ValueError("shard_objects/shard_frames need a catalog")
        if shard_objects is not None and shard_objects < 1:
            raise ValueError(f"shard_objects must be >= 1: {shard_objects}")
        if shard_frames is not None and shard_frames < 1:
            raise ValueError(f"shard_frames must be >= 1: {shard_frames}")
        if shard_format is not None and catalog is None:
            raise ValueError("shard_format needs a catalog")
        self.catalog = catalog
        self.shard_objects = shard_objects
        self.shard_frames = shard_frames
        # None -> the catalog's default (v4 quantized columnar); pin 3 to
        # seal fp32 npz shards (baselines, migration fixtures)
        self.shard_format = shard_format
        if pipeline is not None:
            # bind last: a constructor rejected above must not consume
            # the pipeline (binding is permanent per stream)
            pipeline._bind(self)
        try:
            self._cluster_fn = C.CLUSTER_FNS[self.cfg.clustering]
        except KeyError:
            raise ValueError(
                f"unknown clustering variant {self.cfg.clustering!r}; "
                f"expected one of {sorted(C.CLUSTER_FNS)}") from None
        # the index exists up front whenever the class width is known, so a
        # QueryEngine can bind to it before the first chunk arrives
        self._index: Optional[TopKIndex] = None
        if n_local_classes is not None or class_map is not None:
            nl = (n_local_classes if n_local_classes is not None
                  else class_map.n_local)
            self._index = TopKIndex(self.cfg.K, nl, class_map)
        self._state = None                      # lazy: dims from first batch
        self._slot_cid = np.full(self.cfg.max_clusters, -1, np.int64)
        self._next_cid = 0
        self._tracker = _PixelTracker(self.cfg.pixel_diff_threshold)
        self._gate = (_RedundancyGate(self.cfg.gate_threshold,
                                      self.cfg.gate_capacity)
                      if self.cfg.gate else None)
        if self.cfg.frame_stride < 1:
            raise ValueError(
                f"frame_stride must be >= 1: {self.cfg.frame_stride}")
        self._frame_stride = self.cfg.frame_stride
        # unique-object buffer, awaiting a full CNN batch
        self._buf = _ChunkBuffer()
        # pixel-diff duplicates awaiting their root's batch
        self._dup_objs: List[np.ndarray] = []
        self._dup_frames: List[np.ndarray] = []
        self._dup_roots: List[np.ndarray] = []
        self._root_cid: Dict[int, int] = {}     # folded unique obj -> cid
        self._n_seen = 0
        self._obj_next = 0       # next default object id (shard-local
                                 # under rollover; == _n_seen otherwise)
        self._max_frame: Optional[int] = None
        self._finished = False
        # live-shard accounting (identity values when no catalog is set)
        self._shard_n_fed = 0                   # objects fed to live shard
        self._shard_obj_base = 0                # global pos of its 1st obj
        self._shard_frame_lo: Optional[int] = None
        self._shard_frame_hi: Optional[int] = None
        self._shard_window_end: Optional[int] = None
        # delta accounting between flushes
        self._delta_new: List[int] = []
        self._delta_touched: set = set()
        self._delta_evictions = 0
        self._delta_published = 0
        self._delta_sealed: List[int] = []
        self._delta_touched_sealed: List[Tuple[int, int]] = []
        if catalog is not None and len(catalog.shards):
            # resuming on a non-empty catalog: new shards continue the
            # global object-id line and the non-decreasing frame contract
            # from where the existing archive ends (every fed object is
            # sealed as a member, so obj_base + n_objects is the count of
            # all objects fed to the prior run)
            last = catalog.shards[-1]
            self._shard_obj_base = last.obj_base + last.n_objects
            self._max_frame = last.frame_hi

    # -- queryable state -------------------------------------------------------

    @property
    def index(self) -> Optional[TopKIndex]:
        """The live index (None until the class width is known)."""
        return self._index

    @property
    def n_ready_batches(self) -> int:
        return len(self._buf) // self.cfg.batch_size

    @property
    def n_pending_unique(self) -> int:
        return len(self._buf)

    @property
    def n_pending_dups(self) -> int:
        return int(sum(len(a) for a in self._dup_objs))

    @property
    def shard_obj_base(self) -> int:
        """Global arrival position of the live shard's first object (0
        when rollover is off) — maps shard-local object ids back to the
        concatenated stream."""
        return self._shard_obj_base

    @property
    def frame_stride(self) -> int:
        return self._frame_stride

    def set_frame_stride(self, stride: int):
        """Retarget the sampling stride (adaptive controller hook).

        Takes effect from the next ``feed``. Changing the stride mid-run
        trades the chunked==one-shot byte-identity for throughput — a
        one-shot run cannot replay a stride schedule — so the controller
        only drives it on live deployments, never in equivalence tests.
        """
        if stride < 1:
            raise ValueError(f"frame_stride must be >= 1: {stride}")
        self._frame_stride = int(stride)

    # -- feeding ---------------------------------------------------------------

    def feed(self, crops: np.ndarray, frames: np.ndarray,
             obj_ids: Optional[np.ndarray] = None):
        """Ingest one chunk. Frames must be non-decreasing across feeds
        (chunks may split a frame's objects; the open frame keeps
        accepting members). ``obj_ids`` defaults to arrival positions in
        the concatenated stream — shard-local under rollover, i.e. the
        shard's objects ranked by arrival, exactly the ids a one-shot
        ``ingest()`` of the shard's window assigns. A rejected chunk
        mutates nothing: validation runs before any stats or object-id
        state is touched.
        """
        if self._finished:
            raise RuntimeError("feed() after finish()")
        crops = np.asarray(crops)
        frames = np.asarray(frames, np.int64)
        n = len(crops)
        arr_pos = None
        if obj_ids is not None:
            obj_ids = np.asarray(obj_ids, np.int64)
        if n:
            order = np.argsort(frames, kind="stable")
            crops, frames = crops[order], frames[order]
            if obj_ids is not None:
                obj_ids = obj_ids[order]
            else:
                arr_pos = order          # chunk-arrival position per slot
            # the contract holds with or without pixel differencing: an
            # out-of-order chunk would silently move the CNN batch
            # partition away from the one-shot run's
            if self._max_frame is not None and frames[0] < self._max_frame:
                raise ValueError(
                    f"frames must be non-decreasing across feeds: got "
                    f"frame {int(frames[0])} after frame {self._max_frame}")
        self._n_seen += n
        if n == 0:
            self.stats.n_objects += n
            return
        self._max_frame = int(frames[-1])
        if self._frame_stride > 1:
            # absolute sampling grid: frame f is kept iff f % stride == 0,
            # a function of the stream alone — dropped objects behave as
            # if never detected (no ids, no stats beyond n_sampled_out)
            keep = frames % self._frame_stride == 0
            self.stats.n_sampled_out += n - int(keep.sum())
            crops, frames = crops[keep], frames[keep]
            if obj_ids is not None:
                obj_ids = obj_ids[keep]
            elif arr_pos is not None:
                arr_pos = arr_pos[keep]
            n = len(crops)
        self.stats.n_objects += n
        if n == 0:
            return
        start = 0
        while start < n:
            if self.catalog is not None \
                    and self._frame_boundary(int(frames[start])):
                self._seal_shard()
            end = self._shard_cut(frames, start, n)
            if obj_ids is None:
                # rank the segment's objects by chunk-arrival position:
                # ids follow arrival order even when the chunk was
                # internally unsorted, matching what a one-shot ingest of
                # the shard's window (objects in arrival order) assigns
                ranks = np.argsort(np.argsort(arr_pos[start:end],
                                              kind="stable"),
                                   kind="stable")
                seg_ids = self._obj_next + ranks.astype(np.int64)
            else:
                seg_ids = obj_ids[start:end]
            self._obj_next += end - start
            self._shard_n_fed += end - start
            if self._shard_frame_lo is None:
                self._shard_frame_lo = int(frames[start])
            self._shard_frame_hi = int(frames[end - 1])
            self._ingest_chunk(crops[start:end], frames[start:end], seg_ids)
            start = end
            if self.catalog is not None and self.shard_objects is not None \
                    and self._shard_n_fed >= self.shard_objects:
                self._seal_shard()

    def _frame_boundary(self, f: int) -> bool:
        """True when the next object falls past the live shard's absolute
        frame window (windows are ``[i*W, (i+1)*W)``, pinned by the
        shard's first frame — so the shard partition is a function of the
        stream alone, never of the chunking)."""
        return (self.shard_frames is not None
                and self._shard_window_end is not None
                and self._shard_n_fed > 0
                and f >= self._shard_window_end)

    def _shard_cut(self, frames: np.ndarray, start: int, n: int) -> int:
        """End of the maximal [start, end) run that stays inside the live
        shard's objects-per-shard and frame-window budgets."""
        end = n
        if self.catalog is None:
            return end
        if self.shard_objects is not None:
            end = min(end, start + self.shard_objects - self._shard_n_fed)
        if self.shard_frames is not None:
            if self._shard_window_end is None:
                W = self.shard_frames
                self._shard_window_end = (int(frames[start]) // W + 1) * W
            end = min(end, start + int(np.searchsorted(
                frames[start:n], self._shard_window_end, side="left")))
        return end

    def _ingest_chunk(self, crops: np.ndarray, frames: np.ndarray,
                      obj_ids: np.ndarray):
        """Pixel-diff + buffer one frame-sorted, single-shard segment,
        folding every completed CNN batch."""
        t0 = time.perf_counter()
        n = len(crops)
        if self.cfg.pixel_diff or self._gate is not None:
            i = 0
            while i < n:
                f = int(frames[i])
                j = i
                while j < n and frames[j] == f:
                    j += 1
                ids = obj_ids[i:j]
                if self.cfg.pixel_diff:
                    roots = self._tracker.resolve(f, crops[i:j], ids)
                    self.stats.n_pixel_dedup += int((roots != ids).sum())
                else:
                    roots = ids.copy()
                if self._gate is not None:
                    roots = self._gate_segment(f, crops[i:j], ids, roots)
                uniq = roots == ids
                self._buffer_unique(crops[i:j][uniq], ids[uniq],
                                    frames[i:j][uniq])
                if not uniq.all():
                    dup = ~uniq
                    self._dup_objs.append(ids[dup])
                    self._dup_frames.append(frames[i:j][dup])
                    self._dup_roots.append(roots[dup])
                i = j
        else:
            self._buffer_unique(crops, obj_ids, frames)
        self.stats.wall_s += time.perf_counter() - t0
        if self.cheap_apply is not None or self.pipeline is not None:
            self._drain_ready()

    def _gate_segment(self, f: int, crops: np.ndarray, ids: np.ndarray,
                      roots: np.ndarray) -> np.ndarray:
        """Run one frame-``f`` segment's tracker-unique crops through the
        redundancy gate; returns the (possibly rewritten) roots. Gate
        hits become duplicates rooted at a ring entry (a CNN-bound
        object), misses are admitted as future ring entries."""
        uniq = roots == ids
        flat = crops[uniq].reshape(int(uniq.sum()),
                                   int(np.prod(crops.shape[1:])))
        groots = self._gate.match(f, flat)
        hit = groots >= 0
        if hit.any():
            roots = roots.copy()
            roots[np.nonzero(uniq)[0][hit]] = groots[hit]
            self.stats.n_gate_skipped += int(hit.sum())
            if self.cfg.pixel_diff:
                # the tracker must see the rewritten roots, else a
                # next-frame tracker match chains to a never-folded id
                self._tracker.amend_last(roots)
        self._gate.admit(flat[~hit], ids[uniq][~hit])
        return roots

    def _buffer_unique(self, crops, obj_ids, frames):
        self._buf.append(crops, obj_ids, frames)

    def take_ready_batch(self):
        """Pop one full CNN batch of buffered uniques (runner API)."""
        b = self.cfg.batch_size
        return self._take(b)

    def take_tail(self):
        """Pop the remaining partial batch (runner finish); empty arrays
        when nothing is buffered."""
        return self._take(len(self._buf))

    def _take(self, k: int):
        return self._buf.take(k)

    def _drain_ready(self):
        if self.pipeline is not None:
            # the pipeline double-buffers internally: each submit
            # dispatches the megastep, then host-folds the previous batch
            while self.n_ready_batches:
                self.pipeline.submit(*self.take_ready_batch())
            return
        while self.n_ready_batches:
            crops, objs, frames = self.take_ready_batch()
            t0 = time.perf_counter()
            probs, feats = self.cheap_apply(crops)
            self.stats.wall_s += time.perf_counter() - t0
            self.fold_batch(crops, objs, frames, probs, feats)

    # -- the chunk-step --------------------------------------------------------

    def fold_batch(self, crops: np.ndarray, obj_ids: np.ndarray,
                   frames: np.ndarray, probs: np.ndarray,
                   feats: np.ndarray):
        """Fold one CNN batch of unique objects into clustering state and
        the index — the loop body of the old one-shot ``ingest()``, with
        ``slot_cid`` / eviction remaps carried across calls. An
        ``IngestPipeline`` computes clustering on-device instead and
        enters below at ``_fold_rows`` with precomputed slots.
        """
        t0 = time.perf_counter()
        probs = np.asarray(probs)
        feats = np.asarray(feats, np.float32)
        self.stats.n_cnn_invocations += len(obj_ids)
        self.stats.cheap_flops += len(obj_ids) * self.cheap_flops_per_image

        if self._state is None:
            self._state = C.init_state(self.cfg.max_clusters, feats.shape[1])
        state, slots = self._cluster_fn(self._state, feats,
                                        self.cfg.threshold)
        self._state = state
        # focuslint: disable=host-sync -- staged path folds on host per
        # batch by design; the fused pipeline removes this sync
        slots_np = np.asarray(slots)
        self._fold_rows(crops, obj_ids, frames, probs, feats, slots_np)
        # eviction keeps the live table at M (paper: evict smallest)
        # focuslint: disable=host-sync -- staged path checks the live
        # count per fold; the fused pipeline's _n_hi bound replaces it
        if int(self._state.n) >= int(self.cfg.high_water
                                     * self.cfg.max_clusters):
            self._evict_live()
        self.stats.wall_s += time.perf_counter() - t0

    def _fold_rows(self, crops: np.ndarray, obj_ids: np.ndarray,
                   frames: np.ndarray, probs: np.ndarray,
                   feats: np.ndarray, slots: np.ndarray):
        """Host bookkeeping for one clustered batch: slot -> cid mapping,
        SoA index fold, delta accounting. Shared by the staged path
        (``fold_batch``) and the fused pipeline."""
        if self.n_local_classes is None:
            self.n_local_classes = probs.shape[1]
        if self._index is None:
            self._index = TopKIndex(self.cfg.K, self.n_local_classes,
                                    self.class_map)
        # slot -> cid, assigning fresh cids in first-appearance order
        unmapped = self._slot_cid[slots] < 0
        if unmapped.any():
            new_slots, first_pos = np.unique(slots[unmapped],
                                             return_index=True)
            order = np.argsort(first_pos, kind="stable")
            fresh = self._next_cid + np.arange(len(new_slots))
            self._slot_cid[new_slots[order]] = fresh
            self._next_cid += len(new_slots)
            self._delta_new.extend(fresh.tolist())
        cids = self._slot_cid[slots]
        self._root_cid.update(zip(obj_ids.tolist(), cids.tolist()))

        touched = self._index.add_batch(cids, feats, probs, obj_ids, frames,
                                        crops=crops)
        self._delta_touched.update(
            self._index.store.row_cids[touched].tolist())
        self._delta_published += len(obj_ids)

    def _evict_live(self):
        """Evict the smallest clusters from the live table and remap
        ``slot_cid``. Host-side by design: eviction compacts the table
        with an argsort and rewrites the slot -> cid map, both entangled
        with index bookkeeping the device never sees."""
        state, evicted, remap = C.evict_smallest(self._state,
                                                 self.cfg.evict_frac)
        self.stats.n_evictions += len(evicted)
        self._delta_evictions += len(evicted)
        new_slot_cid = np.full_like(self._slot_cid, -1)
        live = remap >= 0
        new_slot_cid[remap[live]] = self._slot_cid[live]
        self._slot_cid = new_slot_cid
        self._state = state

    # -- shard rollover --------------------------------------------------------

    def _empty_index(self) -> TopKIndex:
        nl = (self.n_local_classes if self.n_local_classes is not None
              else (self.class_map.n_local
                    if self.class_map is not None else 0))
        return TopKIndex(self.cfg.K, nl, self.class_map)

    def _seal_shard(self):
        """Seal the live index as one archive shard: drain the tail batch,
        attach the remaining duplicates, save through the catalog, and
        reset all per-shard state (clustering table, slot->cid map, pixel
        tracker, object ids). The next shard then ingests exactly like a
        fresh run, which is what makes every sealed shard byte-identical
        to a one-shot ``ingest()`` of its window."""
        self._drain_ready()
        if len(self._buf):
            crops, objs, frames = self.take_tail()
            self._fold_tail(crops, objs, frames)
        if self.pipeline is not None:
            self.pipeline.flush_pending()
        if self._index is None:
            self._index = self._empty_index()
        self._attach_eligible()
        self._dup_objs, self._dup_frames, self._dup_roots = [], [], []
        seal_kw = ({} if self.shard_format is None
                   else {"format": self.shard_format})
        meta = self.catalog.seal(
            self._index,
            frame_lo=(self._shard_frame_lo
                      if self._shard_frame_lo is not None else 0),
            frame_hi=(self._shard_frame_hi
                      if self._shard_frame_hi is not None else 0),
            obj_base=self._shard_obj_base, **seal_kw)
        # clusters touched since the last flush now live in the sealed
        # shard; report them shard-tagged so a query-side cache can warm
        # them under their final identity
        self._delta_sealed.append(meta.shard_id)
        self._delta_touched_sealed.extend(
            (meta.shard_id, c) for c in sorted(self._delta_touched))
        self._delta_touched = set()
        self._delta_new = []
        self._state = None
        self._slot_cid = np.full(self.cfg.max_clusters, -1, np.int64)
        self._next_cid = 0
        self._tracker = _PixelTracker(self.cfg.pixel_diff_threshold)
        self._gate = (_RedundancyGate(self.cfg.gate_threshold,
                                      self.cfg.gate_capacity)
                      if self.cfg.gate else None)
        self._root_cid = {}
        self._index = (self._empty_index()
                       if self.n_local_classes is not None
                       or self.class_map is not None else None)
        self._shard_obj_base += self._shard_n_fed
        self._shard_n_fed = 0
        self._obj_next = 0
        self._shard_frame_lo = None
        self._shard_frame_hi = None
        self._shard_window_end = None
        if self.pipeline is not None:
            self.pipeline.reset()
        return meta

    def _fold_tail(self, crops, objs, frames):
        """Fold a ragged tail batch through whichever CNN path drives this
        ingestor (fused pipeline or host-staged cheap_apply)."""
        if self.pipeline is not None:
            self.pipeline.submit(crops, objs, frames)
            return
        t0 = time.perf_counter()
        probs, feats = self.cheap_apply(crops)
        self.stats.wall_s += time.perf_counter() - t0
        self.fold_batch(crops, objs, frames, probs, feats)

    # -- publication -----------------------------------------------------------

    def _attach_eligible(self):
        """Attach pending duplicates whose root's batch has folded."""
        if not self._dup_objs:
            return
        objs = np.concatenate(self._dup_objs)
        frames = np.concatenate(self._dup_frames)
        roots = np.concatenate(self._dup_roots)
        cids = np.array([self._root_cid.get(r, -1) for r in roots.tolist()],
                        np.int64)
        ready = cids >= 0
        if ready.any():
            self._index.attach(cids[ready], objs[ready], frames[ready])
            self._delta_published += int(ready.sum())
        hold = ~ready
        if hold.any():
            self._dup_objs = [objs[hold]]
            self._dup_frames = [frames[hold]]
            self._dup_roots = [roots[hold]]
        else:
            self._dup_objs, self._dup_frames, self._dup_roots = [], [], []

    def _prune_root_cids(self):
        """Drop root -> cid entries no future duplicate can reference: new
        dups only ever point at roots in the tracker's open/previous frame
        groups, and held dups carry their root explicitly. Keeps the map
        O(active window) over a continuously ingested stream instead of
        O(total unique objects)."""
        keep = set()
        for seg in self._tracker._open_roots:
            keep.update(seg.tolist())
        if self._tracker._prev_roots is not None:
            keep.update(self._tracker._prev_roots.tolist())
        for seg in self._dup_roots:
            keep.update(seg.tolist())
        if self._gate is not None:
            # gate roots can be far older than the tracker window; any
            # ring entry may still be matched (and need its cid) later
            keep |= self._gate.live_roots()
        self._root_cid = {r: c for r, c in self._root_cid.items()
                          if r in keep}

    def flush(self) -> IngestDelta:
        """Publish what has been ingested so far: attach eligible
        duplicates and report the clusters a query-side cache needs to
        refresh. Does NOT fold the partial unique batch — the batch
        partition must stay a function of the stream alone (that is what
        makes chunked and one-shot ingests identical)."""
        if self.pipeline is not None:
            self.pipeline.flush_pending()     # publication barrier
        t0 = time.perf_counter()
        self._attach_eligible()
        self._prune_root_cids()
        delta = IngestDelta(
            n_objects_published=self._delta_published,
            new_cids=list(self._delta_new),
            touched_cids=sorted(self._delta_touched),
            n_evictions=self._delta_evictions,
            n_pending_unique=self.n_pending_unique,
            n_pending_dups=self.n_pending_dups,
            sealed_shards=list(self._delta_sealed),
            touched_sealed=list(self._delta_touched_sealed))
        self._delta_new = []
        self._delta_touched = set()
        self._delta_evictions = 0
        self._delta_published = 0
        self._delta_sealed = []
        self._delta_touched_sealed = []
        self.stats.wall_s += time.perf_counter() - t0
        return delta

    def finish(self) -> Tuple[TopKIndex, IngestStats]:
        """Drain the final partial batch, attach the remaining duplicates,
        and return ``(index, stats)`` — after this the ingestor is closed.
        Under rollover the tail is sealed as the final shard and the
        returned index is the (empty) successor; the archive lives in the
        catalog."""
        if self._finished:
            return self._index, self.stats
        if self.catalog is not None:
            if self._shard_n_fed:
                self._seal_shard()
            if self._index is None:
                self._index = self._empty_index()
            self._finished = True
            return self._index, self.stats
        if self.cheap_apply is not None or self.pipeline is not None:
            self._drain_ready()
        if len(self._buf):
            if self.cheap_apply is None and self.pipeline is None:
                raise RuntimeError(
                    "pending unique objects but no cheap_apply; a "
                    "runner-driven ingestor must be finished through "
                    "MultiStreamRunner.finish()")
            crops, objs, frames = self.take_tail()
            self._fold_tail(crops, objs, frames)
        if self.pipeline is not None:
            self.pipeline.flush_pending()
        if self._index is None:          # empty stream: class width from the
            self._index = self._empty_index()   # class map, never dropped
        self._attach_eligible()
        # anything still pending has an unknown root (defensive, mirrors the
        # old one-shot valid-root filter): drop it
        self._dup_objs, self._dup_frames, self._dup_roots = [], [], []
        self._finished = True
        return self._index, self.stats


class StreamPlacement:
    """Deterministic stream -> device placement for sharded ingest
    (DESIGN.md §13).

    Pure function of ``(names, n_devices)`` — round-robin in the given
    name order: stream ``i`` lives on device ``i % n_devices``. The
    device-major ``slots`` list (each device's block padded with ``None``
    to a common width) is exactly the slot layout a
    ``ShardedIngestPipeline`` stacks along its leading stream axis, so
    the placement — and with it every stream's device and stacked row —
    is reproducible across runs and independent of feed() chunking.
    """

    def __init__(self, names, n_devices: int):
        names = list(names)
        if not names:
            raise ValueError("need at least one stream name")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stream names in {names!r}")
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        self.names = names
        self.n_devices = n_devices
        self.width = -(-len(names) // n_devices)        # ceil
        blocks: List[List[Optional[str]]] = [[] for _ in range(n_devices)]
        for i, nm in enumerate(names):
            blocks[i % n_devices].append(nm)
        for b in blocks:
            b.extend([None] * (self.width - len(b)))
        self.slots: List[Optional[str]] = [nm for b in blocks for nm in b]
        self._slot_of = {nm: s for s, nm in enumerate(self.slots)
                         if nm is not None}

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def slot_of(self, name: str) -> int:
        return self._slot_of[name]

    def device_of(self, name: str) -> int:
        return self._slot_of[name] // self.width

    def assignment(self) -> Dict[str, int]:
        """{stream name: device index} — the reproducibility contract."""
        return {nm: self.device_of(nm) for nm in self.names}


class MultiStreamRunner:
    """Round-robins N per-stream ingestors through ONE shared cheap CNN.

    Two modes:

    * **Staged** (``cheap_apply`` given): ready batches (exactly
      ``cfg.batch_size`` unique crops each) from all streams are stacked
      into one device batch, bucket-padded to reuse the same compiled
      executable, classified in a single ``cheap_apply`` call, and split
      back per stream. When a mesh is given, the stacked batch is placed
      with ``distributed.sharding.batch_spec`` (sharding hoisted to
      construction — never rebuilt per step).
    * **Sharded** (``pipeline`` = a ``ShardedIngestPipeline``): each
      ingestor was constructed with ``pipeline=shared.handle(name)``;
      feeds enqueue per-stream batches and every ``step()`` runs ONE
      sharded megastep over the head batch of each stream (see
      ``make_sharded_runner``). The runner disables the pipeline's
      auto-pump so batches stack *across* streams.

    Either way, per-stream fold order is preserved, so each stream's
    index is byte-identical to a self-driven single-device run
    (``cheap_apply`` must be per-example pure, which holds for the
    inference CNNs here).
    """

    def __init__(self, ingestors: Mapping[str, StreamingIngestor],
                 cheap_apply: Optional[Callable] = None,
                 batch_pad: int = 64, mesh=None, pipeline=None,
                 placement: Optional[StreamPlacement] = None):
        if not ingestors:
            raise ValueError("need at least one ingestor")
        if (cheap_apply is None) == (pipeline is None):
            raise ValueError(
                "pass exactly one of cheap_apply (staged stacking) or "
                "pipeline (ShardedIngestPipeline)")
        if pipeline is not None:
            for name, ing in ingestors.items():
                h = ing.pipeline
                if h is None or getattr(h, "shared", None) is not pipeline:
                    raise ValueError(
                        f"ingestor {name!r} is not bound to this sharded "
                        f"pipeline; construct it with "
                        f"pipeline=shared.handle({name!r})")
            pipeline.auto_pump = False   # runner owns step timing
        else:
            for name, ing in ingestors.items():
                if ing.cheap_apply is not None or ing.pipeline is not None:
                    raise ValueError(
                        f"ingestor {name!r} owns a cheap_apply/pipeline; "
                        f"runner-driven ingestors must be constructed "
                        f"with neither")
        self.ingestors: Dict[str, StreamingIngestor] = dict(ingestors)
        self.cheap_apply = cheap_apply
        self.batch_pad = batch_pad
        self.mesh = mesh
        self.pipeline = pipeline
        self.placement = placement
        self._rotation = list(self.ingestors)
        # hoisted: the stacked-batch sharding is a pure function of the
        # mesh; rebuilding it (and re-importing jax) every step was the
        # old per-step hot-path bug (ISSUE 9 satellite)
        self._stack_sharding = None
        if mesh is not None and cheap_apply is not None:
            import jax
            from jax.sharding import NamedSharding

            from repro.distributed.sharding import batch_spec
            self._stack_sharding = NamedSharding(mesh, batch_spec(mesh, 3))

    def feed(self, feeds: Mapping[str, Tuple[np.ndarray, np.ndarray]]):
        """Feed per-stream chunks, then fold every ready batch."""
        for name, (crops, frames) in feeds.items():
            self.ingestors[name].feed(crops, frames)
        self.drain()

    def step(self) -> int:
        """One stacked device batch: up to one ready batch per stream.
        Staged mode rotates which stream leads the stack; sharded mode
        folds the head batch of every queued stream in one sharded
        dispatch. Returns objects folded (0 = nothing ready)."""
        if self.pipeline is not None:
            for ing in self.ingestors.values():
                ing._drain_ready()       # enqueue ready batches
            return self.pipeline.pump_one()
        parts = []
        for name in self._rotation:
            ing = self.ingestors[name]
            if ing.n_ready_batches:
                parts.append((ing, *ing.take_ready_batch()))
        self._rotation = self._rotation[1:] + self._rotation[:1]
        if not parts:
            return 0
        self._fold_stacked(parts)
        return int(sum(len(p[2]) for p in parts))

    def drain(self):
        while self.step():
            pass

    def _fold_stacked(self, parts):
        from repro.core.query import pad_to_bucket
        t0 = time.perf_counter()
        stacked = np.concatenate([p[1] for p in parts])
        n = len(stacked)
        padded = pad_to_bucket(stacked, self.batch_pad)
        if self._stack_sharding is not None:
            try:
                import jax
                padded = jax.device_put(padded, self._stack_sharding)
            except (ValueError, RuntimeError):
                pass                     # indivisible batch / CPU fallback
        probs, feats = self.cheap_apply(padded)
        probs = np.asarray(probs)[:n]
        feats = np.asarray(feats)[:n]
        cnn_s = time.perf_counter() - t0     # shared pass, attributed below
        off = 0
        for ing, crops, objs, frames in parts:
            k = len(objs)
            ing.stats.wall_s += cnn_s * (k / n)
            ing.fold_batch(crops, objs, frames, probs[off:off + k],
                           feats[off:off + k])
            off += k

    def flush(self) -> Dict[str, IngestDelta]:
        self.drain()
        return {name: ing.flush() for name, ing in self.ingestors.items()}

    def finish(self) -> Dict[str, Tuple[TopKIndex, IngestStats]]:
        """Fold the ragged per-stream tails in one final stacked pass,
        then finalize every ingestor."""
        self.drain()
        if self.pipeline is not None:
            # each finish() submits its own tail + flushes the shared
            # pipeline; catalog'd streams seal themselves
            return {name: ing.finish()
                    for name, ing in self.ingestors.items()}
        parts = [(ing, *ing.take_tail())
                 for ing in self.ingestors.values()
                 if ing.n_pending_unique]
        if parts:
            self._fold_stacked(parts)
        return {name: ing.finish() for name, ing in self.ingestors.items()}


def make_sharded_runner(cheap_fn: Callable, mesh, stream_names,
                        cfg: Optional[IngestConfig] = None,
                        topk_k: Optional[int] = None,
                        topk_sink: Optional[Callable] = None,
                        ingestor_kwargs: Optional[Mapping[str, dict]] = None,
                        **common_kwargs) -> MultiStreamRunner:
    """Build the full sharded multi-stream stack: a ``StreamPlacement``
    over ``mesh.size`` devices, one shared ``ShardedIngestPipeline``, one
    ``StreamingIngestor`` per stream bound to its slot handle, and a
    ``MultiStreamRunner`` driving it.

    ``ingestor_kwargs`` maps stream name -> extra ``StreamingIngestor``
    kwargs (e.g. a per-stream ``catalog``); ``common_kwargs`` go to every
    ingestor. Per-stream cfg overrides are rejected by the pipeline —
    the stacked cluster tables share one shape/threshold.
    """
    from repro.core.pipeline import ShardedIngestPipeline
    placement = StreamPlacement(stream_names, mesh.size)
    shared = ShardedIngestPipeline(cheap_fn, mesh, placement.slots,
                                   cfg=cfg, topk_k=topk_k,
                                   topk_sink=topk_sink)
    ingestors = {}
    for nm in placement.names:
        kw = dict(common_kwargs)
        kw.update((ingestor_kwargs or {}).get(nm, {}))
        kw.setdefault("cfg", cfg)
        ingestors[nm] = StreamingIngestor(pipeline=shared.handle(nm), **kw)
    return MultiStreamRunner(ingestors, mesh=mesh, pipeline=shared,
                             placement=placement)
