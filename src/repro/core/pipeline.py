"""Device-resident fused ingest megastep (DESIGN.md §9).

The staged ingest hot path runs cheap-CNN forward, top-K, and clustering
as separate host-driven stages with numpy round-trips between them.
``IngestPipeline`` fuses the whole per-batch fast path into ONE jitted
dispatch::

    crops ──► cheap-CNN forward ──► probs ──► Pallas topk ──► (vals, idxs)
                     │
                     └► feats ──► fused-threshold centroid_assign (phase 1)
                                         │
                                         └► matched-fold segment-sum
                                            (ClusterState update, donated)

Only the small per-batch outputs come back to the host: the assignment
vector ``j``/``matched`` (for slot → cid bookkeeping and the unmatched
tail), the top-K values/indices, and — lazily — ``probs``/``feats`` rows
for the SoA index fold. The sequential tail over *unmatched* rows (new
clusters within a batch) is the only other device dispatch, so the fused
path issues at most 2 dispatches per batch (gated in CI).

Double buffering: ``submit`` dispatches batch N+1's megastep *before*
host-folding batch N's rows into the ``TopKIndex`` — JAX async dispatch
lets the accelerator chew on N+1 while the host does numpy bookkeeping
for N. The clustering state stays device-resident across batches; the
host only syncs on ``state.n`` when an upper bound (live clusters +
cumulative unmatched rows) says eviction *might* be due, which keeps the
common batch entirely sync-free between the tiny ``j``/``matched``
fetches.

Numerics contract (pinned by ``tests/test_pipeline.py``): a pipeline-
driven ``StreamingIngestor`` saves a byte-identical index (and identical
``IngestStats`` counters) to the host-staged path over the same stream,
chunking, eviction, and shard-rollover boundaries. The megastep inlines
the *same* jitted sub-computations the staged path runs (``forward``,
``_phase1``, ``_fold_matched``, ``_scan_unmatched``), so per-row values
agree bit-for-bit.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering as C
from repro.kernels import ops as kops


def batch_bucket(n: int, batch_size: int) -> int:
    """Compile-cache bucket for a batch of ``n`` crops.

    Full driver batches (``n >= batch_size`` — ``StreamingIngestor``
    ready batches are exactly ``batch_size``) map to themselves; ragged
    tail batches round up to the next power of two (min 8, capped at
    ``batch_size``), so every tail size in a bucket reuses one compiled
    executable instead of retracing per size.
    """
    if n >= batch_size:
        return n
    return min(C._pad_bucket(n), batch_size)


def _pad_rows(arr: np.ndarray, bucket: int) -> np.ndarray:
    n = len(arr)
    if n == bucket:
        return arr
    return np.concatenate(
        [arr, np.zeros((bucket - n,) + arr.shape[1:], arr.dtype)])


def _donate_argnums() -> tuple:
    """Donate the ClusterState buffers (centroids, counts, n) so the fold
    updates them in place. CPU XLA cannot alias donated buffers (it would
    only warn), so donation is enabled off-CPU only."""
    return () if jax.default_backend() == "cpu" else (0, 1, 2)


# ---------------------------------------------------------------------------
# jitted steps (module-cached so every pipeline over the same cheap_fn
# shares compiled executables)
# ---------------------------------------------------------------------------

# bounded LRU: shares compiled executables between pipelines over the
# same cheap_fn without pinning every model's params (each key holds the
# cheap_fn closure, i.e. its full parameter tree) for process lifetime
_MEGASTEP_JITS: "OrderedDict[Tuple, Callable]" = OrderedDict()
_MEGASTEP_JITS_MAX = 16
_SCAN_TAIL_JIT: Optional[Callable] = None


def _megastep_jit(cheap_fn: Callable, k_top: int,
                  with_topk: bool) -> Callable:
    """The fused megastep for one traceable ``cheap_fn``: forward →
    [topk →] phase-1 assign → matched fold, one XLA computation.
    ``n_real`` masks bucket-padding rows out of the fold (their phase-1
    outputs are sliced away host-side), so padded tail batches fold
    exactly like unpadded ones. The top-K branch is compiled in only when
    a sink consumes it — without one the (bucket, K) outputs would be
    computed and materialized per batch for nobody (jit outputs cannot be
    dead-code-eliminated)."""
    key = (cheap_fn, k_top, with_topk)
    fn = _MEGASTEP_JITS.get(key)
    if fn is not None:
        _MEGASTEP_JITS.move_to_end(key)
        return fn

    def megastep(centroids, counts, n, threshold, n_real, crops):
        probs, feats = cheap_fn(crops)
        probs = probs.astype(jnp.float32)
        feats = feats.astype(jnp.float32)
        if with_topk:
            vals, idxs = kops.topk(probs, min(k_top, probs.shape[1]))
        else:
            vals = idxs = None
        j, matched = C._phase1(centroids, counts, n, feats, threshold)
        valid = jnp.arange(feats.shape[0], dtype=jnp.int32) < n_real
        state = C._fold_matched(C.ClusterState(centroids, counts, n), feats,
                                j, jnp.logical_and(matched, valid))
        return (state.centroids, state.counts, state.n,
                probs, feats, j, matched, vals, idxs)

    fn = jax.jit(megastep, donate_argnums=_donate_argnums())
    _MEGASTEP_JITS[key] = fn
    if len(_MEGASTEP_JITS) > _MEGASTEP_JITS_MAX:
        _MEGASTEP_JITS.popitem(last=False)
    return fn


def _scan_tail_jit() -> Callable:
    """Sequential rule over the gathered unmatched subsequence — the
    second (and last) device dispatch of a batch. The gather is fused in
    so the padded feats never round-trip through the host."""
    global _SCAN_TAIL_JIT
    if _SCAN_TAIL_JIT is not None:
        return _SCAN_TAIL_JIT

    def scan_tail(centroids, counts, n, feats, gather, valid, threshold):
        state = C.ClusterState(centroids, counts, n)
        state, sub_ids = C._scan_unmatched(state, feats[gather], valid,
                                           threshold)
        return state.centroids, state.counts, state.n, sub_ids

    _SCAN_TAIL_JIT = jax.jit(scan_tail, donate_argnums=_donate_argnums())
    return _SCAN_TAIL_JIT


def staged_cheap_apply(cheap_fn: Callable, cfg) -> Callable:
    """Host-staged reference wrapper over a traceable ``cheap_fn``: jitted
    forward with the SAME ``batch_bucket`` padding the pipeline uses,
    returning numpy ``(probs, feats)``. This is the baseline the fused
    megastep is benchmarked — and byte-compared — against."""
    fwd = jax.jit(cheap_fn)

    # focuslint: disable=host-sync -- staged boundary by contract: apply
    # returns host arrays; the fused pipeline is the async path
    def apply(crops: np.ndarray):
        n = len(crops)
        if n == 0:
            p_s, f_s = jax.eval_shape(
                cheap_fn, jax.ShapeDtypeStruct((8,) + crops.shape[1:],
                                               jnp.float32))
            return (np.zeros((0, p_s.shape[1]), np.float32),
                    np.zeros((0, f_s.shape[1]), np.float32))
        padded = _pad_rows(np.asarray(crops), batch_bucket(n, cfg.batch_size))
        probs, feats = fwd(jnp.asarray(padded))
        return (np.asarray(probs, np.float32)[:n],
                np.asarray(feats, np.float32)[:n])

    return apply


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

@dataclass
class PipelineStats:
    n_batches: int = 0            # per-stream batches folded
    n_objects: int = 0            # real rows folded (pad rows excluded)
    n_dispatches: int = 0         # device computations launched
    n_steps: int = 0              # stacked sharded steps (== n_batches on
                                  # the single-stream IngestPipeline)
    n_tail_scans: int = 0         # batches that needed the unmatched tail
    n_eviction_syncs: int = 0     # host syncs on state.n (bound crossed)
    compile_hits: int = 0         # megastep (bucket, res) key already seen
    compile_misses: int = 0       # fresh megastep (bucket, res) key
    tail_compile_hits: int = 0    # tail-scan pad bucket P already seen
    tail_compile_misses: int = 0  # fresh tail-scan pad bucket P

    @property
    def dispatches_per_batch(self) -> float:
        return self.n_dispatches / max(self.n_batches, 1)


@dataclass
class _InFlight:
    """One dispatched-but-not-yet-host-folded batch."""
    crops: np.ndarray             # real rows only
    objs: np.ndarray
    frames: np.ndarray
    n: int
    probs: jax.Array              # (bucket, C) device
    feats: jax.Array              # (bucket, D) device
    vals: jax.Array               # (bucket, k) device top-K values
    idxs: jax.Array               # (bucket, k) device top-K indices
    j: np.ndarray = field(default=None)         # (n,) host, after resolve
    matched: np.ndarray = field(default=None)   # (n,) host bool
    unmatched_idx: np.ndarray = field(default=None)
    sub_ids: Optional[jax.Array] = None         # scan-tail ids (device)


class IngestPipeline:
    """Owns the fused megastep + double buffering for ONE ingestor.

    ``cheap_fn(crops (B, R, R, 3)) -> (probs (B, C), feats (B, D))`` must
    be jax-traceable and per-example pure (every inference CNN here is).
    Construct, then pass as ``StreamingIngestor(..., pipeline=...)`` — the
    ingestor binds itself and routes ``_drain_ready`` / tail folds through
    ``submit``/``flush_pending``. ``topk_sink(objs, vals, idxs)``, when
    given, receives each folded batch's per-object top-K classes (the
    megastep emits them for free; without a sink they are never fetched).
    The K defaults to ``cfg.K`` clamped to the model's class width —
    ``TopKIndex``'s ``min(K, C)`` semantics — while an *explicit*
    ``topk_k`` wider than the class width raises, matching
    ``kernels/ops.topk``.
    """

    def __init__(self, cheap_fn: Callable, cfg=None,
                 topk_k: Optional[int] = None,
                 topk_sink: Optional[Callable] = None):
        self.cheap_fn = cheap_fn
        self.cfg = cfg
        if cfg is not None:
            self._check_clustering(cfg)
        self.topk_k = topk_k
        self.topk_sink = topk_sink
        self.stats = PipelineStats()
        self._ing = None
        self._pending: Optional[_InFlight] = None
        self._seen_keys = set()
        self._megastep_fn: Optional[Callable] = None   # set at dispatch
        self._n_hi = 0                # upper bound on live clusters

    # -- wiring ----------------------------------------------------------------

    @staticmethod
    def _check_clustering(cfg):
        """The megastep hard-codes the fused clustering semantics
        (phase-1 assign + matched fold + unmatched tail); running it under
        a config that names another variant would silently break the
        byte-identity contract with the staged path."""
        if cfg.clustering != "fused":
            raise ValueError(
                f"IngestPipeline implements clustering='fused' only; got "
                f"cfg.clustering={cfg.clustering!r} — use the host-staged "
                f"cheap_apply path for other variants")

    def _bind(self, ingestor):
        if self._ing is not None and self._ing is not ingestor:
            raise ValueError("IngestPipeline is already bound to an "
                             "ingestor; build one pipeline per stream")
        self._check_clustering(ingestor.cfg)
        if self.cfg is not None and self.cfg != ingestor.cfg:
            raise ValueError(
                "IngestPipeline cfg differs from the ingestor's cfg; the "
                "megastep clusters/evicts with its own threshold and "
                "table size, so a mismatch would silently diverge from "
                "the staged path — construct with cfg=None to inherit, "
                "or pass the same IngestConfig to both")
        self._ing = ingestor
        if self.cfg is None:
            self.cfg = ingestor.cfg

    def reset(self):
        """Shard rollover: clustering state was reset by the ingestor."""
        if self._pending is not None:
            raise RuntimeError("reset() with a pending batch; drain first")
        self._n_hi = 0

    # -- driver API ------------------------------------------------------------

    def submit(self, crops: np.ndarray, objs: np.ndarray,
               frames: np.ndarray):
        """Dispatch one batch's megastep, host-fold the previous batch
        while the device runs, then resolve this batch's assignments
        (tail scan + eviction bookkeeping). Batches must be submitted in
        stream order — ``StreamingIngestor`` guarantees this."""
        n = len(objs)
        if n == 0:
            return
        ing = self._ing
        if ing is None:
            raise RuntimeError("pipeline is not bound to an ingestor; "
                               "pass it to StreamingIngestor(pipeline=...)")
        t0 = time.perf_counter()
        if ing._state is None:
            self._init_state(crops)
        rec = self._dispatch(crops, objs, frames)
        # double buffer: fold batch N-1 on the host while the device runs N
        prev, self._pending = self._pending, None
        ing.stats.wall_s += time.perf_counter() - t0
        if prev is not None:
            self._fold(prev)
        self._resolve(rec)

    def flush_pending(self):
        """Host-fold the outstanding batch (publication barrier: flush /
        finish / seal call this before the index is observed)."""
        if self._pending is not None:
            rec, self._pending = self._pending, None
            self._fold(rec)

    def jit_cache_entries(self) -> dict:
        """REAL trace-cache entry counts of the shared megastep / tail
        jits (``-1`` if this jax version lacks introspection). This is
        what the CI retrace gate checks: the per-pipeline
        ``compile_hits/misses`` counters track (bucket, res) key novelty
        only and cannot see an XLA retrace caused by dtype or weak-type
        drift."""
        def size(fn):
            if fn is None:
                return 0
            try:
                return int(fn._cache_size())
            except Exception:
                return -1
        # the exact jit this pipeline dispatched — no key reconstruction
        # that could drift from _dispatch and leave the gate measuring 0
        return {"megastep": size(self._megastep_fn),
                "tail": size(_SCAN_TAIL_JIT)}

    # -- internals -------------------------------------------------------------

    def _init_state(self, crops: np.ndarray):
        probs_s, feats_s = jax.eval_shape(
            self.cheap_fn,
            jax.ShapeDtypeStruct((8,) + crops.shape[1:], jnp.float32))
        if self.topk_k is not None and self.topk_k > probs_s.shape[1]:
            # an explicit topk_k beyond the class width is a config error
            # (same contract as kernels/ops.topk); the cfg.K default is
            # clamped instead, mirroring TopKIndex's min(K, C) semantics
            raise ValueError(
                f"topk_k={self.topk_k} exceeds the model's "
                f"{probs_s.shape[1]} classes")
        self._ing._state = C.init_state(self.cfg.max_clusters,
                                        feats_s.shape[1])
        self._n_hi = 0

    def _dispatch(self, crops, objs, frames) -> _InFlight:
        n = len(objs)
        bucket = batch_bucket(n, self.cfg.batch_size)
        key = (bucket, crops.shape[1])
        if key in self._seen_keys:
            self.stats.compile_hits += 1
        else:
            self._seen_keys.add(key)
            self.stats.compile_misses += 1
        k_top = self.topk_k if self.topk_k is not None else self.cfg.K
        fn = self._megastep_fn = _megastep_jit(self.cheap_fn, k_top,
                                               self.topk_sink is not None)
        st = self._ing._state
        out = fn(st.centroids, st.counts, st.n,
                 jnp.asarray(self.cfg.threshold, jnp.float32),
                 np.int32(n), jnp.asarray(_pad_rows(np.asarray(crops),
                                                    bucket)))
        cen, cnt, nn, probs, feats, j, matched, vals, idxs = out
        self._ing._state = C.ClusterState(cen, cnt, nn)
        self.stats.n_dispatches += 1
        self.stats.n_batches += 1
        self.stats.n_steps += 1
        return _InFlight(crops=crops, objs=objs, frames=frames, n=n,
                         probs=probs, feats=feats, vals=vals, idxs=idxs,
                         j=j, matched=matched)

    def _resolve(self, rec: _InFlight):
        """Sync the tiny assignment outputs, run the unmatched tail, and
        decide eviction — everything batch N+1's megastep depends on.
        Times itself into ``stats.wall_s``, pausing around ``_fold`` (it
        keeps its own clock) so eviction batches are not double-counted."""
        ing = self._ing
        t0 = time.perf_counter()
        # focuslint: disable=host-sync -- single tiny (j, matched) fetch
        # per resolved batch; the double-buffered dispatch has already
        # overlapped this batch's compute
        j, matched = jax.device_get((rec.j, rec.matched))
        rec.j = np.asarray(j)[:rec.n]
        rec.matched = np.asarray(matched)[:rec.n]
        rec.unmatched_idx = np.nonzero(~rec.matched)[0]
        U = len(rec.unmatched_idx)
        if U:
            # identical tail construction to cluster_fused: gather indices
            # padded to a power-of-two bucket, invalid rows are no-ops.
            # Tail executables are keyed by (P, feats bucket) — a bounded
            # set (P is a power of two <= bucket), tracked so a retrace
            # regression in the tail path also trips the CI compile gate
            P = C._pad_bucket(U)
            tail_key = ("tail", P, rec.feats.shape[0])
            if tail_key in self._seen_keys:
                self.stats.tail_compile_hits += 1
            else:
                self._seen_keys.add(tail_key)
                self.stats.tail_compile_misses += 1
            gather = np.zeros((P,), np.int64)
            gather[:U] = rec.unmatched_idx
            st = ing._state
            cen, cnt, nn, sub_ids = _scan_tail_jit()(
                st.centroids, st.counts, st.n, rec.feats,
                jnp.asarray(gather), jnp.asarray(np.arange(P) < U),
                jnp.asarray(self.cfg.threshold, jnp.float32))
            ing._state = C.ClusterState(cen, cnt, nn)
            rec.sub_ids = sub_ids
            self.stats.n_dispatches += 1
            self.stats.n_tail_scans += 1
            self._n_hi += U
        # eviction uses the same trigger as the staged path (state.n at
        # high water), but only syncs when the bound says it could fire:
        # n_hi >= actual n always, so no staged eviction point is missed
        hw = int(self.cfg.high_water * self.cfg.max_clusters)
        if self._n_hi >= hw:
            self.stats.n_eviction_syncs += 1
            # focuslint: disable=host-sync -- bound-gated: fires only
            # when _n_hi crosses the ceiling, not per batch (counted in
            # stats.n_eviction_syncs)
            n_live = int(jax.device_get(ing._state.n))
            self._n_hi = n_live
            if n_live >= hw:
                # the remap must not run before this batch's slots are
                # translated: fold now (no overlap for this rare batch)
                ing.stats.wall_s += time.perf_counter() - t0
                self._fold(rec)
                t0 = time.perf_counter()
                ing._evict_live()
                # focuslint: disable=host-sync -- rare eviction path;
                # the remap must land before the next dispatch
                self._n_hi = int(jax.device_get(ing._state.n))
                ing.stats.wall_s += time.perf_counter() - t0
                return
        self._pending = rec
        ing.stats.wall_s += time.perf_counter() - t0

    def _fold(self, rec: _InFlight):
        """Host side of the fold: scatter tail ids, slot → cid, SoA index
        update — mirrors the staged ``fold_batch`` exactly."""
        ing = self._ing
        t0 = time.perf_counter()
        slots = rec.j.astype(np.int32)
        if len(rec.unmatched_idx):
            slots[rec.unmatched_idx] = \
                np.asarray(rec.sub_ids)[:len(rec.unmatched_idx)]
        probs = np.asarray(rec.probs, np.float32)[:rec.n]
        feats = np.asarray(rec.feats, np.float32)[:rec.n]
        ing.stats.n_cnn_invocations += rec.n
        ing.stats.cheap_flops += rec.n * ing.cheap_flops_per_image
        ing._fold_rows(rec.crops, rec.objs, rec.frames, probs, feats, slots)
        self.stats.n_objects += rec.n
        if self.topk_sink is not None:
            self.topk_sink(rec.objs, np.asarray(rec.vals)[:rec.n],
                           np.asarray(rec.idxs)[:rec.n])
        ing.stats.wall_s += time.perf_counter() - t0


# ---------------------------------------------------------------------------
# sharded multi-stream pipeline (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# ``ShardedIngestPipeline`` stacks N streams' batches along a leading
# STREAM axis and runs the SAME megastep body per stream inside ONE
# ``shard_map`` dispatch over a 1-D ("data",) mesh: each device owns a
# contiguous block of stream slots (cluster tables resident on it for the
# whole run), so the hot path moves no cluster state between devices.
# Byte-identity with the per-stream single-device path holds by
# construction: the shard_map body calls the identical jitted
# sub-computations (``cheap_fn``, ``_phase1``, ``_fold_matched``,
# ``_scan_unmatched``) on per-stream arrays of the same shapes — no vmap,
# no reassociation — and idle slots (n_real == 0) are exact no-ops
# (``_fold_matched`` preserves untouched rows bitwise, ``_scan_unmatched``
# skips invalid rows bitwise).

# sharded tail executables are model-free; keyed per (mesh, width)
_SHARDED_TAIL_JITS: "OrderedDict[Tuple, Callable]" = OrderedDict()
_SHARDED_TAIL_JITS_MAX = 8


def _sharded_megastep_jit(cheap_fn: Callable, k_top: int, with_topk: bool,
                          mesh, width: int) -> Callable:
    """The stacked megastep: per device, an unrolled loop over its
    ``width`` stream slots, each running the exact single-device megastep
    body on that slot's (bucket, ...) slice. Cached in the same module
    LRU as the single-device megastep, keyed by (cheap_fn, k, topk, mesh,
    width); jit then specializes per (bucket, res) like the single-device
    path."""
    key = (cheap_fn, k_top, with_topk, mesh, width)
    fn = _MEGASTEP_JITS.get(key)
    if fn is not None:
        _MEGASTEP_JITS.move_to_end(key)
        return fn

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd

    def block(cen, cnt, nv, thr, n_real, crops):
        # per-device block: cen (W,M,D) cnt (W,M) nv (W,) n_real (W,)
        # crops (W,B,R,R,3); thr is replicated. Unrolled so every slot
        # runs the unbatched single-device computation bit-for-bit.
        outs = []
        for w in range(width):
            probs, feats = cheap_fn(crops[w])
            probs = probs.astype(jnp.float32)
            feats = feats.astype(jnp.float32)
            if with_topk:
                vals, idxs = kops.topk(probs, min(k_top, probs.shape[1]))
            j, matched = C._phase1(cen[w], cnt[w], nv[w], feats, thr)
            valid = jnp.arange(feats.shape[0], dtype=jnp.int32) < n_real[w]
            st = C._fold_matched(C.ClusterState(cen[w], cnt[w], nv[w]),
                                 feats, j, jnp.logical_and(matched, valid))
            row = [st.centroids, st.counts, st.n, probs, feats, j, matched]
            if with_topk:
                row += [vals, idxs]
            outs.append(row)
        return tuple(jnp.stack([o[i] for o in outs])
                     for i in range(len(outs[0])))

    s = lambda r: shd.stream_spec(mesh, r)          # noqa: E731
    in_specs = (s(2), s(1), s(0), P(), s(0), s(4))
    out_specs = (s(2), s(1), s(0), s(2), s(2), s(1), s(1))
    if with_topk:
        out_specs = out_specs + (s(2), s(2))
    # check_rep=False: Pallas calls have no replication rule
    fn = jax.jit(shard_map(block, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False),
                 donate_argnums=_donate_argnums())
    _MEGASTEP_JITS[key] = fn
    if len(_MEGASTEP_JITS) > _MEGASTEP_JITS_MAX:
        _MEGASTEP_JITS.popitem(last=False)
    return fn


def _sharded_tail_jit(mesh, width: int) -> Callable:
    """Stacked unmatched-tail scan: per slot, the identical
    ``_scan_unmatched`` over that slot's gathered rows; slots with no
    unmatched rows carry an all-False valid mask and are bitwise no-ops."""
    key = (mesh, width)
    fn = _SHARDED_TAIL_JITS.get(key)
    if fn is not None:
        _SHARDED_TAIL_JITS.move_to_end(key)
        return fn

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd

    def block(cen, cnt, nv, feats, gather, valid, thr):
        outs = []
        for w in range(width):
            st, sub = C._scan_unmatched(
                C.ClusterState(cen[w], cnt[w], nv[w]),
                feats[w][gather[w]], valid[w], thr)
            outs.append([st.centroids, st.counts, st.n, sub])
        return tuple(jnp.stack([o[i] for o in outs]) for i in range(4))

    s = lambda r: shd.stream_spec(mesh, r)          # noqa: E731
    fn = jax.jit(shard_map(block, mesh=mesh,
                           in_specs=(s(2), s(1), s(0), s(2), s(1), s(1),
                                     P()),
                           out_specs=(s(2), s(1), s(0), s(1)),
                           check_rep=False),
                 donate_argnums=_donate_argnums())
    _SHARDED_TAIL_JITS[key] = fn
    if len(_SHARDED_TAIL_JITS) > _SHARDED_TAIL_JITS_MAX:
        _SHARDED_TAIL_JITS.popitem(last=False)
    return fn


class _ShardSlot:
    """Per-stream handle onto a shared ``ShardedIngestPipeline``.

    Implements the ``StreamingIngestor`` pipeline protocol (``_bind`` /
    ``submit`` / ``flush_pending`` / ``reset``), so an ingestor constructed
    with ``pipeline=shared.handle(name)`` — including catalog'd ones that
    seal shards mid-run — works unchanged. ``submit`` enqueues the batch
    in stream order; the shared pipeline folds queued head batches from
    all streams in stacked steps."""

    def __init__(self, shared: "ShardedIngestPipeline", name: str,
                 slot: int):
        self.shared = shared
        self.name = name
        self.slot = slot
        self.queue: deque = deque()      # (crops, objs, frames), FIFO
        self._ing = None
        self._n_hi = 0                   # upper bound on live clusters

    @property
    def cfg(self):
        return self.shared.cfg

    def _bind(self, ingestor):
        self.shared._bind_slot(self, ingestor)

    def submit(self, crops: np.ndarray, objs: np.ndarray,
               frames: np.ndarray):
        if len(objs) == 0:
            return
        self.queue.append((np.asarray(crops), np.asarray(objs, np.int64),
                           np.asarray(frames, np.int64)))
        if self.shared.auto_pump:
            self.shared.pump()

    def flush_pending(self):
        """Publication barrier: drain every queued batch (all streams —
        fold timing is invisible to the byte-identity contract)."""
        self.shared.pump()

    def reset(self):
        """Shard rollover for this stream: its ingestor reset its host
        state; zero the stream's device-resident block."""
        if self.queue:
            raise RuntimeError(
                f"reset() on stream {self.name!r} with queued batches; "
                f"seal must drain first")
        self.shared._reset_slot(self)


class ShardedIngestPipeline:
    """N-stream fused ingest sharded over a 1-D ``("data",)`` mesh.

    ``slots`` is the device-major stream layout (see
    ``core.streaming.StreamPlacement``): length a multiple of the mesh
    size, ``None`` entries are inert padding slots. All streams share ONE
    ``IngestConfig`` (the stacked cluster tables have one (M, D) shape)
    and one traceable ``cheap_fn``. Per stacked step the pipeline issues
    one sharded megastep (plus at most one sharded tail scan) covering up
    to one queued batch per stream, then fetches the whole stack's
    ``(j, matched)`` — and the fold rows — in single ``device_get`` calls
    at the designed fold boundary; folding stays host-side per stream via
    ``StreamingIngestor._fold_rows``.

    ``topk_sink(stream_name, objs, vals, idxs)`` — note the extra leading
    stream name vs the single-stream ``IngestPipeline`` sink.
    """

    def __init__(self, cheap_fn: Callable, mesh,
                 slots: Sequence[Optional[str]], cfg=None,
                 topk_k: Optional[int] = None,
                 topk_sink: Optional[Callable] = None,
                 auto_pump: bool = True):
        from repro.distributed import sharding as shd
        if mesh is None:
            raise ValueError("ShardedIngestPipeline needs a mesh; use "
                             "launch.mesh.make_ingest_mesh(n_devices)")
        slots = list(slots)
        n_dev = mesh.size
        if not slots or len(slots) % n_dev:
            raise ValueError(
                f"len(slots)={len(slots)} must be a non-zero multiple of "
                f"the mesh size {n_dev} (pad with None)")
        self.cheap_fn = cheap_fn
        self.mesh = mesh
        self.width = len(slots) // n_dev
        self.cfg = cfg
        if cfg is not None:
            IngestPipeline._check_clustering(cfg)
        self.topk_k = topk_k
        self.topk_sink = topk_sink
        self.auto_pump = auto_pump
        self.stats = PipelineStats()
        # hoisted once: shardings are never rebuilt per step
        self._shardings = shd.ingest_shardings(mesh)
        self._slots: List[Optional[_ShardSlot]] = [
            (_ShardSlot(self, nm, i) if nm is not None else None)
            for i, nm in enumerate(slots)]
        self.handles: Dict[str, _ShardSlot] = {}
        for h in self._slots:
            if h is None:
                continue
            if h.name in self.handles:
                raise ValueError(f"duplicate stream name {h.name!r}")
            self.handles[h.name] = h
        # stacked device state (lazy: feat dim from the first batch)
        self._cen = self._cnt = self._n = None
        self._thr = None
        self._crop_shape: Optional[tuple] = None
        self._seen_keys = set()
        self._megastep_fn: Optional[Callable] = None
        self._tail_fn: Optional[Callable] = None

    def handle(self, name: str) -> _ShardSlot:
        """The pipeline handle to pass as ``StreamingIngestor(pipeline=)``
        for stream ``name``."""
        return self.handles[name]

    # -- wiring ----------------------------------------------------------------

    def _bind_slot(self, h: _ShardSlot, ingestor):
        if h._ing is not None and h._ing is not ingestor:
            raise ValueError(
                f"slot {h.name!r} is already bound to an ingestor")
        IngestPipeline._check_clustering(ingestor.cfg)
        if self.cfg is None:
            self.cfg = ingestor.cfg
        elif self.cfg != ingestor.cfg:
            raise ValueError(
                "all streams sharing a ShardedIngestPipeline must use one "
                "IngestConfig (the stacked cluster tables share one shape "
                "and threshold); construct the pipeline with cfg=None to "
                "inherit the first ingestor's, or pass the same cfg to "
                "every stream")
        h._ing = ingestor

    # -- driver API ------------------------------------------------------------

    def pump(self) -> int:
        """Fold every queued batch; returns total objects folded."""
        total = 0
        while True:
            k = self.pump_one()
            if not k:
                return total
            total += k

    def flush_pending(self):
        self.pump()

    def jit_cache_entries(self) -> dict:
        """Trace-cache entry counts of the sharded megastep / tail jits
        (same contract as ``IngestPipeline.jit_cache_entries``)."""
        def size(fn):
            if fn is None:
                return 0
            try:
                return int(fn._cache_size())
            except Exception:
                return -1
        return {"megastep": size(self._megastep_fn),
                "tail": size(self._tail_fn)}

    # -- the stacked step ------------------------------------------------------

    def pump_one(self) -> int:
        """Dispatch ONE stacked step over the head batch of every stream
        whose head shares the leading stream's (bucket, resolution) key,
        then fold those streams' rows host-side. Returns objects folded
        (0 = no queued batches)."""
        active = [h for h in self._slots if h is not None and h.queue]
        if not active:
            return 0
        t0 = time.perf_counter()
        cfg = self.cfg
        lead_crops = active[0].queue[0][0]
        bucket = batch_bucket(len(active[0].queue[0][1]), cfg.batch_size)
        shape = lead_crops.shape[1:]
        group = [h for h in active
                 if batch_bucket(len(h.queue[0][1]),
                                 cfg.batch_size) == bucket
                 and h.queue[0][0].shape[1:] == shape]
        if self._cen is None:
            self._init_stacked(lead_crops)
        key = (bucket, shape[0])
        if key in self._seen_keys:
            self.stats.compile_hits += 1
        else:
            self._seen_keys.add(key)
            self.stats.compile_misses += 1

        S = len(self._slots)
        crops_stack = np.zeros((S, bucket) + shape, lead_crops.dtype)
        n_real = np.zeros((S,), np.int32)
        parts: Dict[int, tuple] = {}
        for h in group:
            crops, objs, frames = h.queue.popleft()
            crops_stack[h.slot, :len(objs)] = crops
            n_real[h.slot] = len(objs)
            parts[h.slot] = (h, crops, objs, frames)

        k_top = self.topk_k if self.topk_k is not None else cfg.K
        with_topk = self.topk_sink is not None
        fn = self._megastep_fn = _sharded_megastep_jit(
            self.cheap_fn, k_top, with_topk, self.mesh, self.width)
        out = fn(self._cen, self._cnt, self._n, self._thr,
                 jax.device_put(n_real, self._shardings["n_real"]),
                 jax.device_put(crops_stack, self._shardings["crops"]))
        if with_topk:
            cen, cnt, nv, probs, feats, j, matched, vals, idxs = out
        else:
            cen, cnt, nv, probs, feats, j, matched = out
            vals = idxs = None
        self._cen, self._cnt, self._n = cen, cnt, nv
        self.stats.n_dispatches += 1
        self.stats.n_steps += 1
        self.stats.n_batches += len(parts)

        # focuslint: disable=host-sync -- the ONE designed per-step
        # (j, matched) fetch: the whole stack in a single device_get (a
        # per-slot slice fetch would dispatch a gather per stream)
        j_h, m_h = jax.device_get((j, matched))
        j_h, m_h = np.asarray(j_h), np.asarray(m_h)

        # stacked unmatched tail: one more dispatch covering every stream
        # that needs it; others ride along as bitwise no-ops
        tails: Dict[int, np.ndarray] = {}
        u_max = 0
        for slot, (h, crops, objs, frames) in parts.items():
            um = np.nonzero(~m_h[slot, :len(objs)])[0]
            if len(um):
                tails[slot] = um
                u_max = max(u_max, len(um))
        sub_h = None
        if tails:
            P = C._pad_bucket(u_max)
            tail_key = ("tail", P, bucket)
            if tail_key in self._seen_keys:
                self.stats.tail_compile_hits += 1
            else:
                self._seen_keys.add(tail_key)
                self.stats.tail_compile_misses += 1
            gather = np.zeros((S, P), np.int64)
            valid = np.zeros((S, P), bool)
            for slot, um in tails.items():
                gather[slot, :len(um)] = um
                valid[slot, :len(um)] = True
            gfn = self._tail_fn = _sharded_tail_jit(self.mesh, self.width)
            cen, cnt, nv, sub = gfn(
                self._cen, self._cnt, self._n, feats,
                jax.device_put(gather, self._shardings["rows"]),
                jax.device_put(valid, self._shardings["rows"]), self._thr)
            self._cen, self._cnt, self._n = cen, cnt, nv
            self.stats.n_dispatches += 1
            self.stats.n_tail_scans += 1

        # focuslint: disable=host-sync -- designed fold boundary: the fold
        # rows (probs/feats[/topk/tail ids]) for ALL streams in ONE fetch
        fetch = jax.device_get(tuple(
            a for a in (probs, feats, vals, idxs,
                        sub if tails else None) if a is not None))
        probs_h, feats_h = np.asarray(fetch[0]), np.asarray(fetch[1])
        if with_topk:
            vals_h, idxs_h = np.asarray(fetch[2]), np.asarray(fetch[3])
        if tails:
            sub_h = np.asarray(fetch[-1])

        # host fold per stream in slot order; evictions collect and run
        # once after the loop (per-slot independent, so batching the
        # rare-path stack round trip changes no per-stream bytes)
        n_host = None
        hw = int(cfg.high_water * cfg.max_clusters)
        evictors: List[_ShardSlot] = []
        total = 0
        for slot in sorted(parts):
            h, crops, objs, frames = parts[slot]
            n = len(objs)
            ing = h._ing
            slots_v = j_h[slot, :n].astype(np.int32)
            um = tails.get(slot)
            if um is not None:
                slots_v[um] = sub_h[slot, :len(um)]
                h._n_hi += len(um)
            ing.stats.n_cnn_invocations += n
            ing.stats.cheap_flops += n * ing.cheap_flops_per_image
            ing._fold_rows(crops, objs, frames, probs_h[slot, :n],
                           feats_h[slot, :n], slots_v)
            self.stats.n_objects += n
            total += n
            if with_topk:
                self.topk_sink(h.name, objs, vals_h[slot, :n],
                               idxs_h[slot, :n])
            # same bound-gated eviction trigger as IngestPipeline._resolve:
            # n_hi >= live n always, so no staged eviction point is missed
            if h._n_hi >= hw:
                if n_host is None:
                    self.stats.n_eviction_syncs += 1
                    # focuslint: disable=host-sync -- bound-gated: the
                    # tiny (S,) live-count vector, once per crossing step
                    n_host = np.asarray(jax.device_get(self._n))
                h._n_hi = int(n_host[slot])
                if h._n_hi >= hw:
                    evictors.append(h)
        if evictors:
            self._evict_slots(evictors)
        dt = time.perf_counter() - t0
        for slot in parts:
            h, _, objs, _ = parts[slot]
            h._ing.stats.wall_s += dt * (len(objs) / max(total, 1))
        return total

    # -- internals -------------------------------------------------------------

    def _init_stacked(self, crops: np.ndarray):
        cfg = self.cfg
        if cfg is None:
            raise RuntimeError("pipeline has no cfg; bind an ingestor "
                               "(StreamingIngestor(pipeline=handle)) first")
        probs_s, feats_s = jax.eval_shape(
            self.cheap_fn,
            jax.ShapeDtypeStruct((8,) + crops.shape[1:], jnp.float32))
        if self.topk_k is not None and self.topk_k > probs_s.shape[1]:
            raise ValueError(
                f"topk_k={self.topk_k} exceeds the model's "
                f"{probs_s.shape[1]} classes")
        S, M, D = len(self._slots), cfg.max_clusters, feats_s.shape[1]
        self._cen = jax.device_put(np.zeros((S, M, D), np.float32),
                                   self._shardings["centroids"])
        self._cnt = jax.device_put(np.zeros((S, M), np.int32),
                                   self._shardings["counts"])
        self._n = jax.device_put(np.zeros((S,), np.int32),
                                 self._shardings["n"])
        self._thr = jax.device_put(np.float32(cfg.threshold),
                                   self._shardings["replicated"])
        self._crop_shape = crops.shape[1:]

    def _evict_slots(self, handles: Sequence[_ShardSlot]):
        """Rare path, same semantics as the staged ``_evict_live``: pull
        the evicting streams' tables to the host, evict smallest + remap
        through each ingestor (slot→cid bookkeeping lives there), write
        the blocks back. All of a step's crossing slots share ONE
        fetch/store of the whole stack — evictions only touch their own
        slot's rows, so batching them is bitwise-neutral, and a per-slot
        slice fetch of a sharded array would dispatch a gather per stream
        and is far slower than the straight copy."""
        # focuslint: disable=host-sync -- rare eviction path; the remap
        # must land before the streams' next batch dispatches
        cen_h, cnt_h, n_h = jax.device_get((self._cen, self._cnt, self._n))
        cen_h, cnt_h = np.asarray(cen_h).copy(), np.asarray(cnt_h).copy()
        n_h = np.asarray(n_h).copy()
        for h in handles:
            ing = h._ing
            ing._state = C.ClusterState(cen_h[h.slot], cnt_h[h.slot],
                                        n_h[h.slot])
            ing._evict_live()
            st = ing._state
            ing._state = None            # sharded state lives on-device
            cen_h[h.slot] = np.asarray(st.centroids)
            cnt_h[h.slot] = np.asarray(st.counts)
            n_h[h.slot] = int(st.n)
            h._n_hi = int(n_h[h.slot])
        self._write_back(cen_h, cnt_h, n_h)

    def _reset_slot(self, h: _ShardSlot):
        h._n_hi = 0
        if self._cen is None:
            return
        # focuslint: disable=host-sync -- shard-rollover path (seal), not
        # the per-batch hot path
        cen_h, cnt_h, n_h = jax.device_get((self._cen, self._cnt, self._n))
        cen_h, cnt_h = np.asarray(cen_h).copy(), np.asarray(cnt_h).copy()
        n_h = np.asarray(n_h).copy()
        cen_h[h.slot] = 0.0
        cnt_h[h.slot] = 0
        n_h[h.slot] = 0
        self._write_back(cen_h, cnt_h, n_h)

    def _write_back(self, cen_h, cnt_h, n_h):
        self._cen = jax.device_put(cen_h, self._shardings["centroids"])
        self._cnt = jax.device_put(cnt_h, self._shardings["counts"])
        self._n = jax.device_put(n_h, self._shardings["n"])
