"""Focus parameter selection (paper §4.4).

Sweeps (CheapCNN_i, K, T) per stream against GT-CNN ground truth on a
sample, keeps configurations meeting the precision/recall targets, draws the
Pareto boundary over (ingest cost, query latency), and picks:
    Balance     — min (ingest + query) total GPU cost   [default]
    Opt-Ingest  — cheapest ingest among viable configs
    Opt-Query   — fastest query among viable configs

Two-step search exactly as §4.4: (CheapCNN_i, Ls, K) are chosen against the
recall target first; T is then tightened until precision passes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import QueryEngine
from repro.core.query import (dominant_classes, gt_frames_by_class,
                              precision_recall)
from repro.core.ingest import IngestConfig, ingest
from repro.core.index import TopKIndex


@dataclass(frozen=True)
class Candidate:
    model_id: str
    K: int
    T: float


@dataclass
class ConfigEval:
    candidate: Candidate
    precision: float
    recall: float
    ingest_flops: float
    query_flops: float           # avg over dominant classes (latency proxy)
    n_clusters: int
    viable: bool = False

    def cost_tuple(self) -> Tuple[float, float]:
        return (self.ingest_flops, self.query_flops)


def _simulate_queries(engine: QueryEngine, gt_by_class: Dict[int, np.ndarray],
                      classes: Sequence[int], Kx: int, gt_flops: float):
    """P/R + query cost for each dominant class, served through the batched
    engine in oracle mode (rep object's gt label IS what GT-CNN would
    output, by the paper's definition of ground truth). The engine's label
    cache persists across calls, so sweeping the K grid verifies each
    cluster once instead of once per K.

    ``query_flops`` stays the *cold* cost model — what one standalone query
    of this class would pay (candidates × GT FLOPs) — since it is the
    paper's query-latency proxy, independent of sweep-internal caching.
    """
    results, _ = engine.query_many(classes, Kx)
    ps, rs, costs = [], [], []
    for x, res in zip(classes, results):
        p, r = precision_recall(res.frames,
                                gt_by_class.get(int(x), np.array([])))
        ps.append(p)
        rs.append(r)
        costs.append(res.n_candidate_clusters * gt_flops)
    return float(np.mean(ps)), float(np.mean(rs)), float(np.mean(costs))


def sweep(crops: np.ndarray, frames: np.ndarray, gt_labels: np.ndarray,
          cheap_models: Dict[str, Tuple[Callable, float]],
          Ks: Sequence[int], Ts: Sequence[float], gt_flops: float,
          precision_target: float = 0.95, recall_target: float = 0.95,
          max_clusters: int = 4096, batch_size: int = 512,
          class_maps: Optional[Dict[str, object]] = None,
          ) -> List[ConfigEval]:
    """cheap_models: model_id -> (apply_fn, flops_per_image)."""
    evals: List[ConfigEval] = []
    dom = dominant_classes(gt_labels)
    gt_by_class = gt_frames_by_class(gt_labels, frames)
    Kmax = max(Ks)
    for mid, (apply_fn, flops) in cheap_models.items():
        cmap = (class_maps or {}).get(mid)
        for T in Ts:
            cfg = IngestConfig(K=Kmax, threshold=T,
                               max_clusters=max_clusters,
                               batch_size=batch_size)
            index, stats = ingest(crops, frames, apply_fn, flops, cfg,
                                  class_map=cmap)
            engine = QueryEngine(index, oracle_labels=gt_labels,
                                 gt_flops_per_image=gt_flops)
            for K in Ks:
                p, r, qcost = _simulate_queries(engine, gt_by_class,
                                                dom, K, gt_flops)
                evals.append(ConfigEval(
                    Candidate(mid, K, T), precision=p, recall=r,
                    ingest_flops=stats.cheap_flops, query_flops=qcost,
                    n_clusters=index.n_clusters,
                    viable=(p >= precision_target and r >= recall_target)))
    return evals


@dataclass(frozen=True)
class SamplerConfig:
    """Knobs for the per-stream adaptive frame sampler (DESIGN.md §10)."""
    min_stride: int = 1
    max_stride: int = 30
    # duplicate-rate hysteresis band: raise the stride above ``high``,
    # lower it below ``low``, hold inside the band
    dup_high: float = 0.80
    dup_low: float = 0.50
    recall_floor: float = 0.97      # the recall gate


class AdaptiveSampler:
    """AIMD frame-stride controller driven by observed redundancy.

    Each ``observe`` window reports how many objects the gate/tracker
    skipped vs. ingested. A high duplicate rate means the stream is
    redundant — the stride *additively* increases (+1), spending less on
    near-identical frames. A low rate means content is changing — the
    stride *multiplicatively* halves, the classic AIMD asymmetry: probe
    savings slowly, give them back fast.

    The recall gate overrides everything: when a probe measures recall
    against ungated ingest below ``recall_floor``, the stride collapses
    to ``min_stride`` immediately — throughput is never bought with
    recall. The caller wires the output to
    ``StreamingIngestor.set_frame_stride``.
    """

    def __init__(self, cfg: SamplerConfig = SamplerConfig()):
        if cfg.min_stride < 1 or cfg.max_stride < cfg.min_stride:
            raise ValueError(f"bad stride bounds: {cfg}")
        if not 0.0 <= cfg.dup_low <= cfg.dup_high <= 1.0:
            raise ValueError(f"bad duplicate-rate band: {cfg}")
        self.cfg = cfg
        self.stride = cfg.min_stride

    def observe(self, n_ingested: int, n_skipped: int,
                recall: Optional[float] = None,
                n_sampled_out: int = 0) -> int:
        """One control step; returns the stride for the next window.

        ``n_ingested`` — objects that reached the CNN this window;
        ``n_skipped`` — objects the tracker/gate deduplicated *among
        those that survived the stride filter*;
        ``n_sampled_out`` — objects the frame stride itself dropped.
        They are excluded from the duplicate rate: at stride S the stride
        removes >= (S-1)/S of the window regardless of content, so
        counting them as "skipped" is a positive feedback loop — the
        controller's own stride manufactures the redundancy signal that
        raises the stride, ratcheting to ``max_stride`` until the recall
        probe collapses it and the loop starts over (oscillation instead
        of convergence). Only gate/tracker skips measure content
        redundancy, and they naturally fall as the stride widens past the
        stream's temporal-correlation window — the negative feedback that
        makes AIMD settle.
        ``recall`` — optional probe of gated recall vs. ungated ingest.
        """
        c = self.cfg
        if recall is not None and recall < c.recall_floor:
            self.stride = c.min_stride
            return self.stride
        del n_sampled_out                  # accepted, never a control input
        total = n_ingested + n_skipped
        if total <= 0:
            return self.stride
        dup_rate = n_skipped / total
        if dup_rate > c.dup_high:
            self.stride = min(self.stride + 1, c.max_stride)
        elif dup_rate < c.dup_low:
            self.stride = max(self.stride // 2, c.min_stride)
        return self.stride


def pareto_boundary(evals: Sequence[ConfigEval]) -> List[ConfigEval]:
    """Non-dominated (ingest, query) points among viable configs."""
    viable = [e for e in evals if e.viable]
    out = []
    for e in viable:
        dominated = any(
            (o.ingest_flops <= e.ingest_flops
             and o.query_flops <= e.query_flops
             and (o.ingest_flops < e.ingest_flops
                  or o.query_flops < e.query_flops))
            for o in viable)
        if not dominated:
            out.append(e)
    return sorted(out, key=lambda e: e.ingest_flops)


def select(evals: Sequence[ConfigEval], policy: str = "balance",
           ) -> Optional[ConfigEval]:
    front = pareto_boundary(evals)
    if not front:
        return None
    if policy == "balance":     # min total GPU cycles (§4.4)
        return min(front, key=lambda e: e.ingest_flops + e.query_flops)
    if policy == "opt_ingest":
        return min(front, key=lambda e: (e.ingest_flops, e.query_flops))
    if policy == "opt_query":
        return min(front, key=lambda e: (e.query_flops, e.ingest_flops))
    raise ValueError(policy)
