"""Batched multi-query engine over one Focus top-K index (paper §4.2, §5).

The per-class ``query()`` loop re-invokes the expensive GT-CNN on the same
cluster centroids for every query — exactly the redundant work Focus exists
to avoid (a centroid's class does not depend on which query asked).
``QueryEngine`` serves many concurrent queries against one index with:

* a persistent **GT-label cache** keyed by ``(cluster id, centroid
  version)``: a centroid is classified by the GT-CNN at most once across
  all queries and all classes. ``ClusterStore.versions`` is bumped whenever
  ingest moves a centroid (``add_batch`` fold, ``add_cluster`` replace), so
  stale entries invalidate precisely — per moved cluster, not cache-wide.
  ``attach`` does not move centroids and therefore invalidates nothing.
* ``query_many(classes, Kx)``: union the candidate clusters of the whole
  query batch, dedupe against the cache, run **one** padded/bucketed
  GT-CNN pass over only the uncached rep crops, and scatter verdicts back
  to each query. Result frame sets are identical to sequential ``query()``
  per class.
* an **oracle mode** (``oracle_labels``) where a cluster's GT verdict is
  its first (centroid-representative) member's ground-truth label — the
  stand-in §4.4 parameter selection uses, so sweeps stop paying redundant
  simulated GT passes across the K grid.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.index import TopKIndex
from repro.core.query import QueryResult, pad_to_bucket


def grow_row_cache(vers: np.ndarray, labels: np.ndarray, n_rows: int,
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Grow a row-aligned (versions, labels) label cache to cover
    ``n_rows`` store rows (amortized doubling; version -1 = no entry —
    live rows always have version >= 1, so the sentinel is safe). Shared
    by ``QueryEngine`` and the per-shard caches in ``core.archive``."""
    if len(vers) < n_rows:
        grown_v = np.full(max(n_rows, 2 * len(vers)), -1, np.int64)
        grown_v[:len(vers)] = vers
        grown_l = np.zeros(len(grown_v), np.int64)
        grown_l[:len(labels)] = labels
        vers, labels = grown_v, grown_l
    return vers, labels


def _reject_bool_kx(x):
    # bool is a subclass of int, so True/False would silently pass the
    # scalar check below and query with Kx=1/0 — almost certainly a
    # mis-passed flag; demand an explicit integer
    if isinstance(x, (bool, np.bool_)):
        raise TypeError(
            f"Kx must be an int or None, got bool {x!r} (True/False would "
            f"silently query with Kx=1/0)")


def normalize_kx(Kx, n_queries: int) -> List[Optional[int]]:
    """One Kx per query: broadcast a scalar/None, validate a sequence."""
    _reject_bool_kx(Kx)
    if Kx is None or isinstance(Kx, (int, np.integer)):
        return [Kx] * n_queries
    if len(Kx) != n_queries:
        raise ValueError("per-query Kx length mismatch")
    out = list(Kx)
    for k in out:
        _reject_bool_kx(k)
    return out


def probe_row_cache(vers: np.ndarray, cached: np.ndarray, rows: np.ndarray,
                    versions: np.ndarray,
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized probe of a row-aligned label cache: one version-match
    against the store's ``versions`` for the given rows. Returns
    ``(hit mask, labels (stale at miss positions), miss positions)``.
    Shared by ``QueryEngine.verify`` and both archive cache paths."""
    hit = vers[rows] == versions
    labels = cached[rows].copy()
    return hit, labels, np.nonzero(~hit)[0]


def classify_crops(gt_apply: Callable[[np.ndarray], np.ndarray],
                   crops: np.ndarray, batch_size: int, batch_pad: int,
                   ) -> Tuple[np.ndarray, int]:
    """One bucket-padded GT-CNN pass over ``crops``, chunked only by
    ``batch_size``; returns (labels, gt_apply launches)."""
    out = np.empty(len(crops), np.int64)
    n_batches = 0
    for start in range(0, len(crops), batch_size):
        chunk = crops[start:start + batch_size]
        padded = pad_to_bucket(chunk, batch_pad)
        out[start:start + len(chunk)] = \
            np.asarray(gt_apply(padded))[:len(chunk)]
        n_batches += 1
    return out, n_batches


@dataclass
class EngineStats:
    """Cumulative counters over the engine's lifetime."""
    n_queries: int = 0
    n_candidates: int = 0        # sum of per-query candidate clusters
    n_cache_hits: int = 0        # candidate verdicts served from the cache
    n_gt_invocations: int = 0    # real crops classified by the GT-CNN
    gt_flops: float = 0.0


@dataclass
class BatchQueryStats:
    """Accounting for one ``query_many`` call."""
    n_queries: int
    n_candidates: int            # sum over queries (with cross-query dups)
    n_unique_candidates: int     # after the cross-query union
    n_cache_hits: int
    n_gt_invocations: int        # real crops classified in this call
    gt_flops: float
    wall_s: float


class QueryEngine:
    """Serves class queries against ``index``, classifying each cluster
    centroid with the expensive GT-CNN at most once.

    Exactly one of ``gt_apply`` (crops (B,R,R,3) -> global class ids (B,))
    and ``oracle_labels`` (per-object ground-truth labels, indexed by the
    cluster's first member) must be given.
    """

    def __init__(self, index: TopKIndex,
                 gt_apply: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 gt_flops_per_image: float = 0.0,
                 batch_size: int = 256, batch_pad: int = 64,
                 oracle_labels: Optional[np.ndarray] = None):
        if (gt_apply is None) == (oracle_labels is None):
            raise ValueError(
                "exactly one of gt_apply / oracle_labels must be provided")
        self.index = index
        self.gt_apply = gt_apply
        self.gt_flops_per_image = gt_flops_per_image
        self.batch_size = batch_size
        self.batch_pad = batch_pad
        self.oracle_labels = (np.asarray(oracle_labels, np.int64)
                              if oracle_labels is not None else None)
        # row-aligned GT-label cache: the entry for a cluster lives at its
        # store row (rows are append-only, so alignment is stable), keyed
        # semantically by (cid, centroid version). version -1 = no entry;
        # live rows always have version >= 1, so the sentinel is safe.
        self._cache_vers = np.full(0, -1, np.int64)
        self._cache_labels = np.zeros(0, np.int64)
        self.stats = EngineStats()

    # -- cache -----------------------------------------------------------------

    def __len__(self) -> int:
        return int((self._cache_vers >= 0).sum())

    def _cache_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Grow the row-aligned cache to cover every store row."""
        self._cache_vers, self._cache_labels = grow_row_cache(
            self._cache_vers, self._cache_labels, self.index.store.n_rows)
        return self._cache_vers, self._cache_labels

    def cached_label(self, cid: int) -> Optional[int]:
        """The cached GT verdict for ``cid`` if still valid, else None
        (also for cids the index has never seen)."""
        row = self.index.store._cid_to_row.get(int(cid))
        if row is None or row >= len(self._cache_vers):
            return None
        if int(self._cache_vers[row]) != int(self.index.store.versions[row]):
            return None
        return int(self._cache_labels[row])

    def _classify_misses(self, rows: np.ndarray) -> np.ndarray:
        """GT-CNN labels for the store rows of uncached candidates."""
        s = self.index.store
        if self.oracle_labels is not None:
            return self.oracle_labels[s.first_objs[rows]]
        if s.rep_crops is None:
            raise ValueError("no representative crops were stored "
                             "(add_batch was called without crops)")
        labels, _ = classify_crops(self.gt_apply, s.rep_crops[rows],
                                   self.batch_size, self.batch_pad)
        return labels

    def verify(self, cids: np.ndarray) -> Tuple[np.ndarray, int, List[int]]:
        """GT verdicts for ``cids`` (aligned), via the cache.

        Returns ``(labels, n_cache_hits, miss_cids)`` where ``miss_cids``
        are the cids freshly classified in this call (len == GT
        invocations); they are classified in one bucketed pass and cached
        under the centroid's current version.
        """
        cids = np.asarray(cids, np.int64)
        if len(cids) == 0:
            return np.zeros((0,), np.int64), 0, []
        s = self.index.store
        rows = s.rows_of(cids)
        versions = s.versions[rows]
        vers, cached = self._cache_arrays()
        # vectorized version-match: one compare against store.versions
        # instead of a per-candidate Python probe (candidate unions are
        # multiplied by shard fan-out in archive queries)
        _, labels, miss = probe_row_cache(vers, cached, rows, versions)
        n_hits = len(cids) - len(miss)
        if len(miss):
            mrows = rows[miss]
            fresh = self._classify_misses(mrows)
            labels[miss] = fresh
            vers[mrows] = versions[miss]
            cached[mrows] = fresh
        return labels, n_hits, [int(c) for c in cids[miss]]

    def prefetch(self, cids) -> int:
        """Warm the GT-label cache for ``cids`` — typically a streaming
        flush's ``IngestDelta.touched_cids`` — ahead of the next query
        round, moving GT-CNN cost for new/moved centroids off the query
        path (query-while-ingest freshness). Returns the number of fresh
        classifications; already-valid entries cost nothing."""
        cids = np.unique(np.asarray(list(cids), np.int64))
        _, _, miss = self.verify(cids)
        self.stats.n_gt_invocations += len(miss)
        self.stats.gt_flops += len(miss) * self.gt_flops_per_image
        return len(miss)

    # -- queries ---------------------------------------------------------------

    def query_many(self, classes: Sequence[int],
                   Kx: Union[None, int, Sequence[Optional[int]]] = None,
                   ) -> Tuple[List[QueryResult], BatchQueryStats]:
        """Serve a batch of class queries with one shared GT-CNN pass.

        ``Kx`` is either one value for the whole batch or a per-query
        sequence. Per-query ``n_gt_invocations`` charges each freshly
        classified centroid to the first query whose candidate set contains
        it (so the per-query numbers sum to the batch total); ``wall_s`` is
        the batch wall time amortized evenly over the queries — the batch
        stats carry the true totals.
        """
        t0 = time.perf_counter()
        classes = [int(c) for c in classes]
        Kxs = normalize_kx(Kx, len(classes))
        cand = [np.asarray(self.index.lookup(c, k), np.int64)
                for c, k in zip(classes, Kxs)]
        union = (np.unique(np.concatenate(cand)) if cand
                 else np.zeros((0,), np.int64))
        labels, n_hits, miss_cids = self.verify(union)
        n_gt = len(miss_cids)
        label_of = dict(zip(union.tolist(), labels.tolist()))

        results = []
        uncharged = set(miss_cids)
        for cls, cids in zip(classes, cand):
            matched = [int(c) for c in cids.tolist() if label_of[c] == cls]
            fresh = [c for c in cids.tolist() if c in uncharged]
            uncharged.difference_update(fresh)
            results.append(QueryResult(
                queried_class=cls, frames=self.index.frames_of(matched),
                matched_clusters=matched, n_candidate_clusters=len(cids),
                n_gt_invocations=len(fresh),
                gt_flops=len(fresh) * self.gt_flops_per_image,
                wall_s=0.0))
        wall = time.perf_counter() - t0          # includes frame scatter
        per_q_wall = wall / max(len(classes), 1)
        for res in results:
            res.wall_s = per_q_wall
        batch = BatchQueryStats(
            n_queries=len(classes),
            n_candidates=int(sum(len(c) for c in cand)),
            n_unique_candidates=len(union), n_cache_hits=n_hits,
            n_gt_invocations=n_gt,
            gt_flops=n_gt * self.gt_flops_per_image, wall_s=wall)
        self.stats.n_queries += batch.n_queries
        self.stats.n_candidates += batch.n_candidates
        self.stats.n_cache_hits += n_hits
        self.stats.n_gt_invocations += n_gt
        self.stats.gt_flops += batch.gt_flops
        return results, batch

    def query(self, global_class: int,
              Kx: Optional[int] = None) -> QueryResult:
        """Single-query convenience over the shared cache."""
        results, batch = self.query_many([global_class], Kx)
        res = results[0]
        res.wall_s = batch.wall_s
        return res
