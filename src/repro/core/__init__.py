"""Focus core: the paper's contribution (ingest/query split, top-K index,
clustering, parameter selection, specialization)."""
from repro.core.index import (  # noqa: F401
    ClassMap,
    Cluster,
    ClusterStore,
    TopKIndex,
    OTHER,
)
from repro.core.engine import (  # noqa: F401
    BatchQueryStats,
    EngineStats,
    QueryEngine,
)
from repro.core.archive import (  # noqa: F401
    ArchiveBatchStats,
    ArchiveQueryEngine,
    ArchiveQueryResult,
    ShardCatalog,
    ShardLoader,
    ShardMeta,
)
from repro.core.ingest import IngestConfig, IngestStats, ingest  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    IngestPipeline,
    PipelineStats,
    batch_bucket,
    staged_cheap_apply,
)
from repro.core.streaming import (  # noqa: F401
    IngestDelta,
    MultiStreamRunner,
    StreamingIngestor,
)
from repro.core.query import (  # noqa: F401
    BaselineCosts,
    QueryResult,
    dominant_classes,
    gt_frames_by_class,
    gpu_seconds,
    precision_recall,
    query,
)
