"""Incremental single-pass object clustering (Focus §4.2).

Semantics (paper): put the first object in cluster c1. For each new object
with feature f, assign it to the closest centroid within L2 distance T and
update that centroid's running mean; otherwise open a new cluster at f. The
cluster count is bounded by M; when the buffer fills, the *smallest*
clusters are evicted to the top-K index (handled by the ingest driver
between batches) — complexity stays O(M·n).

Three implementations (DESIGN.md §3):
  * ``cluster_scan``   — canonical sequential semantics via lax.scan
                         (the oracle; exactly the paper's algorithm).
  * ``cluster_batched``— TPU-adapted two-phase variant: the (B, M) distance
                         matrix is computed in one MXU-friendly shot (Pallas
                         kernel on TPU, jnp on CPU) against the *batch-start*
                         centroid table; objects that match no existing
                         centroid are resolved sequentially within the batch.
                         This exposes the parallelism the paper's CPU loop
                         lacks and is provably equivalent to ``cluster_scan``
                         whenever batch objects join pre-existing clusters
                         (the common case: consecutive frames of the same
                         object).
  * ``cluster_fused``  — the vectorized fast path: phase-1 matched objects
                         fold into their centroids in ONE segment-sum shot
                         (a batched running-mean update), and the sequential
                         scan runs only over the gathered *unmatched*
                         subsequence (typically a small fraction of the
                         batch) before ids are scattered back. Equivalent to
                         ``cluster_scan`` on the same inputs where
                         ``cluster_batched`` is (assignment decisions stable
                         under within-batch centroid drift): the final
                         centroid of a fixed member set is its arithmetic
                         mean, which is fold-order independent.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


class ClusterState(NamedTuple):
    centroids: jax.Array    # (M, D) float32; rows >= n are undefined
    counts: jax.Array       # (M,) int32 (0 for empty slots)
    n: jax.Array            # scalar int32: live cluster count


def init_state(max_clusters: int, feat_dim: int) -> ClusterState:
    return ClusterState(
        centroids=jnp.zeros((max_clusters, feat_dim), jnp.float32),
        counts=jnp.zeros((max_clusters,), jnp.int32),
        n=jnp.zeros((), jnp.int32),
    )


def _sq_dists(f, centroids):
    """Squared L2 distance of f (D,) to every centroid row (M, D)."""
    diff = centroids - f[None, :]
    return jnp.sum(diff * diff, axis=-1)


def _assign_one(state: ClusterState, f, threshold: float):
    """Assign a single feature; returns (new_state, cluster_id)."""
    M = state.centroids.shape[0]
    d2 = _sq_dists(f, state.centroids)
    live = jnp.arange(M) < state.n
    d2 = jnp.where(live, d2, jnp.inf)
    j = jnp.argmin(d2)
    within = d2[j] <= threshold * threshold

    full = state.n >= M
    make_new = jnp.logical_and(~within, ~full)
    # If full and nothing within T: paper evicts smallest; here the object
    # joins the nearest cluster and the driver evicts between batches.
    cid = jnp.where(make_new, state.n, j)

    cnt = state.counts[cid]
    new_count = jnp.where(make_new, 1, cnt + 1)
    old_c = state.centroids[cid]
    new_c = jnp.where(make_new, f, old_c + (f - old_c) / new_count)

    centroids = state.centroids.at[cid].set(new_c)
    counts = state.counts.at[cid].set(new_count)
    n = jnp.where(make_new, state.n + 1, state.n)
    return ClusterState(centroids, counts, n), cid


@jax.jit
def _cluster_scan_impl(state: ClusterState, feats, threshold):
    def step(st, f):
        st, cid = _assign_one(st, f, threshold)
        return st, cid

    return lax.scan(step, state, feats)


def cluster_scan(state: ClusterState, feats, threshold: float):
    """Sequential clustering of feats (B, D). Returns (state, ids (B,))."""
    return _cluster_scan_impl(state, jnp.asarray(feats, jnp.float32),
                              jnp.float32(threshold))


# ---------------------------------------------------------------------------
# TPU-adapted two-phase batched variant
# ---------------------------------------------------------------------------

@jax.jit
def _phase1(centroids, counts, n, feats, threshold):
    """Kernel-backed distances against the batch-start centroid table.
    Dead slots (>= n) are pushed to a far sentinel so the kernel's online
    argmin never selects them. The threshold compare is fused into the
    kernel's final grid step (one pass, no host-side compare); the
    threshold enters the kernel as an SMEM scalar, so sweeping T (§4.4
    parameter selection) never recompiles."""
    from repro.kernels import ops as kops
    M = centroids.shape[0]
    live = (jnp.arange(M) < n)[:, None]
    masked = jnp.where(live, centroids, 1e9)
    d2, j, matched = kops.centroid_assign(feats, masked,
                                          threshold=threshold)
    return j, matched


def cluster_batched(state: ClusterState, feats, threshold: float):
    """Two-phase batched clustering. Returns (state, ids (B,)).

    Phase 1 (parallel, MXU): distances of the whole batch against the
    batch-start centroids -> matched mask. Phase 2 (scan): matched objects
    fold into their centroid; unmatched objects run the sequential rule so
    within-batch new clusters behave exactly like ``cluster_scan``.
    """
    feats = jnp.asarray(feats, jnp.float32)
    j, matched = _phase1(state.centroids, state.counts, state.n, feats,
                         jnp.float32(threshold))
    return _phase2(state, feats, j, matched, jnp.float32(threshold))


@jax.jit
def _phase2(state, feats, j, matched, threshold):
    def step(st, inp):
        f, jj, m = inp

        def fold(st):
            cnt = st.counts[jj] + 1
            c = st.centroids[jj]
            c = c + (f - c) / cnt
            return ClusterState(st.centroids.at[jj].set(c),
                                st.counts.at[jj].set(cnt), st.n), jj

        def slow(st):
            return _assign_one(st, f, threshold)

        return lax.cond(m, fold, slow, st)

    return lax.scan(step, state, (feats, j, matched))


# ---------------------------------------------------------------------------
# Fused fast path: segment-sum fold + unmatched-only scan
# ---------------------------------------------------------------------------

@jax.jit
def _fold_matched(state: ClusterState, feats, j, matched):
    """Fold every phase-1-matched object into its centroid in one shot.

    Unmatched rows are routed to an overflow segment M that is sliced away,
    so a single ``segment_sum`` handles the whole batch. The batched
    running-mean update ``(c·cnt + Σf) / (cnt + k)`` equals k sequential
    running-mean folds exactly (up to float association).
    """
    M = state.centroids.shape[0]
    seg = jnp.where(matched, j, M)
    add_cnt = jax.ops.segment_sum(matched.astype(jnp.int32), seg,
                                  num_segments=M + 1)[:M]
    feat_sum = jax.ops.segment_sum(feats, seg, num_segments=M + 1)[:M]
    new_counts = state.counts + add_cnt
    denom = jnp.maximum(new_counts, 1).astype(jnp.float32)[:, None]
    folded = (state.centroids * state.counts.astype(jnp.float32)[:, None]
              + feat_sum) / denom
    centroids = jnp.where(add_cnt[:, None] > 0, folded, state.centroids)
    return ClusterState(centroids, new_counts, state.n)


@jax.jit
def _scan_unmatched(state: ClusterState, feats, valid, threshold):
    """Sequential rule over the gathered unmatched subsequence; padded rows
    (valid == False) are no-ops and return id -1."""
    def step(st, inp):
        f, v = inp
        new_st, cid = _assign_one(st, f, threshold)
        st = jax.tree.map(lambda a, b: jnp.where(v, a, b), new_st, st)
        return st, jnp.where(v, cid, -1)

    return lax.scan(step, state, (feats, valid))


def _pad_bucket(n: int) -> int:
    """Next power of two >= n (min 8): bounds scan recompiles to O(log B)."""
    p = 8
    while p < n:
        p *= 2
    return p


def cluster_fused(state: ClusterState, feats, threshold: float):
    """Vectorized fast-path clustering. Returns (state, ids (B,)).

    Phase 1 (parallel, MXU): kernel distances + fused threshold -> matched.
    Matched objects fold into their batch-start centroids via one
    segment-sum (no scan step for them at all). Phase 2 (scan) runs ONLY
    over the gathered unmatched subsequence — length U << B in steady-state
    video — padded to a power-of-two bucket; ids are scattered back into
    batch order. Equivalent to ``cluster_scan`` wherever ``cluster_batched``
    is (see module docstring).
    """
    feats = jnp.asarray(feats, jnp.float32)
    B = feats.shape[0]
    if B == 0:
        return state, jnp.zeros((0,), jnp.int32)
    j, matched = _phase1(state.centroids, state.counts, state.n, feats,
                         jnp.float32(threshold))
    # focuslint: disable=host-sync -- the one designed per-batch fetch:
    # (j, matched) gate which rows the host fold touches
    j_np, matched_np = jax.device_get((j, matched))
    state = _fold_matched(state, feats, j, matched)

    ids = j_np.astype(np.int32)
    unmatched_idx = np.nonzero(~matched_np)[0]
    U = len(unmatched_idx)
    if U:
        P = _pad_bucket(U)
        gather = np.zeros((P,), np.int64)
        gather[:U] = unmatched_idx
        sub = feats[jnp.asarray(gather)]
        valid = jnp.asarray(np.arange(P) < U)
        state, sub_ids = _scan_unmatched(state, sub, valid,
                                         jnp.float32(threshold))
        # focuslint: disable=host-sync -- same designed sync boundary:
        # winner ids feed the host-side fold
        ids[unmatched_idx] = np.asarray(sub_ids)[:U]
    return state, jnp.asarray(ids)


CLUSTER_FNS = {
    "scan": cluster_scan,
    "batched": cluster_batched,
    "fused": cluster_fused,
}


# ---------------------------------------------------------------------------
# Host-side eviction helper (keeps cluster count at M, paper §4.2)
# ---------------------------------------------------------------------------

def evict_smallest(state: ClusterState, frac: float = 0.25):
    """Evict the smallest ``frac`` of live clusters; returns
    (compacted_state, evicted_slot_ids, slot_remap (M,) old->new or -1)."""
    centroids = np.asarray(state.centroids)
    counts = np.asarray(state.counts)
    n = int(state.n)
    M = centroids.shape[0]
    if n == 0:
        return state, np.zeros((0,), np.int32), np.full((M,), -1, np.int32)
    k = max(1, int(n * frac))
    order = np.argsort(counts[:n])          # smallest first
    evicted = np.sort(order[:k]).astype(np.int32)
    keep = np.sort(order[k:]).astype(np.int32)
    remap = np.full((M,), -1, np.int32)
    remap[keep] = np.arange(len(keep), dtype=np.int32)
    new_centroids = np.zeros_like(centroids)
    new_counts = np.zeros_like(counts)
    new_centroids[: len(keep)] = centroids[keep]
    new_counts[: len(keep)] = counts[keep]
    new_state = ClusterState(jnp.asarray(new_centroids),
                             jnp.asarray(new_counts),
                             jnp.asarray(len(keep), jnp.int32))
    return new_state, evicted, remap
