"""Time-sharded archive of top-K indexes with cross-shard query fan-out.

Focus's headline scenario is "after the fact" queries over *many days* of
recorded video (paper §1, §5), but a single in-memory ``TopKIndex`` grows
without bound over a long stream and a query must hold the whole archive's
centroids and rep-crops resident. Following the partitioned-repository
shape of zero-streaming cameras / ExSample, the archive here is a sequence
of **time shards**: ``StreamingIngestor`` seals its live index at an
objects-per-shard or frame-window boundary (through ``TopKIndex.save`` —
v4 quantized columnar by default), resets clustering state, and keeps
feeding. Each
sealed shard is byte-identical to a one-shot ``ingest()`` of its window —
the rollover invariant, pinned by ``tests/test_archive.py``.

* ``ShardCatalog`` — the JSON manifest (shard id, frame window, object /
  cluster counts, object-id base, on-disk bytes, paths) plus
  ``seal``/``load_shard``; the manifest is written atomically (temp file +
  ``os.replace``), so a crash mid-seal leaves at worst orphan shard files
  that no manifest references.
* ``LazyShardIndex`` — the query-side view of a v4 quantized shard
  (DESIGN.md §14): per-column ``.npy`` files opened ``mmap_mode="r"``,
  ranks computed by the fused ``dequant_topk`` kernel straight off the
  uint8 mean-prob rows, rep-crops dequantized per gathered row only when
  a cluster actually reaches the GT pass.
* ``ShardLoader`` — LRU-bounded loader whose capacity is **bytes
  resident** (materialized heap per shard), with a deprecated shard-count
  mode for old callers; loads/hits/evictions are counted.
* ``ArchiveQueryEngine`` — extends the PR-2 batching one level up:
  ``query_many`` fans ``lookup`` out across all shards, unions the
  **uncached** rep crops across all shards *and* all queries into one
  bucket-padded GT-CNN pass, and merges frame results per query. The
  GT-label cache is keyed ``(shard, cid, version)`` (stored row-aligned
  per shard, so the probe is one vectorized compare) and survives shard
  eviction *and* live-shard rollover: the live shard's id becomes the
  sealed shard's id and ``versions`` round-trip through ``save``, so a
  warm engine re-verifies nothing after a rollover. Query cost therefore
  scales with uncached candidates, not archive size.
"""
from __future__ import annotations

import json
import os
import time
from collections import OrderedDict
from dataclasses import asdict, dataclass
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.core.engine import (classify_crops, grow_row_cache,
                               normalize_kx, probe_row_cache)
from repro.core.index import (INDEX_FORMAT, PROB_GLOBAL_SCALE, ClassMap,
                              TopKIndex, _resolve_kx, dequant_crops,
                              saved_nbytes)
from repro.kernels import ops as kops

CATALOG_NAME = "catalog.json"


@dataclass
class ShardMeta:
    """One sealed shard in the catalog manifest."""
    shard_id: int
    frame_lo: int                # first frame fed into the shard
    frame_hi: int                # last frame fed into the shard
    n_objects: int               # members in the shard index (folds+attaches)
    n_clusters: int
    obj_base: int                # global arrival position of the shard's
                                 # first object (ids inside are shard-local)
    path: str                    # basename under the catalog root
    n_bytes: int = 0             # on-disk bytes of the shard's index files
                                 # (0 in pre-v4 manifests)


class ShardCatalog:
    """JSON manifest of sealed shards under one archive directory.

    ``<root>/catalog.json`` lists the shards in time order; each shard's
    index lives at ``<root>/<path>.*`` (v4 quantized per-column ``.npy``
    by default; any ``TopKIndex`` format loads).
    """

    FORMAT = 1

    def __init__(self, root: str):
        self.root = root
        self.shards: List[ShardMeta] = []

    @classmethod
    def open(cls, root: str) -> "ShardCatalog":
        """Load the manifest at ``root`` (an empty catalog if absent)."""
        cat = cls(root)
        manifest = os.path.join(root, CATALOG_NAME)
        if os.path.exists(manifest):
            with open(manifest) as f:
                data = json.load(f)
            cat.shards = [ShardMeta(**m) for m in data["shards"]]
        return cat

    def save(self):
        """Atomically rewrite the manifest: the new contents go to a temp
        file that ``os.replace`` swaps in, so a crash mid-write can never
        leave a truncated/corrupt ``catalog.json`` — readers see either
        the old manifest or the new one."""
        os.makedirs(self.root, exist_ok=True)
        final = os.path.join(self.root, CATALOG_NAME)
        tmp = final + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"format": self.FORMAT,
                       "shards": [asdict(m) for m in self.shards]}, f,
                      indent=1)
        os.replace(tmp, final)

    def next_shard_id(self) -> int:
        return self.shards[-1].shard_id + 1 if self.shards else 0

    def path_of(self, shard_id: int) -> str:
        for m in self.shards:
            if m.shard_id == shard_id:
                return os.path.join(self.root, m.path)
        raise KeyError(f"unknown shard id {shard_id}")

    def seal(self, index: TopKIndex, frame_lo: int, frame_hi: int,
             obj_base: int, *, format: int = INDEX_FORMAT) -> ShardMeta:
        """Persist ``index`` as the next shard and append it to the
        manifest. The caller (``StreamingIngestor._seal_shard``) guarantees
        the index is final — sealed shards are immutable. Shard files are
        written before the manifest references them; if the manifest write
        fails, the in-memory shard list is rolled back so a retry reseals
        under the same id (overwriting the orphan files)."""
        sid = self.next_shard_id()
        name = f"shard_{sid:05d}"
        os.makedirs(self.root, exist_ok=True)
        prefix = os.path.join(self.root, name)
        index.save(prefix, format=format)
        meta = ShardMeta(shard_id=sid, frame_lo=int(frame_lo),
                         frame_hi=int(frame_hi),
                         n_objects=index.n_objects,
                         n_clusters=index.n_clusters,
                         obj_base=int(obj_base), path=name,
                         n_bytes=saved_nbytes(prefix))
        self.shards.append(meta)
        try:
            self.save()
        except BaseException:
            self.shards.pop()
            raise
        return meta

    def load_shard(self, shard_id: int) -> TopKIndex:
        """Eagerly load a shard as a full ``TopKIndex`` (any format)."""
        return TopKIndex.load(self.path_of(shard_id))

    def __len__(self) -> int:
        return len(self.shards)

    def __iter__(self) -> Iterator[ShardMeta]:
        return iter(self.shards)


class _LazyCropColumn:
    """Fancy-index view over the mmap'd uint8 rep-crop column: dequantizes
    only the gathered rows (the GT pass touches a handful of uncached
    clusters; the crop file — the bulk of a shard — is never read whole)."""

    def __init__(self, store: "_LazyStore"):
        self._store = store
        self._qparams: Optional[np.ndarray] = None

    def __getitem__(self, rows) -> np.ndarray:
        if self._qparams is None:
            self._qparams = np.asarray(self._store._col("crop_qparams"),
                                       np.float32)
        q = self._store._col("rep_crops_q")
        return dequant_crops(np.asarray(q[rows]), self._qparams)


class _LazyStore:
    """Read-side ``ClusterStore`` facade over a v4 shard's mmap'd columns.

    Exposes exactly the surface ``ArchiveQueryEngine`` reads — ``n_rows``,
    ``versions``/``first_objs`` (mmap), ``rows_of``, ``frames_of_each``,
    ``rep_crops[rows]``, ``_cid_to_row`` — materializing only small
    derived caches (cid sorter, member/frame CSR) on first use."""

    def __init__(self, prefix: str, meta: dict):
        self._prefix = prefix
        self.n_rows = int(meta["n_rows"])
        self._cols: Dict[str, np.ndarray] = {}
        self._rc64: Optional[np.ndarray] = None
        self._sorter: Optional[np.ndarray] = None
        self._csr = None
        self._cid_map: Optional[Dict[int, int]] = None
        self.rep_crops = _LazyCropColumn(self)

    def _col(self, name: str) -> np.ndarray:
        a = self._cols.get(name)
        if a is None:
            a = np.load(self._prefix + f".{name}.npy", mmap_mode="r")
            self._cols[name] = a
        return a

    @property
    def versions(self) -> np.ndarray:
        return self._col("versions")

    @property
    def first_objs(self) -> np.ndarray:
        return self._col("first_objs")

    @property
    def row_cids(self) -> np.ndarray:
        return self._col("row_cids")

    @property
    def counts(self) -> np.ndarray:
        return self._col("counts")

    def _row_cids64(self) -> np.ndarray:
        if self._rc64 is None:
            self._rc64 = np.asarray(self._col("row_cids"), np.int64)
        return self._rc64

    @property
    def _cid_to_row(self) -> Dict[int, int]:
        if self._cid_map is None:
            self._cid_map = {int(c): r for r, c in
                             enumerate(self._row_cids64().tolist())}
        return self._cid_map

    def rows_of(self, cids) -> np.ndarray:
        """Vectorized cid -> row map; raises KeyError on unknown cids
        (the ``ClusterStore.rows_of`` contract)."""
        cids = np.asarray(cids, np.int64)
        if len(cids) == 0:
            return np.zeros((0,), np.int64)
        if self.n_rows == 0:
            raise KeyError(f"unknown cluster ids: {cids.tolist()[:5]}")
        rc = self._row_cids64()
        if self._sorter is None:
            self._sorter = np.argsort(rc, kind="stable")
        pos = np.searchsorted(rc, cids, sorter=self._sorter)
        rows = self._sorter[np.minimum(pos, self.n_rows - 1)]
        bad = rc[rows] != cids
        if bad.any():
            raise KeyError(f"unknown cluster ids: "
                           f"{np.unique(cids[bad]).tolist()[:5]}")
        return rows

    def _build_csr(self):
        """CSR over the saved member/frame logs — fold entries (file
        order) then attach entries (already canonical (obj, frame) order
        on disk), matching ``ClusterStore._build_csr`` exactly."""
        if self._csr is None:
            log_cids = np.asarray(self._col("log_cids"), np.int64)
            att_cids = np.asarray(self._col("att_cids"), np.int64)
            rows = np.concatenate([self.rows_of(log_cids),
                                   self.rows_of(att_cids)])
            objs = np.concatenate([
                np.asarray(self._col("log_objs"), np.int64),
                np.asarray(self._col("att_objs"), np.int64)])
            frames = np.concatenate([
                np.asarray(self._col("log_frames"), np.int64),
                np.asarray(self._col("att_frames"), np.int64)])
            order = np.argsort(rows, kind="stable")
            counts = np.bincount(rows, minlength=self.n_rows)
            indptr = np.zeros(self.n_rows + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            self._csr = (order, indptr, objs, frames)
        return self._csr

    def frames_of_rows(self, rows: np.ndarray) -> np.ndarray:
        order, indptr, _, frames = self._build_csr()
        if len(rows) == 0:
            return np.array([], np.int64)
        sel = np.concatenate([order[indptr[r]:indptr[r + 1]] for r in rows])
        return np.unique(frames[sel]).astype(np.int64)

    def frames_of_each(self, rows: np.ndarray) -> List[np.ndarray]:
        order, indptr, _, frames = self._build_csr()
        return [np.unique(frames[order[indptr[r]:indptr[r + 1]]]
                          ).astype(np.int64) for r in rows]

    def cache_nbytes(self) -> int:
        """Heap bytes of materialized caches. Mapped column pages are NOT
        counted: they belong to the OS page cache and are reclaimed under
        memory pressure without the loader's help."""
        import sys
        total = 0
        for a in (self._rc64, self._sorter):
            if a is not None:
                total += a.nbytes
        if self._csr is not None:
            total += sum(int(x.nbytes) for x in self._csr)
        if self._cid_map is not None:
            total += sys.getsizeof(self._cid_map)
        return total


class LazyShardIndex:
    """Query-side view of a v4 quantized shard (DESIGN.md §14).

    Duck-types the slice of ``TopKIndex`` that ``ArchiveQueryEngine``
    touches. ``lookup`` ranks the uint8 mean-prob rows with the fused
    ``dequant_topk`` kernel — the per-row scale is applied in-kernel, so
    no fp32 probability matrix is ever materialized — and caches the
    (M, K) top-k ids for the shard's residency. Because the kernel, the
    eager loader, and ``TopKIndex._rank_rows`` share one dequant op order
    and one tie rule (lowest class id), lazy answers are byte-identical
    to eagerly loading the same shard."""

    def __init__(self, prefix: str, meta: dict):
        self._prefix = prefix
        self.meta = meta
        self.K = int(meta["K"])
        self.n_local_classes = int(meta["n_local_classes"])
        self.class_map = (ClassMap(np.array(meta["class_map"]))
                          if meta["class_map"] is not None else None)
        self.store = _LazyStore(prefix, meta)
        self._topk_ids: Optional[np.ndarray] = None

    @property
    def n_clusters(self) -> int:
        return self.store.n_rows

    @property
    def n_objects(self) -> int:
        return int(np.asarray(self.store.counts, np.int64).sum())

    def _rank_ids(self) -> np.ndarray:
        if self._topk_ids is None:
            q = self.store._col("mean_probs_q")
            M, C = q.shape
            if M == 0 or C == 0:
                self._topk_ids = np.zeros((M, 0), np.int32)
            else:
                scales = np.asarray(self.store._col("prob_scales"),
                                    np.float32)
                _, ids = kops.dequant_topk(
                    np.asarray(q), scales, min(self.K, C),
                    global_scale=PROB_GLOBAL_SCALE)
                # focuslint: disable=host-sync -- designed once-per-shard
                # boundary: rank ids are fetched a single time on first
                # lookup and cached for the shard's resident lifetime
                self._topk_ids = np.asarray(ids)
        return self._topk_ids

    def lookup(self, global_class: int,
               Kx: Optional[int] = None) -> List[int]:
        """Cluster ids whose top-Kx (local) classes include the queried
        class — same contract and validation as ``TopKIndex.lookup``."""
        Kx = _resolve_kx(Kx, self.K)
        local = (self.class_map.to_local(global_class)
                 if self.class_map is not None else global_class)
        ids = self._rank_ids()
        n_classes = (self.store._col("mean_probs_q").shape[1]
                     if self.store.n_rows else 0)
        if ids.size == 0 or not 0 <= local < n_classes:
            return []
        kx = min(Kx, ids.shape[1])
        rows = np.nonzero((ids[:, :kx] == local).any(axis=1))[0]
        return self.store._row_cids64()[rows].tolist()

    def frames_of(self, cids: Sequence[int]) -> np.ndarray:
        if len(cids) == 0:
            return np.array([], np.int64)
        return self.store.frames_of_rows(self.store.rows_of(cids))

    def rep_crops(self, cids: Sequence[int]) -> np.ndarray:
        return self.store.rep_crops[self.store.rows_of(cids)]

    @property
    def nbytes(self) -> int:
        """Materialized heap bytes (rank-id cache + store caches) — the
        resident-size unit for the bytes-bounded ``ShardLoader``."""
        total = self.store.cache_nbytes()
        if self._topk_ids is not None:
            total += self._topk_ids.nbytes
        return total


DEFAULT_CAPACITY_BYTES = 256 << 20      # 256 MiB of materialized shard state


class ShardLoader:
    """LRU-bounded shard index loader whose capacity is **bytes resident**.

    ``capacity_bytes`` bounds the summed heap footprint of resident shard
    indexes (``TopKIndex.nbytes`` for eagerly loaded formats <= 3;
    ``LazyShardIndex.nbytes`` — materialized caches only, mmap pages are
    the OS's — for v4). The bound is re-checked on every ``get`` because a
    lazy shard's footprint grows as its rank/CSR caches build; the most
    recently used shard is never evicted, even when it alone exceeds the
    budget. Reloads are counted (``n_loads`` / ``n_hits`` /
    ``n_evictions``) and ``resident_bytes`` reports current residency.

    ``capacity_shards`` (or the deprecated positional-era alias
    ``capacity=``) instead bounds the resident *count* — the pre-v4
    behaviour, kept so existing callers and benchmarks don't break. New
    code should pass ``capacity_bytes``; the count mode will go away once
    callers migrate. Exactly one bound applies: passing both is an error,
    passing neither defaults to ``DEFAULT_CAPACITY_BYTES``.
    """

    def __init__(self, catalog: ShardCatalog,
                 capacity_bytes: Optional[int] = None, *,
                 capacity_shards: Optional[int] = None,
                 capacity: Optional[int] = None):
        if capacity is not None:
            if capacity_shards is not None:
                raise ValueError(
                    "pass capacity_shards or the deprecated capacity "
                    "alias, not both")
            capacity_shards = capacity
        if capacity_bytes is not None and capacity_shards is not None:
            raise ValueError(
                "capacity_bytes and capacity_shards are mutually "
                "exclusive bounds")
        if capacity_bytes is None and capacity_shards is None:
            capacity_bytes = DEFAULT_CAPACITY_BYTES
        if capacity_shards is not None and capacity_shards < 1:
            raise ValueError(
                f"capacity must be >= 1 shard, got {capacity_shards}")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError(
                f"capacity_bytes must be >= 1, got {capacity_bytes}")
        self.catalog = catalog
        self.capacity_bytes = capacity_bytes
        self.capacity_shards = capacity_shards
        self._lru: "OrderedDict[int, TopKIndex]" = OrderedDict()
        self.n_loads = 0
        self.n_hits = 0
        self.n_evictions = 0

    @property
    def resident_bytes(self) -> int:
        """Summed heap bytes of resident shard indexes right now."""
        return sum(int(ix.nbytes) for ix in self._lru.values())

    def _over_budget(self) -> bool:
        if self.capacity_shards is not None:
            return len(self._lru) > self.capacity_shards
        return self.resident_bytes > self.capacity_bytes

    def _load(self, shard_id: int):
        prefix = self.catalog.path_of(shard_id)
        with open(prefix + ".json") as f:
            meta = json.load(f)
        if meta.get("format", 1) >= 4:
            return LazyShardIndex(prefix, meta)
        return TopKIndex.load(prefix)

    def get(self, shard_id: int) -> TopKIndex:
        idx = self._lru.get(shard_id)
        if idx is not None:
            self._lru.move_to_end(shard_id)
            self.n_hits += 1
        else:
            idx = self._load(shard_id)
            self.n_loads += 1
            self._lru[shard_id] = idx
        while len(self._lru) > 1 and self._over_budget():
            self._lru.popitem(last=False)
            self.n_evictions += 1
        return idx

    def __len__(self) -> int:
        return len(self._lru)


@dataclass
class ArchiveQueryResult:
    """Per-query result of an archive fan-out (mirrors ``QueryResult``;
    matched clusters are ``(shard_id, cid)`` pairs)."""
    queried_class: int
    frames: np.ndarray                       # union over shards, sorted
    matched: List[Tuple[int, int]]
    n_candidate_clusters: int                # summed over shards
    n_gt_invocations: int                    # fresh verdicts charged here
    gt_flops: float
    wall_s: float


@dataclass
class ArchiveBatchStats:
    """Accounting for one ``ArchiveQueryEngine.query_many`` call. Field
    names mirror ``BatchQueryStats`` so drivers can report either."""
    n_queries: int
    n_shards: int
    n_candidates: int            # sum over (query, shard) pairs
    n_unique_candidates: int     # after per-shard cross-query union
    n_cache_hits: int
    n_gt_invocations: int        # real crops classified in this call
    n_gt_batches: int            # gt_apply launches (the "one pass" gate)
    gt_flops: float
    wall_s: float
    n_shard_loads: int           # shards read from disk during this call
    n_shard_evictions: int


@dataclass
class ArchiveStats:
    """Cumulative counters over the archive engine's lifetime, including
    the loader's residency (mirrored after every query/prefetch so one
    snapshot serves benchmark reports and the serve summary table)."""
    n_queries: int = 0
    n_candidates: int = 0
    n_cache_hits: int = 0
    n_gt_invocations: int = 0
    gt_flops: float = 0.0
    n_shard_loads: int = 0       # cold shard reads over the lifetime
    n_shard_hits: int = 0        # LRU hits over the lifetime
    n_shard_evictions: int = 0
    resident_bytes: int = 0      # loader heap residency at last snapshot

    @property
    def shard_hit_rate(self) -> float:
        total = self.n_shard_loads + self.n_shard_hits
        return self.n_shard_hits / total if total else 0.0


class ArchiveQueryEngine:
    """Serves class queries against a time-sharded archive, classifying
    each (shard, centroid) with the GT-CNN at most once per version.

    ``ingestor`` (optional) is a live ``StreamingIngestor`` whose
    un-sealed index is queried as the newest shard; its eventual shard id
    is ``catalog.next_shard_id()``, so label-cache entries survive the
    rollover unchanged. Exactly one of ``gt_apply`` / ``oracle_labels``
    must be given (oracle labels are indexed by ``obj_base`` + the
    cluster's shard-local first member).
    """

    def __init__(self, catalog: ShardCatalog,
                 gt_apply: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 gt_flops_per_image: float = 0.0,
                 batch_size: int = 256, batch_pad: int = 64,
                 oracle_labels: Optional[np.ndarray] = None,
                 capacity: Optional[int] = None,
                 capacity_bytes: Optional[int] = None, ingestor=None):
        if (gt_apply is None) == (oracle_labels is None):
            raise ValueError(
                "exactly one of gt_apply / oracle_labels must be provided")
        self.catalog = catalog
        # capacity= keeps the pre-v4 shard-count bound for existing
        # callers; capacity_bytes= is the bytes-resident bound (neither
        # given -> the loader's byte default)
        self.loader = ShardLoader(catalog, capacity_bytes=capacity_bytes,
                                  capacity_shards=capacity)
        self.gt_apply = gt_apply
        self.gt_flops_per_image = gt_flops_per_image
        self.batch_size = batch_size
        self.batch_pad = batch_pad
        self.oracle_labels = (np.asarray(oracle_labels, np.int64)
                              if oracle_labels is not None else None)
        self.ingestor = ingestor
        # per-shard row-aligned GT-label cache: shard id -> (versions,
        # labels). Row order is deterministic under save/load, so entries
        # survive LRU eviction and live-shard sealing; a mismatch between
        # the cached version and the store's is a stale entry.
        self._cache: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.stats = ArchiveStats()

    # -- shard plumbing --------------------------------------------------------

    def _iter_shards(self):
        """(shard_id, index, obj_base) over sealed shards in time order,
        then the live shard (if any and non-empty)."""
        for m in self.catalog.shards:
            yield m.shard_id, self.loader.get(m.shard_id), m.obj_base
        if self.ingestor is not None:
            live = self.ingestor.index
            if live is not None and live.n_clusters:
                yield (self.catalog.next_shard_id(), live,
                       self.ingestor.shard_obj_base)

    def _sync_loader_stats(self):
        """Mirror the loader's residency counters into ``stats`` so one
        snapshot reports everything (satellite of DESIGN.md §14)."""
        self.stats.n_shard_loads = self.loader.n_loads
        self.stats.n_shard_hits = self.loader.n_hits
        self.stats.n_shard_evictions = self.loader.n_evictions
        self.stats.resident_bytes = self.loader.resident_bytes

    def _shard_cache(self, shard_id: int, n_rows: int):
        vers, labels = self._cache.get(shard_id,
                                       (np.full(0, -1, np.int64),
                                        np.zeros(0, np.int64)))
        vers, labels = grow_row_cache(vers, labels, n_rows)
        self._cache[shard_id] = (vers, labels)
        return vers, labels

    def cached_label(self, shard_id: int, cid: int) -> Optional[int]:
        """The cached verdict for ``(shard, cid)`` if still valid. A
        read-only probe: validates against the live index or an already
        resident shard, and returns None (rather than pulling a cold
        shard through the LRU, evicting a hot one) when the shard is not
        loaded."""
        ent = self._cache.get(int(shard_id))
        if ent is None:
            return None
        if self.ingestor is not None \
                and shard_id == self.catalog.next_shard_id():
            idx = self.ingestor.index
        else:
            idx = self.loader._lru.get(shard_id)     # resident shards only
        if idx is None:
            return None
        row = idx.store._cid_to_row.get(int(cid))
        if row is None or row >= len(ent[0]):
            return None
        if int(ent[0][row]) != int(idx.store.versions[row]):
            return None
        return int(ent[1][row])

    # -- classification --------------------------------------------------------

    def _classify_crops(self, crops: np.ndarray) -> Tuple[np.ndarray, int]:
        """One bucket-padded GT pass over ``crops``; returns (labels,
        gt_apply launches)."""
        return classify_crops(self.gt_apply, crops, self.batch_size,
                              self.batch_pad)

    def _verify_shard(self, shard_id: int, index: TopKIndex,
                      obj_base: int, cids: np.ndarray) -> int:
        """Ensure verdicts for ``cids`` of one shard are cached (prefetch
        path — runs its own GT pass). Returns fresh classifications."""
        cids = np.unique(np.asarray(cids, np.int64))
        if len(cids) == 0:
            return 0
        s = index.store
        rows = s.rows_of(cids)
        versions = s.versions[rows]
        vers, labels = self._shard_cache(shard_id, s.n_rows)
        _, _, miss = probe_row_cache(vers, labels, rows, versions)
        if len(miss) == 0:
            return 0
        mrows = rows[miss]
        if self.oracle_labels is not None:
            fresh = self.oracle_labels[s.first_objs[mrows] + obj_base]
        else:
            fresh, _ = self._classify_crops(s.rep_crops[mrows])
        vers[mrows] = versions[miss]
        labels[mrows] = fresh
        self.stats.n_gt_invocations += len(miss)
        self.stats.gt_flops += len(miss) * self.gt_flops_per_image
        return len(miss)

    def prefetch(self, delta_or_cids) -> int:
        """Warm the label cache ahead of the next query round.

        Accepts either a streaming ``IngestDelta`` — live ``touched_cids``
        plus ``touched_sealed`` ``(shard, cid)`` pairs from rollovers since
        the last flush — or a plain cid iterable for the live shard.
        Returns the number of fresh classifications."""
        touched_live = getattr(delta_or_cids, "touched_cids", None)
        touched_sealed = getattr(delta_or_cids, "touched_sealed", ())
        if touched_live is None:
            touched_live = list(delta_or_cids)
        n = 0
        by_shard: Dict[int, List[int]] = {}
        for sid, cid in touched_sealed:
            by_shard.setdefault(int(sid), []).append(int(cid))
        for m in self.catalog.shards:
            if m.shard_id in by_shard:
                n += self._verify_shard(
                    m.shard_id, self.loader.get(m.shard_id), m.obj_base,
                    np.asarray(by_shard[m.shard_id], np.int64))
        if len(touched_live) and self.ingestor is not None \
                and self.ingestor.index is not None:
            n += self._verify_shard(
                self.catalog.next_shard_id(), self.ingestor.index,
                self.ingestor.shard_obj_base,
                np.asarray(list(touched_live), np.int64))
        self._sync_loader_stats()
        return n

    # -- queries ---------------------------------------------------------------

    def query_many(self, classes: Sequence[int],
                   Kx: Union[None, int, Sequence[Optional[int]]] = None,
                   ) -> Tuple[List[ArchiveQueryResult], ArchiveBatchStats]:
        """Serve a query batch across every shard with one GT-CNN pass.

        Per shard: fan out ``lookup`` per query, union candidates across
        the batch, probe the ``(shard, cid, version)`` cache with one
        vectorized compare. The misses of *all shards and all queries* are
        then classified in a single bucket-padded GT pass and scattered
        back; per-query frame sets are the union over shards. Answers are
        identical to running a per-shard ``QueryEngine`` and unioning
        (pinned by ``tests/test_archive.py`` and the
        ``benchmarks/archive_bench.py`` gate).
        """
        t0 = time.perf_counter()
        loads0, ev0 = self.loader.n_loads, self.loader.n_evictions
        classes = [int(c) for c in classes]
        Kxs = normalize_kx(Kx, len(classes))

        # fan-out + cache probe, collecting misses across shards. Each
        # entry detaches from its shard index (candidate frames gathered
        # eagerly, miss crops copied), so at most one shard is resident
        # beyond the loader's LRU capacity at any point in the call.
        entries = []          # (sid, cand, union, labels, frames_each)
        miss_crops: List[np.ndarray] = []
        # (entry idx, miss positions, their rows, their versions)
        miss_refs: List[Tuple[int, np.ndarray, np.ndarray, np.ndarray]] = []
        miss_keys: List[Tuple[int, int]] = []       # (sid, cid) fresh here
        n_cand = n_unique = n_hits = n_gt = n_batches = 0
        for sid, idx, obj_base in self._iter_shards():
            cand = [np.asarray(idx.lookup(c, k), np.int64)
                    for c, k in zip(classes, Kxs)]
            n_cand += int(sum(len(c) for c in cand))
            union = (np.unique(np.concatenate(cand)) if cand
                     else np.zeros((0,), np.int64))
            if len(union) == 0:
                entries.append((sid, cand, union, np.zeros(0, np.int64),
                                []))
                continue
            s = idx.store
            rows = s.rows_of(union)
            versions = s.versions[rows]
            vers, cached = self._shard_cache(sid, s.n_rows)
            hit, labels, miss = probe_row_cache(vers, cached, rows,
                                                versions)
            n_unique += len(union)
            n_hits += int(hit.sum())
            if len(miss):
                mrows = rows[miss]
                miss_keys.extend((sid, int(c)) for c in union[miss])
                if self.oracle_labels is not None:
                    fresh = self.oracle_labels[s.first_objs[mrows]
                                               + obj_base]
                    labels[miss] = fresh
                    vers[mrows] = versions[miss]
                    cached[mrows] = fresh
                    n_gt += len(miss)
                    hit = np.ones(len(union), bool)   # all labels known
                else:
                    # defer: one GT pass over all shards' misses below
                    miss_crops.append(s.rep_crops[mrows])
                    miss_refs.append((len(entries), miss, mrows,
                                      versions[miss]))
            # gather frames only where they can be returned: rows whose
            # (known) label matches a queried class, plus every miss —
            # the bulk of a warm round's candidates match none of the
            # queried classes and are skipped entirely
            need = ~hit | np.isin(labels, np.asarray(classes, np.int64))
            frames_each: List[Optional[np.ndarray]] = [None] * len(union)
            for p, fr in zip(np.nonzero(need)[0].tolist(),
                             idx.store.frames_of_each(rows[need])):
                frames_each[p] = fr
            entries.append((sid, cand, union, labels, frames_each))

        if miss_crops:
            fresh_all, n_batches = self._classify_crops(
                np.concatenate(miss_crops))
            n_gt += len(fresh_all)
            off = 0
            for entry_i, miss, mrows, mvers in miss_refs:
                sid, _, _, labels, _ = entries[entry_i]
                fresh = fresh_all[off:off + len(miss)]
                off += len(miss)
                labels[miss] = fresh
                vers, cached = self._shard_cache(sid, 0)
                vers[mrows] = mvers
                cached[mrows] = fresh

        # per-query scatter + frame merge across shards
        results = []
        uncharged = set(miss_keys)
        for qi, cls in enumerate(classes):
            matched_all: List[Tuple[int, int]] = []
            frames_parts: List[np.ndarray] = []
            n_cand_q = 0
            fresh_q = 0
            for sid, cand, union, labels, frames_each in entries:
                cq = cand[qi]
                n_cand_q += len(cq)
                if len(cq) == 0:
                    continue
                pos = np.searchsorted(union, cq)
                mask = labels[pos] == cls
                for c in cq.tolist():
                    if (sid, c) in uncharged:
                        uncharged.discard((sid, c))
                        fresh_q += 1
                if mask.any():
                    matched_all.extend((sid, int(c))
                                       for c in cq[mask].tolist())
                    frames_parts.extend(frames_each[p]
                                        for p in pos[mask].tolist())
            frames = (np.unique(np.concatenate(frames_parts))
                      if frames_parts else np.array([], np.int64))
            results.append(ArchiveQueryResult(
                queried_class=cls, frames=frames, matched=matched_all,
                n_candidate_clusters=n_cand_q, n_gt_invocations=fresh_q,
                gt_flops=fresh_q * self.gt_flops_per_image, wall_s=0.0))

        wall = time.perf_counter() - t0
        per_q = wall / max(len(classes), 1)
        for res in results:
            res.wall_s = per_q
        batch = ArchiveBatchStats(
            n_queries=len(classes), n_shards=len(entries),
            n_candidates=n_cand, n_unique_candidates=n_unique,
            n_cache_hits=n_hits, n_gt_invocations=n_gt,
            n_gt_batches=n_batches,
            gt_flops=n_gt * self.gt_flops_per_image, wall_s=wall,
            n_shard_loads=self.loader.n_loads - loads0,
            n_shard_evictions=self.loader.n_evictions - ev0)
        self.stats.n_queries += batch.n_queries
        self.stats.n_candidates += batch.n_candidates
        self.stats.n_cache_hits += n_hits
        self.stats.n_gt_invocations += n_gt
        self.stats.gt_flops += batch.gt_flops
        self._sync_loader_stats()
        return results, batch

    def query(self, global_class: int,
              Kx: Optional[int] = None) -> ArchiveQueryResult:
        results, batch = self.query_many([global_class], Kx)
        res = results[0]
        res.wall_s = batch.wall_s
        return res
