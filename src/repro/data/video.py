"""Synthetic video streams with exact ground truth.

Mirrors the paper's video characteristics (§2.2):
  * a fraction of frames has no moving objects (§2.2.1: one-third to one-half)
  * each stream draws from a limited, stream-specific subset of the global
    class space, with power-law frequencies (§2.2.2: 3-10% of classes cover
    >=95% of objects)
  * objects persist across frames with slowly drifting appearance
    (§2.2.3: duplicate objects with nearly identical features)

Objects are procedurally rendered: each class has a distinct low-frequency
color pattern + oriented grating; instances jitter around the class
prototype; per-frame drift is small. This is learnable by the cheap CNN
family and gives exact generator labels to score the GT-CNN against.

Two access paths:
  * ``frames()``        — full frames for the background-subtraction path
  * ``object_stream()`` — post-detection object crops (the paper's metrics
                          count only GPU classification time, so benchmarks
                          drive this path)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, List, NamedTuple, Optional

import numpy as np


@dataclass(frozen=True)
class StreamConfig:
    name: str
    seed: int = 0
    n_classes: int = 1000          # GT label space (ImageNet-like)
    n_stream_classes: int = 12     # classes that actually occur here
    zipf_a: float = 1.6            # class-frequency skew
    fps: int = 30
    duration_s: int = 120
    frame_res: int = 128
    obj_res: int = 32
    mean_tracks_per_frame: float = 1.2
    frac_empty: float = 0.4        # frames with no moving object
    dwell_s: float = 1.5           # seconds an object stays in view
    appearance_jitter: float = 0.12
    drift: float = 0.02

    @property
    def n_frames(self) -> int:
        return self.fps * self.duration_s


class DetectedObject(NamedTuple):
    frame_id: int
    track_id: int
    crop: np.ndarray          # (obj_res, obj_res, 3) float32 in [0, 1]
    true_class: int           # generator label (global class id)


class Track(NamedTuple):
    track_id: int
    cls: int
    t0: int
    t1: int
    proto: np.ndarray
    x0: float
    y0: float
    vx: float
    vy: float


def _class_proto(cls: int, res: int) -> np.ndarray:
    """Deterministic prototype pattern for a class."""
    rng = np.random.default_rng(cls * 7919 + 13)
    palette = rng.uniform(0.1, 0.9, size=(4, 4, 3))
    base = np.kron(palette, np.ones((res // 4, res // 4, 1)))
    yy, xx = np.mgrid[0:res, 0:res] / res
    theta = (cls % 17) / 17.0 * np.pi
    freq = 3 + (cls % 5)
    grating = 0.25 * np.sin(2 * np.pi * freq *
                            (xx * np.cos(theta) + yy * np.sin(theta)))
    return np.clip(base + grating[..., None], 0.0, 1.0).astype(np.float32)


class VideoStream:
    def __init__(self, cfg: StreamConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # Stream-specific class subset with zipf frequencies (§2.2.2)
        all_classes = np.arange(cfg.n_classes)
        self.rng.shuffle(all_classes)
        self.stream_classes = np.sort(all_classes[: cfg.n_stream_classes])
        w = 1.0 / np.arange(1, cfg.n_stream_classes + 1) ** cfg.zipf_a
        self.class_probs = w / w.sum()
        self._tracks = self._make_tracks()

    def _make_tracks(self) -> List[Track]:
        cfg = self.cfg
        dwell = max(1, int(cfg.dwell_s * cfg.fps))
        # expected live tracks per frame; thin births so ~frac_empty frames
        # see no object at all
        n_frames = cfg.n_frames
        target_births = cfg.mean_tracks_per_frame * n_frames / dwell
        births = self.rng.poisson(target_births / n_frames, size=n_frames)
        # carve out empty stretches
        empty = self.rng.random(n_frames) < cfg.frac_empty
        births[empty] = 0
        tracks = []
        tid = 0
        for t, b in enumerate(births):
            for _ in range(int(b)):
                cls_local = self.rng.choice(len(self.stream_classes),
                                            p=self.class_probs)
                cls = int(self.stream_classes[cls_local])
                proto = _class_proto(cls, cfg.obj_res)
                inst = proto + self.rng.normal(
                    0, cfg.appearance_jitter, proto.shape).astype(np.float32)
                d = int(dwell * self.rng.uniform(0.5, 1.5))
                x0, y0 = self.rng.uniform(0.05, 0.6, size=2)
                vx, vy = self.rng.uniform(-0.3, 0.3, size=2) / cfg.fps
                tracks.append(Track(tid, cls, t, min(t + d, n_frames),
                                    np.clip(inst, 0, 1), x0, y0, vx, vy))
                tid += 1
        return tracks

    # -- fast path: post-detection object crops --------------------------------

    def object_stream(self, max_frames: Optional[int] = None,
                      frame_stride: int = 1) -> Iterator[DetectedObject]:
        """Yields one DetectedObject per (visible track, sampled frame)."""
        cfg = self.cfg
        n = min(cfg.n_frames, max_frames or cfg.n_frames)
        rng = np.random.default_rng(cfg.seed + 1)
        by_frame: List[List[Track]] = [[] for _ in range(n)]
        for tr in self._tracks:
            for t in range(tr.t0, min(tr.t1, n)):
                by_frame[t].append(tr)
        for t in range(0, n, frame_stride):
            for tr in by_frame[t]:
                drift = rng.normal(0, cfg.drift, tr.proto.shape)
                crop = np.clip(tr.proto + drift, 0, 1).astype(np.float32)
                yield DetectedObject(t, tr.track_id, crop, tr.cls)

    def object_chunks(self, chunk_frames: int,
                      max_frames: Optional[int] = None,
                      frame_stride: int = 1) -> Iterator[tuple]:
        """Lazily yield ``(crops, frames, tracks, labels)`` per window of
        ``chunk_frames`` consecutive frames — the feed unit for
        ``core.streaming.StreamingIngestor`` (frames are non-decreasing
        within and across chunks). Concatenating all chunks equals
        ``objects_array`` exactly.
        """
        if chunk_frames <= 0:
            raise ValueError(f"chunk_frames must be positive, "
                             f"got {chunk_frames}")
        r = self.cfg.obj_res
        empty = (np.zeros((0, r, r, 3), np.float32),
                 np.zeros((0,), np.int64), np.zeros((0,), np.int64),
                 np.zeros((0,), np.int64))
        pend: List[DetectedObject] = []
        window_end = chunk_frames

        def pack(objs):
            if not objs:
                return empty
            return (np.stack([o.crop for o in objs]),
                    np.array([o.frame_id for o in objs]),
                    np.array([o.track_id for o in objs]),
                    np.array([o.true_class for o in objs]))

        for obj in self.object_stream(max_frames, frame_stride):
            while obj.frame_id >= window_end:
                yield pack(pend)
                pend = []
                window_end += chunk_frames
            pend.append(obj)
        yield pack(pend)

    def objects_array(self, max_frames: Optional[int] = None,
                      frame_stride: int = 1):
        """Materialize the stream: (crops (N,R,R,3), frames (N,), tracks (N,),
        labels (N,))."""
        objs = list(self.object_stream(max_frames, frame_stride))
        if not objs:
            r = self.cfg.obj_res
            return (np.zeros((0, r, r, 3), np.float32),
                    np.zeros((0,), np.int64), np.zeros((0,), np.int64),
                    np.zeros((0,), np.int64))
        crops = np.stack([o.crop for o in objs])
        frames = np.array([o.frame_id for o in objs])
        tracks = np.array([o.track_id for o in objs])
        labels = np.array([o.true_class for o in objs])
        return crops, frames, tracks, labels

    # -- full-frame path (for background subtraction) --------------------------

    def frames(self, max_frames: Optional[int] = None) -> Iterator[np.ndarray]:
        cfg = self.cfg
        n = min(cfg.n_frames, max_frames or cfg.n_frames)
        rng = np.random.default_rng(cfg.seed + 2)
        bg_rng = np.random.default_rng(cfg.seed + 3)
        bg = bg_rng.uniform(0.2, 0.5, size=(cfg.frame_res, cfg.frame_res, 3)
                            ).astype(np.float32)
        by_frame: List[List[Track]] = [[] for _ in range(n)]
        for tr in self._tracks:
            for t in range(tr.t0, min(tr.t1, n)):
                by_frame[t].append(tr)
        R, r = cfg.frame_res, cfg.obj_res
        for t in range(n):
            frame = bg + rng.normal(0, 0.01, bg.shape).astype(np.float32)
            for tr in by_frame[t]:
                dt = t - tr.t0
                x = tr.x0 + tr.vx * dt
                y = tr.y0 + tr.vy * dt
                xi = int(np.clip(x, 0, 1 - r / R) * R)
                yi = int(np.clip(y, 0, 1 - r / R) * R)
                drift = rng.normal(0, cfg.drift, tr.proto.shape)
                frame[yi:yi + r, xi:xi + r] = np.clip(tr.proto + drift, 0, 1)
            yield np.clip(frame, 0, 1)


# The 13-stream zoo used in benchmarks (traffic / surveillance / news mix,
# mirroring Table 1's busy/normal/rotating/plaza/news variety via different
# class counts, skews and empty fractions).
STREAM_ZOO = [
    StreamConfig("auburn_c", seed=1, n_stream_classes=16, zipf_a=1.3,
                 mean_tracks_per_frame=2.5, frac_empty=0.3),
    StreamConfig("auburn_r", seed=2, n_stream_classes=8, zipf_a=1.9,
                 mean_tracks_per_frame=0.8, frac_empty=0.5),
    StreamConfig("city_a_d", seed=3, n_stream_classes=18, zipf_a=1.3,
                 mean_tracks_per_frame=2.8, frac_empty=0.25),
    StreamConfig("city_a_r", seed=4, n_stream_classes=9, zipf_a=1.8,
                 mean_tracks_per_frame=1.0, frac_empty=0.45),
    StreamConfig("bend", seed=5, n_stream_classes=7, zipf_a=2.0,
                 mean_tracks_per_frame=0.7, frac_empty=0.5),
    StreamConfig("jacksonh", seed=6, n_stream_classes=20, zipf_a=1.2,
                 mean_tracks_per_frame=3.0, frac_empty=0.2),
    StreamConfig("church_st", seed=7, n_stream_classes=14, zipf_a=1.5,
                 mean_tracks_per_frame=1.6, frac_empty=0.35, dwell_s=0.8),
    StreamConfig("lausanne", seed=8, n_stream_classes=8, zipf_a=1.8,
                 mean_tracks_per_frame=1.2, frac_empty=0.4),
    StreamConfig("oxford", seed=9, n_stream_classes=9, zipf_a=1.7,
                 mean_tracks_per_frame=1.0, frac_empty=0.45),
    StreamConfig("sittard", seed=10, n_stream_classes=11, zipf_a=1.6,
                 mean_tracks_per_frame=1.4, frac_empty=0.4),
    StreamConfig("cnn", seed=11, n_stream_classes=24, zipf_a=1.1,
                 mean_tracks_per_frame=2.2, frac_empty=0.2, dwell_s=2.5),
    StreamConfig("foxnews", seed=12, n_stream_classes=22, zipf_a=1.15,
                 mean_tracks_per_frame=2.0, frac_empty=0.2, dwell_s=2.5),
    StreamConfig("msnbc", seed=13, n_stream_classes=26, zipf_a=1.1,
                 mean_tracks_per_frame=2.4, frac_empty=0.2, dwell_s=2.5),
]


def get_stream(name: str, **overrides) -> VideoStream:
    for s in STREAM_ZOO:
        if s.name == name:
            return VideoStream(dataclasses.replace(s, **overrides))
    raise KeyError(name)
