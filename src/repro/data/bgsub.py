"""Background subtraction: exclude frames/regions with no moving objects.

The paper uses OpenCV MOG2 [43, 81]; here an exponential-moving-average
background model + tile-grid connected components (JAX/numpy — no OpenCV in
this container). Same role: both Focus and the strengthened baselines skip
frames with no motion (§6.1).
"""
from __future__ import annotations

from typing import List, NamedTuple, Tuple

import numpy as np


class MotionBox(NamedTuple):
    y0: int
    x0: int
    y1: int
    x1: int


class BackgroundSubtractor:
    def __init__(self, alpha: float = 0.05, threshold: float = 0.08,
                 tile: int = 8, min_tiles: int = 4):
        self.alpha = alpha
        self.threshold = threshold
        self.tile = tile
        self.min_tiles = min_tiles
        self._bg = None

    def __call__(self, frame: np.ndarray) -> List[MotionBox]:
        """frame (H, W, 3) float32 -> motion bounding boxes (possibly [])."""
        if self._bg is None:
            self._bg = frame.copy()
            return []
        diff = np.abs(frame - self._bg).mean(axis=-1)        # (H, W)
        self._bg = (1 - self.alpha) * self._bg + self.alpha * frame
        t = self.tile
        H, W = diff.shape
        ty, tx = H // t, W // t
        tiles = diff[: ty * t, : tx * t].reshape(ty, t, tx, t).mean((1, 3))
        hot = tiles > self.threshold                          # (ty, tx)
        return [b for b in self._components(hot)
                if (b.y1 - b.y0) * (b.x1 - b.x0) >= self.min_tiles * t * t]

    def _components(self, hot: np.ndarray) -> List[MotionBox]:
        """Connected components on the small tile grid (4-neighbor BFS)."""
        t = self.tile
        ty, tx = hot.shape
        seen = np.zeros_like(hot, bool)
        boxes = []
        for i in range(ty):
            for j in range(tx):
                if not hot[i, j] or seen[i, j]:
                    continue
                stack = [(i, j)]
                seen[i, j] = True
                ys, xs = [i], [j]
                while stack:
                    a, b = stack.pop()
                    for da, db in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                        na, nb = a + da, b + db
                        if 0 <= na < ty and 0 <= nb < tx and hot[na, nb] \
                                and not seen[na, nb]:
                            seen[na, nb] = True
                            stack.append((na, nb))
                            ys.append(na)
                            xs.append(nb)
                boxes.append(MotionBox(min(ys) * t, min(xs) * t,
                                       (max(ys) + 1) * t, (max(xs) + 1) * t))
        return boxes


def extract_crops(frame: np.ndarray, boxes: List[MotionBox],
                  obj_res: int) -> np.ndarray:
    """Crop + nearest-resize each motion box to (obj_res, obj_res, 3)."""
    crops = []
    for b in boxes:
        patch = frame[b.y0:b.y1, b.x0:b.x1]
        h, w = patch.shape[:2]
        yi = (np.arange(obj_res) * h // obj_res).clip(0, h - 1)
        xi = (np.arange(obj_res) * w // obj_res).clip(0, w - 1)
        crops.append(patch[yi][:, xi])
    return (np.stack(crops) if crops
            else np.zeros((0, obj_res, obj_res, 3), np.float32))


def pixel_difference(crops_a: np.ndarray, crops_b: np.ndarray,
                     threshold: float = 0.02) -> np.ndarray:
    """Paper §4.2 "Pixel Differencing of Objects": pairwise mean-abs-diff of
    current crops vs. the previous frame's crops; returns for each crop in
    ``crops_a`` the index of a near-identical crop in ``crops_b`` or -1."""
    if len(crops_a) == 0 or len(crops_b) == 0:
        return np.full((len(crops_a),), -1, np.int64)
    a = crops_a.reshape(len(crops_a), -1)
    b = crops_b.reshape(len(crops_b), -1)
    d = np.abs(a[:, None, :] - b[None, :, :]).mean(-1)   # (Na, Nb)
    j = d.argmin(1)
    return np.where(d[np.arange(len(a)), j] < threshold, j, -1)
