"""Background subtraction: exclude frames/regions with no moving objects.

The paper uses OpenCV MOG2 [43, 81]; here an exponential-moving-average
background model + tile-grid connected components (JAX/numpy — no OpenCV in
this container). Same role: both Focus and the strengthened baselines skip
frames with no motion (§6.1).

Two backends share one contract:

  * ``numpy`` — blocked host arithmetic (no (Na, Nb, D) broadcast, no
    per-frame Python BFS);
  * ``kernel`` — the Pallas ``pixel_diff`` / ``frame_gate`` kernels via
    ``repro.kernels.ops``, used automatically when a real accelerator
    backs JAX. On CPU the kernels run in interpret mode, which is slower
    than numpy, so ``auto`` resolves to numpy there.
"""
from __future__ import annotations

from typing import List, NamedTuple

import numpy as np

# pair-elements cap for one numpy diff block: block_rows * Nb * D floats.
# 2**24 floats = 64 MiB fp32 scratch, far below the old (Na, Nb, D) blow-up
# (500 crops x 500 crops x 3072 = 3 GiB).
_BLOCK_ELEMS = 1 << 24


def _kernel_backend() -> bool:
    """True when JAX is backed by a real accelerator (kernels compile
    natively). Interpret-mode Pallas on CPU loses to blocked numpy."""
    try:
        import jax
        return jax.default_backend() != "cpu"
    except Exception:                                # jax unavailable
        return False


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "kernel" if _kernel_backend() else "numpy"
    if backend not in ("numpy", "kernel"):
        raise ValueError(f"backend must be auto|numpy|kernel, got {backend!r}")
    return backend


def match_flat(a: np.ndarray, b: np.ndarray, threshold: float,
               backend: str = "auto") -> np.ndarray:
    """Flattened-crop matcher: a (Na, D), b (Nb, D) -> (Na,) int64.

    ``out[i]`` is the lowest index j minimizing ``mean |a_i - b_j|`` when
    that minimum is STRICTLY below ``threshold`` (a diff exactly at the
    threshold does NOT match), else -1. Shared by ``pixel_difference``
    and the streaming redundancy gate so both paths agree bit-for-bit.
    """
    Na, Nb = len(a), len(b)
    if Na == 0 or Nb == 0:
        return np.full((Na,), -1, np.int64)
    if _resolve_backend(backend) == "kernel":
        from repro.kernels import ops
        m, _ = ops.pixel_match(a, b, threshold)
        # focuslint: disable=host-sync -- gate decision is consumed by
        # host control flow; match_flat returns numpy by contract
        return np.asarray(m).astype(np.int64)
    a = np.ascontiguousarray(a, np.float32)
    b = np.ascontiguousarray(b, np.float32)
    D = a.shape[1]
    rows = max(1, _BLOCK_ELEMS // max(1, Nb * D))
    out = np.empty((Na,), np.int64)
    for i in range(0, Na, rows):
        blk = a[i:i + rows]                          # (r, D)
        # (r, Nb): one block of the pairwise matrix; the (r, Nb, D)
        # broadcast is scratch bounded by _BLOCK_ELEMS, freed per block
        d = np.abs(blk[:, None, :] - b[None, :, :]).mean(-1)
        j = d.argmin(1)
        out[i:i + rows] = np.where(d[np.arange(len(blk)), j] < threshold,
                                   j, -1)
    return out


class MotionBox(NamedTuple):
    y0: int
    x0: int
    y1: int
    x1: int


class BackgroundSubtractor:
    """EMA background model + hot-tile connected components.

    ``backend="auto"`` routes the fused EMA/tile-diff/threshold pass
    through the Pallas ``frame_gate`` kernel when an accelerator is
    available, else blocked numpy — identical outputs either way.
    """

    def __init__(self, alpha: float = 0.05, threshold: float = 0.08,
                 tile: int = 8, min_tiles: int = 4, backend: str = "auto"):
        if tile < 1:
            raise ValueError(f"tile must be >= 1, got {tile}")
        self.alpha = alpha
        self.threshold = threshold
        self.tile = tile
        self.min_tiles = min_tiles
        self.backend = _resolve_backend(backend)
        self._bg = None

    def __call__(self, frame: np.ndarray) -> List[MotionBox]:
        """frame (H, W, 3) float32 -> motion bounding boxes (possibly []).

        Edge cases are defined: the first frame seeds the background and
        yields []; frames smaller than one tile (ty == 0 or tx == 0)
        still update the background but yield []; a constant (all-static)
        stream yields [] on every frame; non-multiple-of-tile resolutions
        label complete tiles only (remainder rows/cols belong to no tile
        but still update the background model).
        """
        if self._bg is None:
            self._bg = np.asarray(frame, np.float32).copy()
            return []
        hot = self._step(np.asarray(frame, np.float32))
        if hot.size == 0 or not hot.any():
            return []
        t = self.tile
        return [b for b in self._components(hot)
                if (b.y1 - b.y0) * (b.x1 - b.x0) >= self.min_tiles * t * t]

    def _step(self, frame: np.ndarray) -> np.ndarray:
        """One EMA + tile-diff pass; updates ``self._bg``, returns hot."""
        t = self.tile
        if self.backend == "kernel":
            from repro.kernels import ops
            new_bg, _, hot = ops.motion_gate(frame, self._bg, self.alpha,
                                             self.threshold, tile=t)
            # focuslint: disable=host-sync -- _bg stays numpy so the
            # kernel and numpy backends share state bit-for-bit
            self._bg = np.asarray(new_bg)
            # focuslint: disable=host-sync -- per-frame gate: hot tiles
            # feed host connected-components
            return np.asarray(hot)
        diff = np.abs(frame - self._bg).mean(axis=-1)        # (H, W)
        self._bg = (1 - self.alpha) * self._bg + self.alpha * frame
        H, W = diff.shape
        ty, tx = H // t, W // t
        if ty == 0 or tx == 0:
            return np.zeros((ty, tx), bool)
        tiles = diff[: ty * t, : tx * t].reshape(ty, t, tx, t).mean((1, 3))
        return tiles > self.threshold                        # (ty, tx)

    def _components(self, hot: np.ndarray) -> List[MotionBox]:
        """Connected components on the tile grid (4-neighbor).

        Vectorized iterative min-label propagation: every hot tile starts
        labeled with its flat index, and each sweep takes the min over
        the 4-neighborhood (cold tiles pinned to a sentinel so they never
        bridge components). Converges in O(grid diameter) whole-grid numpy
        ops instead of a per-tile Python BFS. The surviving label of a
        component is its minimum flat index — its first tile in row-major
        order — so boxes come out in the same order the BFS produced.
        """
        t = self.tile
        ty, tx = hot.shape
        sentinel = ty * tx
        lab = np.where(hot, np.arange(ty * tx).reshape(ty, tx), sentinel)
        while True:
            nxt = lab.copy()
            nxt[1:] = np.minimum(nxt[1:], lab[:-1])
            nxt[:-1] = np.minimum(nxt[:-1], lab[1:])
            nxt[:, 1:] = np.minimum(nxt[:, 1:], lab[:, :-1])
            nxt[:, :-1] = np.minimum(nxt[:, :-1], lab[:, 1:])
            nxt[~hot] = sentinel
            if np.array_equal(nxt, lab):
                break
            lab = nxt
        boxes = []
        for root in np.unique(lab[hot]):
            ys, xs = np.nonzero(lab == root)
            boxes.append(MotionBox(ys.min() * t, xs.min() * t,
                                   (ys.max() + 1) * t, (xs.max() + 1) * t))
        # np.unique sorts by flat index == first-encounter order of the
        # row-major scan, matching the BFS reference's box order
        return boxes

    def _components_bfs(self, hot: np.ndarray) -> List[MotionBox]:
        """Reference 4-neighbor BFS (kept as the test oracle)."""
        t = self.tile
        ty, tx = hot.shape
        seen = np.zeros_like(hot, bool)
        boxes = []
        for i in range(ty):
            for j in range(tx):
                if not hot[i, j] or seen[i, j]:
                    continue
                stack = [(i, j)]
                seen[i, j] = True
                ys, xs = [i], [j]
                while stack:
                    a, b = stack.pop()
                    for da, db in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                        na, nb = a + da, b + db
                        if 0 <= na < ty and 0 <= nb < tx and hot[na, nb] \
                                and not seen[na, nb]:
                            seen[na, nb] = True
                            stack.append((na, nb))
                            ys.append(na)
                            xs.append(nb)
                boxes.append(MotionBox(min(ys) * t, min(xs) * t,
                                       (max(ys) + 1) * t, (max(xs) + 1) * t))
        return boxes


def extract_crops(frame: np.ndarray, boxes: List[MotionBox],
                  obj_res: int) -> np.ndarray:
    """Crop + nearest-resize each motion box to (obj_res, obj_res, 3)."""
    crops = []
    for b in boxes:
        patch = frame[b.y0:b.y1, b.x0:b.x1]
        h, w = patch.shape[:2]
        yi = (np.arange(obj_res) * h // obj_res).clip(0, h - 1)
        xi = (np.arange(obj_res) * w // obj_res).clip(0, w - 1)
        crops.append(patch[yi][:, xi])
    return (np.stack(crops) if crops
            else np.zeros((0, obj_res, obj_res, 3), np.float32))


def pixel_difference(crops_a: np.ndarray, crops_b: np.ndarray,
                     threshold: float = 0.02,
                     backend: str = "auto") -> np.ndarray:
    """Paper §4.2 "Pixel Differencing of Objects": pairwise mean-abs-diff of
    current crops vs. the previous frame's crops; returns for each crop in
    ``crops_a`` the index of a near-identical crop in ``crops_b`` or -1.

    A crop matches only when its best mean-abs-diff is STRICTLY below
    ``threshold`` (``< threshold``, not ``<=``); ties between equally
    close references resolve to the lowest index. The pairwise matrix is
    computed in bounded blocks — the full ``(Na, Nb, D)`` broadcast is
    never materialized on either backend.
    """
    return match_flat(
        np.asarray(crops_a, np.float32).reshape(len(crops_a), -1),
        np.asarray(crops_b, np.float32).reshape(len(crops_b), -1),
        threshold, backend=backend)
