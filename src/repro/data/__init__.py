from repro.data.video import (  # noqa: F401
    STREAM_ZOO,
    DetectedObject,
    StreamConfig,
    VideoStream,
    get_stream,
)
from repro.data.bgsub import (  # noqa: F401
    BackgroundSubtractor,
    extract_crops,
    pixel_difference,
)
