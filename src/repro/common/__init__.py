from repro.common.config import (  # noqa: F401
    CheapCNNConfig,
    DiTConfig,
    DIT_SHAPES,
    EffNetConfig,
    LMConfig,
    LM_SHAPES,
    ShapeCell,
    ViTConfig,
    VISION_SHAPES,
    reduced,
    shapes_for,
)
