"""Configuration dataclasses for all model families and input-shape cells.

Every assigned architecture gets a config module in ``repro.configs`` that
instantiates exactly one of these dataclasses and exports the family's shape
cells. The dry-run, smoke tests and benchmarks all read from this single
source of truth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the dry-run grid.

    kind:
      train    -> lowers train_step            (LM)
      prefill  -> lowers prefill serve_step    (LM)
      decode   -> lowers 1-token decode serve_step with seq_len KV cache (LM)
      long     -> decode with a very long cache (sub-quadratic attn required)
      dit_train/dit_gen -> diffusion train / sampler loop
      cls      -> vision train step
      serve    -> vision inference forward
    """

    name: str
    kind: str
    seq_len: int = 0
    global_batch: int = 0
    img_res: int = 0
    steps: int = 0


# ---------------------------------------------------------------------------
# Family configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                      # per-expert width when moe=True
    vocab_size: int
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    moe_group_size: int = 1024     # GShard dispatch group size (tokens)
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"   # "einsum" (GShard baseline) | "scatter"
    norm: str = "rmsnorm"          # "rmsnorm" | "layernorm" | "nonparametric_ln"
    mlp_act: str = "swiglu"        # "swiglu" | "gelu"
    rope_theta: float = 10000.0
    attention: str = "full"        # "full" | "window"
    window: int = 0                # sliding-window size when attention=="window"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # "nothing" | "dots_nobatch" (see layers)
    scan_layers: bool = True
    act_sharding: str = "auto"      # residual-stream layout: "dp" | "sp" |
                                    # "auto" (sp when seq divides model axis)
    train_microbatches: int = 1     # grad-accumulation chunks per train step
    parallelism: str = "fsdp_tp"    # "fsdp_tp" | "ddp_zero1" (small models:
                                    # replicate params, shard only opt state)
    grad_reduce_dtype: str = "f32"  # wire format of the gradient reduce
    attn_scores_dtype: str = "f32"  # "f32" | "bf16": score matrix precision
                                    # (bf16 halves the S^2 HBM traffic)
    attn_q_chunk: int = 4096        # query-block size: live scores shrink to
                                    # (B, H, q_chunk, S) per block
    prefill_batch_chunks: int = 0   # 0 = auto: serialize the prefill batch
                                    # in halves when d_model*seq is huge

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.moe:
            mlp = self.n_experts * (3 * d * f) + d * self.n_experts
        else:
            n_mat = 3 if self.mlp_act == "swiglu" else 2
            mlp = n_mat * d * f
        norms = 2 * d if self.norm != "nonparametric_ln" else 0
        per_layer = attn + mlp + norms
        emb = v * d
        head = 0 if self.tie_embeddings else v * d
        return self.n_layers * per_layer + emb + head + d

    def n_active_params(self) -> int:
        """Parameters active per token (MoE top-k)."""
        if not self.moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        mlp = self.moe_top_k * (3 * d * f) + d * self.n_experts
        per_layer = attn + mlp + (2 * d if self.norm != "nonparametric_ln" else 0)
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        return self.n_layers * per_layer + emb + head + d


@dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    distill_token: bool = False    # DeiT
    in_channels: int = 3
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"
    scan_layers: bool = True
    serve_pure_dp: bool = False    # serve cells: replicate weights, pad the
                                   # batch to the full chip count, zero
                                   # per-layer collectives

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def n_tokens(self, img_res: Optional[int] = None) -> int:
        res = img_res or self.img_res
        n = (res // self.patch) ** 2 + 1
        return n + (1 if self.distill_token else 0)

    def n_params(self) -> int:
        d, f = self.d_model, self.d_ff
        per_layer = 4 * d * d + 2 * d * f + 4 * d
        patch_embed = self.in_channels * self.patch ** 2 * d + d
        pos = self.n_tokens() * d
        head = d * self.n_classes + self.n_classes
        if self.distill_token:
            head *= 2
        return self.n_layers * per_layer + patch_embed + pos + head + 2 * d

    n_active_params = n_params


@dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int                  # pixel-space resolution; latents are res//8
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    n_classes: int = 1000
    latent_channels: int = 4
    vae_factor: int = 8
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"
    scan_layers: bool = True

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def n_tokens(self, img_res: Optional[int] = None) -> int:
        res = (img_res or self.img_res) // self.vae_factor
        return (res // self.patch) ** 2

    def n_params(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 6 * d * d + 2 * d
        io = self.latent_channels * self.patch ** 2 * d * 2
        cond = 256 * d + d * d + self.n_classes * d
        return self.n_layers * per_layer + io + cond

    n_active_params = n_params


@dataclass(frozen=True)
class EffNetConfig:
    name: str
    img_res: int
    width_mult: float
    depth_mult: float
    n_classes: int = 1000
    dtype: str = "bfloat16"
    remat: bool = True

    def n_params(self) -> int:  # filled in by the model module (architectural)
        from repro.models import efficientnet
        return efficientnet.count_params(self)

    n_active_params = n_params


@dataclass(frozen=True)
class CheapCNNConfig:
    """Focus ingest CNN: a small convnet (compressed family member).

    ``n_blocks`` plays the role of "number of conv layers kept" and
    ``input_res`` the rescaled input resolution — the two compression axes the
    paper uses (§4.1). ``n_classes`` shrinks under specialization (§4.3:
    Ls most-frequent classes + OTHER).
    """

    name: str
    input_res: int = 32
    n_blocks: int = 4
    width: int = 64
    n_classes: int = 1000
    feature_dim: int = 128        # penultimate-layer feature vector (clustering)
    in_channels: int = 3
    dtype: str = "float32"

    def flops_per_image(self) -> int:
        from repro.models import cnn
        return cnn.flops_per_image(self)

    def n_params(self) -> int:
        from repro.models import cnn
        return cnn.count_params(self)


ModelConfig = object  # union alias for documentation purposes


# ---------------------------------------------------------------------------
# Shape cell sets (shared per family)
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": ShapeCell("long_500k", "long", seq_len=524288, global_batch=1),
}

DIT_SHAPES = {
    "train_256": ShapeCell("train_256", "dit_train", img_res=256, global_batch=256, steps=1000),
    "gen_1024": ShapeCell("gen_1024", "dit_gen", img_res=1024, global_batch=4, steps=50),
    "gen_fast": ShapeCell("gen_fast", "dit_gen", img_res=512, global_batch=16, steps=4),
    "train_1024": ShapeCell("train_1024", "dit_train", img_res=1024, global_batch=32, steps=1000),
}

VISION_SHAPES = {
    "cls_224": ShapeCell("cls_224", "cls", img_res=224, global_batch=256),
    "cls_384": ShapeCell("cls_384", "cls", img_res=384, global_batch=64),
    "serve_b1": ShapeCell("serve_b1", "serve", img_res=224, global_batch=1),
    "serve_b128": ShapeCell("serve_b128", "serve", img_res=224, global_batch=128),
}


def shapes_for(cfg) -> dict:
    if isinstance(cfg, LMConfig):
        return LM_SHAPES
    if isinstance(cfg, DiTConfig):
        return DIT_SHAPES
    if isinstance(cfg, (ViTConfig, EffNetConfig)):
        return VISION_SHAPES
    raise TypeError(f"unknown config family: {type(cfg)}")


def reduced(cfg, **overrides):
    """A tiny same-family config for CPU smoke tests."""
    if isinstance(cfg, LMConfig):
        base = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=256, moe_group_size=32, remat=False,
        )
        if cfg.moe:
            base.update(n_experts=4, moe_top_k=2)
    elif isinstance(cfg, ViTConfig):
        base = dict(img_res=32, patch=8, n_layers=2, d_model=64, n_heads=4,
                    d_ff=128, n_classes=16, remat=False)
    elif isinstance(cfg, DiTConfig):
        base = dict(img_res=32, patch=2, n_layers=2, d_model=64, n_heads=4,
                    n_classes=16, remat=False)
    elif isinstance(cfg, EffNetConfig):
        base = dict(img_res=32, width_mult=0.25, depth_mult=0.25,
                    n_classes=16, remat=False)
    else:
        raise TypeError(type(cfg))
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
