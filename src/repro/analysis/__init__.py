"""focuslint — static invariant checks for the jit/Pallas hot paths.

Focus's cost claims rest on hot-path discipline that runtime tests catch
late and reviewers miss as the tree grows: no stray host syncs inside the
fused dispatch loop, no reads of donated device buffers, every Pallas
kernel pinned to a pure-jnp oracle, every centroid/prob mutation bumping
the ``(cid, version)`` cache key. This package enforces those invariants
at review time with a lightweight AST pass (no imports, no execution):

* ``host-sync`` / ``retrace-hazard`` — device syncs and per-value retrace
  hazards in functions reachable from a ``jax.jit`` / ``pl.pallas_call``
  (DESIGN.md §11.1);
* ``donated-read``  — reads of a buffer after it was donated to a jitted
  call (§11.2);
* ``kernel-*`` / ``pallas-outside-kernels`` — the kernel contract: oracle
  in ``ref.py``, pad/trim wrapper in ``ops.py``, exact-equality test in
  ``tests/test_kernels.py`` (§11.3);
* ``cache-version`` — ClusterStore mutations must bump ``versions``
  (§11.4).

CLI: ``python -m repro.analysis [paths...]`` — see ``--help``.
Suppress a finding inline with
``# focuslint: disable=<rule>[,<rule>] -- <justification>``.
"""
from repro.analysis.report import Finding
from repro.analysis.runner import run_analysis

__all__ = ["Finding", "run_analysis"]
