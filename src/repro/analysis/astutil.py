"""Small AST helpers shared by the focuslint rules."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Sequence, Set, Tuple

Chain = Tuple[str, ...]


def dotted(node: ast.AST) -> Optional[Chain]:
    """``a.b.c`` -> ('a','b','c'); None for anything not a pure
    Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[Chain]:
    return dotted(call.func)


def walk_in_order(node: ast.AST) -> Iterator[ast.AST]:
    """ast.walk but depth-first in source order (good enough for the
    linear taint pass)."""
    for child in ast.iter_child_nodes(node):
        yield child
        yield from walk_in_order(child)


def const_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    return None


def const_int_set(node: ast.AST) -> Optional[Set[int]]:
    """Resolve a literal int / tuple-of-ints; for conditional
    expressions, the union of both branches (conservative)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[int] = set()
        for e in node.elts:
            s = const_int_set(e)
            if s is None:
                return None
            out |= s
        return out
    if isinstance(node, ast.IfExp):
        a = const_int_set(node.body)
        b = const_int_set(node.orelse)
        if a is None and b is None:
            return None
        return (a or set()) | (b or set())
    return None


def assign_target_chains(stmt: ast.AST) -> List[Chain]:
    """All Name/Attribute chains stored to by an Assign/AugAssign/
    AnnAssign/For/With statement (tuple targets flattened; subscript
    stores report the base chain)."""
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    elif isinstance(stmt, ast.withitem) and stmt.optional_vars:
        targets = [stmt.optional_vars]
    out: List[Chain] = []

    def add(t: ast.AST):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                add(e)
        elif isinstance(t, ast.Starred):
            add(t.value)
        elif isinstance(t, ast.Subscript):
            c = dotted(t.value)
            if c:
                out.append(c)
        else:
            c = dotted(t)
            if c:
                out.append(c)

    for t in targets:
        add(t)
    return out


def chain_matches(load: Chain, tracked: Chain) -> bool:
    """True when a Load of ``load`` observes ``tracked``: equal, or
    tracked is a prefix of load (``st.centroids`` observed through
    ``st.centroids.shape`` is handled by callers' static-attr filter)."""
    return load[:len(tracked)] == tracked


STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def loads_in(node: ast.AST) -> Iterator[Tuple[Chain, ast.AST]]:
    """Yield (chain, node) for every maximal Name/Attribute Load chain
    inside ``node`` (skipping chains that are pure static metadata like
    ``x.shape``/``x.ndim``/``x.dtype``)."""
    seen: Set[int] = set()
    for sub in ast.walk(node):
        if id(sub) in seen:
            continue
        if isinstance(sub, (ast.Attribute, ast.Name)) and \
                isinstance(getattr(sub, "ctx", None), ast.Load):
            c = dotted(sub)
            if c is None:
                continue
            for inner in ast.walk(sub):
                seen.add(id(inner))
            if any(p in STATIC_ATTRS for p in c[1:]):
                continue
            yield c, sub


def enclosing_def_lines(func_stack: Sequence[ast.AST]) -> Tuple[int, ...]:
    return tuple(f.lineno for f in func_stack
                 if isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef)))
