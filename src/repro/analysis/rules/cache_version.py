"""Rule ``cache-version``: the GT-label cache (PRs 2/4) is keyed on
``(cid, versions[cid])`` — any in-place mutation of a store's
``centroids`` / ``mean_probs`` / ``counts`` / ``fold_counts`` columns
that does not also bump ``versions`` in the same function serves stale
cached labels while looking functionally correct.

A function that subscript-assigns any watched column of a base object
(``self.counts[uniq] += ...``, ``s.centroids[rows] = ...``) must also
subscript- or slice-assign ``<base>.versions`` somewhere in the same
function.  Intentional exemptions (e.g. ``ClusterStore.attach``, whose
count bump is label-neutral by design) carry an inline suppression with
the rationale.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.astutil import Chain, dotted
from repro.analysis.callgraph import ModuleInfo, ProjectIndex
from repro.analysis.report import Finding

WATCHED = ("centroids", "mean_probs", "counts", "fold_counts")


def check_module(project: ProjectIndex, mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for fi in mod.functions.values():
        stores: Dict[Chain, List[Tuple[int, str]]] = {}
        version_bases: Set[Chain] = set()
        for stmt in ast.walk(fi.node):
            if isinstance(stmt, (ast.Assign, ast.AugAssign)):
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for t in targets:
                    if not isinstance(t, ast.Subscript):
                        continue
                    chain = dotted(t.value)
                    if chain is None or len(chain) < 2:
                        continue
                    if chain[-1] in WATCHED:
                        stores.setdefault(chain[:-1], []).append(
                            (stmt.lineno, chain[-1]))
                    elif chain[-1] == "versions":
                        version_bases.add(chain[:-1])
        for base, hits in stores.items():
            if base in version_bases:
                continue
            hits.sort()
            line = hits[0][0]
            cols = ", ".join(sorted({h[1] for h in hits}))
            f = Finding(
                rule="cache-version", path=mod.path, line=line,
                message=f"'{fi.name}' mutates {'.'.join(base)}.{{{cols}}} "
                        f"in place without bumping "
                        f"{'.'.join(base)}.versions — the (cid, version) "
                        f"GT-label cache will serve stale labels")
            f._def_lines = fi.def_lines
            out.append(f)
    return out
