"""Rules ``kernel-oracle`` / ``kernel-wrapper`` / ``kernel-test`` /
``kernel-exact`` / ``pallas-outside-kernels``.

The repo's kernel contract (DESIGN.md §11.3): every public entry point
in a ``kernels/`` module that reaches a ``pl.pallas_call`` must have

* a pure-jnp oracle ``<entry>_ref`` in ``kernels/ref.py``,
* a pad/trim wrapper ``<entry>`` in ``kernels/ops.py``,
* a test in ``tests/test_kernels.py`` that calls both the wrapper and
  the oracle, with at least one exact-equality (``assert_array_equal``)
  comparison,

and raw ``pallas_call`` anywhere outside ``kernels/`` is an error —
kernels bypass the wrapper's shape-padding discipline otherwise.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set

from repro.analysis.astutil import call_name, dotted
from repro.analysis.callgraph import (FuncInfo, ModuleInfo, ProjectIndex)
from repro.analysis.report import Finding

_EXEMPT = {"ops.py", "ref.py", "__init__.py"}


def _mk(path: str, line: int, rule: str, msg: str,
        def_lines=()) -> Finding:
    f = Finding(rule=rule, path=path, line=line, message=msg)
    f._def_lines = tuple(def_lines)
    return f


def _kernel_modules(project: ProjectIndex):
    kmods, ops_mod, ref_mod = [], None, None
    for mod in project.modules.values():
        if not mod.in_kernels:
            continue
        base = os.path.basename(mod.path)
        if base == "ops.py":
            ops_mod = mod
        elif base == "ref.py":
            ref_mod = mod
        elif base not in _EXEMPT:
            kmods.append(mod)
    return kmods, ops_mod, ref_mod


def _entries(project: ProjectIndex, mod: ModuleInfo) -> List[FuncInfo]:
    """Public top-level functions that reach a pallas_call (directly or
    through a module-local helper)."""
    local_pallas = {fi.qualname for fi in mod.functions.values()
                    if fi.has_pallas}
    out = []
    for fi in mod.functions.values():
        if fi.class_name or fi.parent or fi.name.startswith("_"):
            continue
        if fi.has_pallas or (fi.callees & local_pallas):
            out.append(fi)
    return out


def check_project(project: ProjectIndex) -> List[Finding]:
    out: List[Finding] = []
    kmods, ops_mod, ref_mod = _kernel_modules(project)
    test_mod = None
    for mod in project.modules.values():
        if mod.in_tests and os.path.basename(mod.path) == "test_kernels.py":
            test_mod = mod

    # pallas_call outside kernels/
    for mod in project.modules.values():
        if mod.in_kernels:
            continue
        for fi in mod.functions.values():
            if not fi.has_pallas:
                continue
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Call):
                    chain = call_name(node)
                    if chain and project.is_pallas_call(mod, chain):
                        out.append(_mk(
                            mod.path, node.lineno, "pallas-outside-kernels",
                            f"raw pallas_call in '{fi.name}' outside "
                            f"kernels/ — route through a kernels/ops.py "
                            f"wrapper", fi.def_lines))

    if not kmods:
        return out
    ops_calls, ref_calls, exact_ops = _scan_tests(project, test_mod,
                                                  ops_mod, ref_mod)
    for mod in kmods:
        for entry in _entries(project, mod):
            w = entry.name
            line, dl = entry.node.lineno, entry.def_lines
            if ref_mod is None or f"{w}_ref" not in ref_mod.symbols:
                out.append(_mk(mod.path, line, "kernel-oracle",
                               f"kernel '{w}' has no oracle "
                               f"'{w}_ref' in kernels/ref.py", dl))
            if ops_mod is None or w not in ops_mod.symbols:
                out.append(_mk(mod.path, line, "kernel-wrapper",
                               f"kernel '{w}' has no pad/trim wrapper "
                               f"'{w}' in kernels/ops.py", dl))
            if w not in ops_calls or f"{w}_ref" not in ref_calls:
                out.append(_mk(mod.path, line, "kernel-test",
                               f"tests/test_kernels.py never exercises "
                               f"ops.{w} together with ref.{w}_ref", dl))
            elif w not in exact_ops:
                out.append(_mk(mod.path, line, "kernel-exact",
                               f"no exact-equality (assert_array_equal) "
                               f"test pins ops.{w} to its oracle", dl))
    return out


def _scan_tests(project: ProjectIndex, test_mod: Optional[ModuleInfo],
                ops_mod: Optional[ModuleInfo],
                ref_mod: Optional[ModuleInfo]):
    """Which ops wrappers / ref oracles does test_kernels.py call, and
    which wrappers appear in a test function that also does an
    assert_array_equal?"""
    ops_calls: Set[str] = set()
    ref_calls: Set[str] = set()
    exact_ops: Set[str] = set()
    if test_mod is None:
        return ops_calls, ref_calls, exact_ops
    ops_q = {f"{ops_mod.modname}::{n}": n
             for n in (ops_mod.symbols if ops_mod else ())}
    ref_q = {f"{ref_mod.modname}::{n}": n
             for n in (ref_mod.symbols if ref_mod else ())}
    for fi in test_mod.functions.values():
        local_ops: Set[str] = set()
        has_exact = False
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            chain = call_name(node)
            if chain and chain[-1] == "assert_array_equal":
                has_exact = True
            if chain is None:
                continue
            val = project.resolve_value(test_mod, chain, fi)
            if val is None:
                continue
            for q in val.targets:
                if q in ops_q:
                    local_ops.add(ops_q[q])
                if q in ref_q:
                    ref_calls.add(ref_q[q])
        ops_calls |= local_ops
        if has_exact:
            exact_ops |= local_ops
    return ops_calls, ref_calls, exact_ops
