"""Rule registry: rule id -> one-line description (``--list-rules``)."""

RULES = {
    "host-sync": (
        "host/device sync (.item(), int()/float()/bool(), np.asarray, "
        "jax.device_get) on a traced or un-synced device value in a "
        "function reachable from jax.jit / pl.pallas_call"),
    "retrace-hazard": (
        "data-dependent Python scalar in a jitted signature (static arg "
        "or int()/float()/len() argument) — forces a retrace or weak-"
        "dtype recompile per distinct value"),
    "donated-read": (
        "read of a buffer after it was donated to a jax.jit(..., "
        "donate_argnums=...) call in the same scope"),
    "kernel-oracle": (
        "pallas_call kernel without a matching *_ref oracle in "
        "kernels/ref.py"),
    "kernel-wrapper": (
        "pallas_call kernel without a pad/trim wrapper in "
        "kernels/ops.py"),
    "kernel-test": (
        "pallas_call kernel whose ops wrapper + ref oracle are never "
        "exercised together in tests/test_kernels.py"),
    "kernel-exact": (
        "pallas_call kernel without an exact-equality "
        "(assert_array_equal) test against its oracle"),
    "pallas-outside-kernels": (
        "raw pl.pallas_call outside src/repro/kernels/"),
    "cache-version": (
        "ClusterStore-style method mutates a centroid/prob/count column "
        "without bumping .versions — rots the (cid, version) GT-label "
        "cache key"),
    "bare-suppression": (
        "focuslint suppression without a '-- justification'"),
    "parse-error": "file failed to parse",
}
