"""Rule ``donated-read``: a buffer passed at a ``donate_argnums``
position of a jitted call is dead after the call — XLA may have reused
its memory — so any later read of the same name (or an attribute path
through it) in the enclosing function is flagged, unless the name was
reassigned between the call and the read.

For calls inside a loop, a read of the donated chain anywhere in the
loop body with no reassignment in that body is flagged too (the second
iteration reads a donated buffer).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (Chain, assign_target_chains, dotted,
                                    loads_in)
from repro.analysis.callgraph import FuncInfo, ModuleInfo, ProjectIndex
from repro.analysis.report import Finding

_ASSIGNS = (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.For)


def _compatible(a: Chain, b: Chain) -> bool:
    """A store to ``a`` kills tracking of ``b`` when either is a prefix
    of the other (storing ``st`` rebinds ``st.centroids`` and vice
    versa)."""
    n = min(len(a), len(b))
    return a[:n] == b[:n]


def _parents(root: ast.AST) -> Dict[int, ast.AST]:
    par: Dict[int, ast.AST] = {}
    for node in ast.walk(root):
        for child in ast.iter_child_nodes(node):
            par[id(child)] = node
    return par


def check_module(project: ProjectIndex, mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for fi in mod.functions.values():
        if not fi.jit_sites:
            continue
        out.extend(_check_func(project, fi))
    return out


def _check_func(project: ProjectIndex, fi: FuncInfo) -> List[Finding]:
    out: List[Finding] = []
    parents = None
    stores: List[Tuple[int, Chain]] = []
    for stmt in ast.walk(fi.node):
        if isinstance(stmt, _ASSIGNS):
            for c in assign_target_chains(stmt):
                stores.append((stmt.lineno, c))

    for call, info in fi.jit_sites:
        if not info.donate:
            continue
        donated: List[Chain] = []
        for i in sorted(info.donate):
            if i < len(call.args):
                c = dotted(call.args[i])
                if c:
                    donated.append(c)
        if not donated:
            continue
        call_nodes = {id(n) for n in ast.walk(call)}
        call_line = getattr(call, "end_lineno", call.lineno) or call.lineno
        if parents is None:
            parents = _parents(fi.node)
        loop = _enclosing_loop(parents, call)
        reported: Set[Tuple[Chain, int]] = set()

        def flag(chain: Chain, node: ast.AST, why: str):
            key = (chain, node.lineno)
            if key in reported:
                return
            reported.add(key)
            f = Finding(
                rule="donated-read", path=fi.module.path, line=node.lineno,
                col=getattr(node, "col_offset", 0),
                message=f"read of '{'.'.join(chain)}' {why} it was donated "
                        f"to a jitted call (line {call.lineno}); the "
                        f"buffer may have been reused by XLA")
            f._def_lines = fi.def_lines
            out.append(f)

        for node in ast.walk(fi.node):
            if id(node) in call_nodes:
                continue
            if not isinstance(node, (ast.Name, ast.Attribute)) or \
                    not isinstance(getattr(node, "ctx", None), ast.Load):
                continue
            chain = dotted(node)
            if chain is None:
                continue
            for d in donated:
                if chain[:len(d)] != d:
                    continue
                if node.lineno > call_line:
                    killed = any(
                        call.lineno <= sl <= node.lineno
                        and _compatible(sc, d)
                        for sl, sc in stores)
                    if not killed:
                        flag(d, node, "after")
                elif loop is not None and _inside(parents, node, loop):
                    killed = any(
                        _inside_line_range(loop, sl) and _compatible(sc, d)
                        for sl, sc in stores)
                    if not killed:
                        flag(d, node, "on the next loop iteration after")
    return out


def _enclosing_loop(parents: Dict[int, ast.AST],
                    node: ast.AST) -> Optional[ast.AST]:
    cur = parents.get(id(node))
    while cur is not None:
        if isinstance(cur, (ast.For, ast.While)):
            return cur
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return None
        cur = parents.get(id(cur))
    return None


def _inside(parents: Dict[int, ast.AST], node: ast.AST,
            ancestor: ast.AST) -> bool:
    cur: Optional[ast.AST] = node
    while cur is not None:
        if cur is ancestor:
            return True
        cur = parents.get(id(cur))
    return False


def _inside_line_range(loop: ast.AST, line: int) -> bool:
    end = getattr(loop, "end_lineno", None)
    return end is not None and loop.lineno <= line <= end
