"""Rules ``host-sync`` and ``retrace-hazard``.

Two function populations, computed by the call-graph walk:

* DEVICE functions (traced: reachable from a jit root or a Pallas kernel
  body) — any ``.item()`` / ``jax.device_get`` / ``np.asarray`` /
  ``np.array`` is an error, and ``int()/float()/bool()`` of a traced
  value is an error (it forces a concretization mid-trace);
* DISPATCHERS (host hot path: transitively call a jitted callable) —
  ``.item()`` and ``jax.device_get`` are flagged unconditionally (each
  one stalls async dispatch); ``int()/float()/bool()/np.asarray`` only
  when applied to a value tracked as un-synced device data (result of a
  jit call or of a device-returning function, propagated through local
  assignments).

Test files are skipped: tests sync on purpose to assert values.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.astutil import (Chain, assign_target_chains, call_name,
                                    dotted, loads_in)
from repro.analysis.callgraph import (FuncInfo, JitInfo, ModuleInfo,
                                      ProjectIndex)
from repro.analysis.report import Finding

_SCALARS = {"builtins.int", "builtins.float", "builtins.bool"}
_NP_CASTS = {"numpy.asarray", "numpy.array"}


def _mk(fi: FuncInfo, node: ast.AST, rule: str, msg: str) -> Finding:
    f = Finding(rule=rule, path=fi.module.path, line=node.lineno,
                col=getattr(node, "col_offset", 0), message=msg)
    f._def_lines = fi.def_lines
    return f


def check_module(project: ProjectIndex, mod: ModuleInfo) -> List[Finding]:
    if mod.in_tests:
        return []
    out: List[Finding] = []
    seen: Set[Tuple[int, int, str]] = set()

    def emit(fi, node, rule, msg):
        key = (node.lineno, getattr(node, "col_offset", 0), rule)
        if key not in seen:
            seen.add(key)
            out.append(_mk(fi, node, rule, msg))

    for fi in mod.functions.values():
        if fi.qualname in project.device_funcs:
            _check_device(project, fi, emit)
        elif fi.qualname in project.dispatchers:
            _check_dispatcher(project, fi, emit)
    return out


# -- DEVICE (traced) functions -------------------------------------------------

def _check_device(project: ProjectIndex, fi: FuncInfo, emit):
    name = fi.name
    for node in ast.walk(fi.node):
        if not isinstance(node, ast.Call):
            continue
        chain = call_name(node)
        if chain is None:
            continue
        if len(chain) >= 2 and chain[-1] == "item":
            emit(fi, node, "host-sync",
                 f".item() inside traced function '{name}' — concretizes "
                 f"a tracer and blocks compilation")
            continue
        canon = project.canonical(fi.module, chain)
        if canon == "jax.device_get":
            emit(fi, node, "host-sync",
                 f"jax.device_get inside traced function '{name}'")
        elif canon in _NP_CASTS:
            emit(fi, node, "host-sync",
                 f"{'.'.join(chain)} inside traced function '{name}' — "
                 f"materializes a tracer on host; use jnp instead")
        elif canon in _SCALARS and node.args:
            if _mentions_dynamic(node.args[0]):
                emit(fi, node, "host-sync",
                     f"{chain[0]}() of a traced value inside '{name}' — "
                     f"concretization error or silent constant-folding")


def _mentions_dynamic(expr: ast.AST) -> bool:
    """True when the expression references non-static data.  ``loads_in``
    already drops pure ``.shape``/``.ndim``/``.dtype`` chains; loads that
    appear only as ``len()`` arguments are shape-static under trace and
    are dropped here."""
    in_len = set()
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call) and call_name(sub) == ("len",):
            for inner in ast.walk(sub):
                in_len.add(id(inner))
    return any(id(node) not in in_len for _, node in loads_in(expr))


# -- DISPATCHER (host hot path) functions --------------------------------------

def _check_dispatcher(project: ProjectIndex, fi: FuncInfo, emit):
    tainted: Set[Chain] = set()
    name = fi.name

    def expr_tainted(expr: ast.AST) -> bool:
        if project.expr_is_coercion(fi, expr):
            return False
        skip = project.taint_stops(fi, expr)
        for sub in ast.walk(expr):
            if id(sub) in skip:
                continue
            if isinstance(sub, ast.Call) and \
                    project.call_returns_device(fi, sub):
                return True
        for chain, node in loads_in(expr):
            if id(node) in skip:
                continue
            for t in tainted:
                if chain[:len(t)] == t:
                    return True
        return False

    def visit_expr(expr: Optional[ast.AST]):
        if expr is None:
            return
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            chain = call_name(node)
            if chain is None:
                continue
            if len(chain) >= 2 and chain[-1] == "item":
                emit(fi, node, "host-sync",
                     f".item() in hot-path function '{name}' — blocks "
                     f"until the device result lands")
                continue
            canon = project.canonical(fi.module, chain)
            if canon == "jax.device_get":
                emit(fi, node, "host-sync",
                     f"jax.device_get in hot-path function '{name}' — "
                     f"synchronous device fetch stalls async dispatch; "
                     f"sanctioned only at the designed (j, matched) fold "
                     f"boundary (one whole-batch fetch per resolved step "
                     f"— DESIGN.md §9/§13), and must carry a suppression "
                     f"naming it")
            elif canon in _NP_CASTS and node.args and \
                    expr_tainted(node.args[0]):
                emit(fi, node, "host-sync",
                     f"{'.'.join(chain)} of an un-synced device value in "
                     f"hot-path function '{name}' — implicit blocking "
                     f"transfer")
            elif canon in _SCALARS and node.args and \
                    expr_tainted(node.args[0]):
                emit(fi, node, "host-sync",
                     f"{chain[0]}() of an un-synced device value in "
                     f"hot-path function '{name}' — implicit blocking "
                     f"transfer")
            cc = project.classify_call(fi, node)
            if cc.kind == "jit":
                _check_retrace(project, fi, node, cc.jit or JitInfo(), emit)

    def visit_block(stmts):
        for stmt in stmts:
            visit_stmt(stmt)

    def visit_stmt(stmt: ast.AST):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                      # nested defs are their own FuncInfo
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                visit_expr(stmt.value)
                vt = expr_tainted(stmt.value)
                for c in assign_target_chains(stmt):
                    if vt:
                        tainted.add(c)
                    else:
                        for t in list(tainted):
                            if t[:len(c)] == c:
                                tainted.discard(t)
            return
        if isinstance(stmt, ast.For):
            visit_expr(stmt.iter)
            if expr_tainted(stmt.iter):
                for c in assign_target_chains(stmt):
                    tainted.add(c)
            visit_block(stmt.body)
            visit_block(stmt.orelse)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            visit_expr(stmt.test)
            visit_block(stmt.body)
            visit_block(stmt.orelse)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                visit_expr(item.context_expr)
            visit_block(stmt.body)
            return
        if isinstance(stmt, ast.Try):
            visit_block(stmt.body)
            for h in stmt.handlers:
                visit_block(h.body)
            visit_block(stmt.orelse)
            visit_block(stmt.finalbody)
            return
        # Expr / Return / Assert / Raise / Delete / ...
        for sub in ast.iter_child_nodes(stmt):
            if isinstance(sub, ast.expr):
                visit_expr(sub)

    visit_block(fi.node.body)


# -- retrace hazards at jit call sites -----------------------------------------

def _data_dependent(expr: ast.AST) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call):
            chain = call_name(sub)
            if chain is None:
                continue
            if chain in (("int",), ("float",), ("len",)) or \
                    (len(chain) >= 2 and chain[-1] == "item"):
                return True
    return False


def _check_retrace(project: ProjectIndex, fi: FuncInfo, call: ast.Call,
                   info: JitInfo, emit):
    static_pos = set(info.static_nums)
    for q in info.targets:
        fn = project.funcs.get(q)
        if fn is None:
            continue
        params = fn.params
        for nm in info.static_names:
            if nm in params:
                static_pos.add(params.index(nm))
    for i, arg in enumerate(call.args):
        if i in static_pos and _data_dependent(arg):
            emit(fi, arg, "retrace-hazard",
                 f"data-dependent value in static argument {i} of a "
                 f"jitted call — retraces per distinct value")
        elif i not in static_pos and isinstance(arg, ast.Call):
            chain = call_name(arg)
            if chain in (("int",), ("float",)):
                emit(fi, arg, "retrace-hazard",
                     f"Python scalar from {chain[0]}() passed to a jitted "
                     f"call — weak-typed host scalar; pass a jnp/np "
                     f"array to keep the trace signature stable")
    for kw in call.keywords:
        if kw.arg in info.static_names and _data_dependent(kw.value):
            emit(fi, kw.value, "retrace-hazard",
                 f"data-dependent value in static argument "
                 f"'{kw.arg}' of a jitted call — retraces per distinct "
                 f"value")
