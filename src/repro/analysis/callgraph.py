"""Project-wide symbol/call-graph index for the focuslint rules.

Pure-AST (nothing is imported or executed).  The index answers three
questions the rules need:

* what does a ``Call`` resolve to — a ``jax.jit``-wrapped callable (with
  its donate/static configuration), a project function, a Pallas
  ``pallas_call``, or an extern like ``numpy.asarray``;
* which functions are DEVICE code (traced: reachable *from* a jit root
  or a Pallas kernel body) vs DISPATCHERS (host hot path: transitively
  *calling* a jitted callable);
* which project functions are *device-returning* (their results carry
  un-synced device buffers), so host-side coercions of those results can
  be flagged without drowning in false positives.

Resolution is deliberately shallow: module aliases, ``from`` imports,
module-level ``NAME = jax.jit(...)`` / dict-of-function bindings,
decorators (incl. ``functools.partial(jax.jit, ...)``), local
``fn = factory(...)`` bindings where the factory's returns are jit
values, and ``self.NAME = ...`` bindings collected across a class's
methods.  Anything unresolved is simply not flagged.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.astutil import (Chain, assign_target_chains, call_name,
                                    const_int_set, const_str_tuple, dotted,
                                    loads_in)

HOST_COERCIONS = {"builtins.int", "builtins.float", "builtins.bool",
                  "numpy.asarray", "numpy.array", "jax.device_get"}
JIT_EXTERNS = {"jax.jit"}
PARTIAL_EXTERNS = {"functools.partial", "partial"}

# Method calls whose results are host-side metadata even when the
# receiver holds device buffers: the AOT lowering/introspection API, and
# block_until_ready (the sanctioned sync point — its result is already
# landed, so a following np.asarray is a copy, not a stall).
HOST_RESULT_ATTRS = {"lower", "compile", "cost_analysis",
                     "memory_analysis", "as_text", "compiler_ir",
                     "block_until_ready", "item"}

# jax.* externs whose results are NOT device data (callables, shape
# structs, backend introspection).
_JAX_HOST_EXTERNS = {"jax.jit", "jax.device_get", "jax.eval_shape",
                     "jax.ShapeDtypeStruct", "jax.devices",
                     "jax.local_devices", "jax.device_count",
                     "jax.local_device_count", "jax.default_backend",
                     "jax.grad", "jax.value_and_grad", "jax.vmap",
                     "jax.pmap", "jax.checkpoint", "jax.named_scope",
                     "jax.debug.print"}


@dataclass
class JitInfo:
    donate: Set[int] = field(default_factory=set)
    static_nums: Set[int] = field(default_factory=set)
    static_names: Set[str] = field(default_factory=set)
    targets: Set[str] = field(default_factory=set)   # inner func qualnames

    def merge(self, other: "JitInfo") -> "JitInfo":
        return JitInfo(self.donate | other.donate,
                       self.static_nums | other.static_nums,
                       self.static_names | other.static_names,
                       self.targets | other.targets)


@dataclass
class Value:
    """A statically-resolved callable binding."""
    kind: str                      # 'func' | 'jit' | 'set'
    targets: Set[str] = field(default_factory=set)
    jit: Optional[JitInfo] = None


@dataclass
class CallClass:
    kind: str                      # 'jit'|'func'|'pallas'|'extern'|'unknown'
    jit: Optional[JitInfo] = None
    targets: Set[str] = field(default_factory=set)
    extern: Optional[str] = None


@dataclass
class FuncInfo:
    qualname: str
    name: str
    module: "ModuleInfo"
    node: ast.AST
    class_name: Optional[str] = None
    parent: Optional[str] = None          # enclosing function qualname
    def_lines: Tuple[int, ...] = ()
    env: Dict[str, Value] = field(default_factory=dict)
    jit_sites: List[Tuple[ast.Call, JitInfo]] = field(default_factory=list)
    has_pallas: bool = False
    callees: Set[str] = field(default_factory=set)

    @property
    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args]
        return names


@dataclass
class ModuleInfo:
    modname: str
    path: str
    tree: ast.Module
    source: str
    aliases: Dict[str, str] = field(default_factory=dict)       # import x as y
    from_imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    symbols: Dict[str, Value] = field(default_factory=dict)
    functions: Dict[str, FuncInfo] = field(default_factory=dict)  # by qualname
    self_attrs: Dict[str, Dict[str, Value]] = field(default_factory=dict)
    kernel_roots: Set[str] = field(default_factory=set)

    @property
    def in_tests(self) -> bool:
        parts = self.path.replace(os.sep, "/").split("/")
        return "tests" in parts

    @property
    def in_kernels(self) -> bool:
        parts = self.path.replace(os.sep, "/").split("/")
        return "kernels" in parts


def modname_for(path: str) -> str:
    norm = os.path.normpath(path).replace(os.sep, "/")
    parts = [p for p in norm.split("/") if p not in (".", "")]
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class ProjectIndex:
    def __init__(self, files: Sequence[Tuple[str, str]]):
        """files: (path, source) pairs; paths are repo-relative."""
        self.modules: Dict[str, ModuleInfo] = {}
        self.funcs: Dict[str, FuncInfo] = {}
        self.parse_errors: List[Tuple[str, str]] = []
        self.device_funcs: Set[str] = set()
        self.dispatchers: Set[str] = set()
        self.device_returning: Set[str] = set()
        self._factories: Dict[str, JitInfo] = {}
        for path, source in files:
            try:
                tree = ast.parse(source, filename=path)
            except SyntaxError as e:
                self.parse_errors.append((path, str(e)))
                continue
            mod = ModuleInfo(modname=modname_for(path), path=path,
                             tree=tree, source=source)
            self.modules[mod.modname] = mod
        for mod in self.modules.values():
            self._collect_imports(mod)
            self._collect_defs(mod)
        for mod in self.modules.values():
            self._collect_module_bindings(mod)
        for _ in range(3):                      # factory/env fixpoint
            changed = self._build_envs()
            if not changed:
                break
        for mod in self.modules.values():
            self._collect_self_attrs(mod)
        self._collect_edges()
        self._compute_closures()
        self._compute_device_returning()

    # -- parsing passes --------------------------------------------------------

    def _collect_imports(self, mod: ModuleInfo):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    mod.from_imports[a.asname or a.name] = (node.module,
                                                            a.name)

    def _collect_defs(self, mod: ModuleInfo):
        def visit(node, class_name, parent, def_lines):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if class_name:
                        local = f"{class_name}.{child.name}"
                    elif parent:
                        local = f"{parent.split('::')[1]}.<locals>." \
                                f"{child.name}"
                    else:
                        local = child.name
                    qual = f"{mod.modname}::{local}"
                    fi = FuncInfo(qualname=qual, name=child.name, module=mod,
                                  node=child, class_name=class_name,
                                  parent=parent,
                                  def_lines=def_lines + (child.lineno,))
                    mod.functions[qual] = fi
                    self.funcs[qual] = fi
                    if not class_name and not parent:
                        mod.symbols.setdefault(
                            child.name, Value("func", {qual}))
                    visit(child, None, qual, fi.def_lines)
                elif isinstance(child, ast.ClassDef):
                    visit(child, child.name, None, def_lines)
                elif not isinstance(child, (ast.Lambda,)):
                    visit(child, class_name, parent, def_lines)
        visit(mod.tree, None, None, ())

    # -- name resolution -------------------------------------------------------

    def canonical(self, mod: ModuleInfo, chain: Chain) -> Optional[str]:
        """Canonical dotted name for an extern chain, e.g. ('np',
        'asarray') -> 'numpy.asarray'."""
        head = chain[0]
        if head in mod.aliases:
            return ".".join((mod.aliases[head],) + chain[1:])
        if head in mod.from_imports:
            src, orig = mod.from_imports[head]
            return ".".join((src, orig) + chain[1:])
        if head in ("int", "float", "bool", "len") and len(chain) == 1:
            return f"builtins.{head}"
        return None

    def is_pallas_call(self, mod: ModuleInfo, chain: Chain) -> bool:
        canon = self.canonical(mod, chain)
        return bool(canon) and (canon.endswith("pallas.pallas_call")
                                or canon.endswith("pl.pallas_call"))

    def _module_for(self, canon_prefix: str) -> Optional[ModuleInfo]:
        return self.modules.get(canon_prefix)

    def resolve_value(self, mod: ModuleInfo, chain: Chain,
                      func: Optional[FuncInfo] = None) -> Optional[Value]:
        head = chain[0]
        if func is not None:
            f: Optional[FuncInfo] = func
            while f is not None:
                if len(chain) == 1 and head in f.env:
                    return f.env[head]
                f = self.funcs.get(f.parent) if f.parent else None
            if head == "self" and func.class_name and len(chain) == 2:
                attrs = mod.self_attrs.get(func.class_name, {})
                if chain[1] in attrs:
                    return attrs[chain[1]]
                meth = f"{mod.modname}::{func.class_name}.{chain[1]}"
                if meth in self.funcs:
                    return Value("func", {meth})
                return None
        if len(chain) == 1:
            if head in mod.symbols:
                return mod.symbols[head]
            if head in mod.from_imports:
                src, orig = mod.from_imports[head]
                other = self._module_for(src)
                if other and orig in other.symbols:
                    return other.symbols[orig]
                nested = self._module_for(f"{src}.{orig}")
                if nested:
                    return None          # module object, not a callable
            return None
        # dotted: resolve the root to a scanned module, then its symbol
        root_mod: Optional[ModuleInfo] = None
        rest = chain[1:]
        if head in mod.aliases:
            root_mod = self._module_for(mod.aliases[head])
        elif head in mod.from_imports:
            src, orig = mod.from_imports[head]
            root_mod = self._module_for(f"{src}.{orig}")
        if root_mod and len(rest) == 1 and rest[0] in root_mod.symbols:
            return root_mod.symbols[rest[0]]
        return None

    # -- jit construction parsing ---------------------------------------------

    def _resolve_int_set_arg(self, mod: ModuleInfo, node: ast.AST,
                             ) -> Set[int]:
        s = const_int_set(node)
        if s is not None:
            return s
        # helper call like donate_argnums=_donate_argnums(): union of the
        # helper's literal returns
        if isinstance(node, ast.Call):
            chain = call_name(node)
            if chain and len(chain) == 1:
                val = mod.symbols.get(chain[0])
                if val and val.kind == "func":
                    out: Set[int] = set()
                    for q in val.targets:
                        fn = self.funcs[q]
                        for sub in ast.walk(fn.node):
                            if isinstance(sub, ast.Return) and sub.value:
                                rs = const_int_set(sub.value)
                                if rs:
                                    out |= rs
                    return out
        if isinstance(node, ast.Name):
            # local NAME = <literal or IfExp> assigned earlier in the
            # same function — scan the enclosing module lazily
            return set()
        return set()

    def parse_jit_call(self, mod: ModuleInfo, call: ast.Call,
                       func: Optional[FuncInfo] = None) -> Optional[JitInfo]:
        """If ``call`` constructs a jit value (``jax.jit(...)`` or
        ``functools.partial(jax.jit, ...)``), return its JitInfo."""
        chain = call_name(call)
        if chain is None:
            return None
        canon = self.canonical(mod, chain) or ".".join(chain)
        kw_start = 0
        if canon in PARTIAL_EXTERNS or canon.endswith("functools.partial"):
            if not call.args:
                return None
            inner = dotted(call.args[0])
            if inner is None:
                return None
            icanon = self.canonical(mod, inner) or ".".join(inner)
            if icanon not in JIT_EXTERNS and not icanon.endswith("jax.jit"):
                return None
            kw_start = 1
        elif canon not in JIT_EXTERNS and not canon.endswith("jax.jit"):
            return None
        info = JitInfo()
        args = call.args[kw_start:]
        if kw_start == 0 and args:
            t = dotted(args[0])
            if t:
                val = self.resolve_value(mod, t, func)
                if val and val.kind in ("func", "jit"):
                    info.targets |= val.targets
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                info.donate |= self._resolve_int_set_arg(mod, kw.value)
                if isinstance(kw.value, ast.Name):
                    info.donate |= self._local_int_binding(mod, func, call,
                                                          kw.value.id)
            elif kw.arg == "static_argnums":
                info.static_nums |= self._resolve_int_set_arg(mod, kw.value)
            elif kw.arg == "static_argnames":
                names = const_str_tuple(kw.value)
                if names:
                    info.static_names |= set(names)
        return info

    def _local_int_binding(self, mod: ModuleInfo, func: Optional[FuncInfo],
                           call: ast.Call, name: str) -> Set[int]:
        """Resolve ``donate_argnums=NAME`` where NAME was bound to a
        literal (or conditional of literals) earlier in the enclosing
        function — e.g. ``donate_args = (0, 1, 2) if donate else ()``."""
        if func is None:
            return set()
        out: Set[int] = set()
        for sub in ast.walk(func.node):
            if isinstance(sub, ast.Assign) and sub.lineno < call.lineno:
                for t in sub.targets:
                    if isinstance(t, ast.Name) and t.id == name:
                        s = const_int_set(sub.value)
                        if s:
                            out |= s
        return out

    # -- module-level bindings -------------------------------------------------

    def _jit_from_decorators(self, mod: ModuleInfo,
                             node: ast.AST) -> Optional[JitInfo]:
        for dec in getattr(node, "decorator_list", []):
            if isinstance(dec, ast.Call):
                info = self.parse_jit_call(mod, dec)
                if info is not None:
                    return info
            else:
                chain = dotted(dec)
                if chain:
                    canon = self.canonical(mod, chain) or ".".join(chain)
                    if canon in JIT_EXTERNS or canon.endswith("jax.jit"):
                        return JitInfo()
        return None

    def _collect_module_bindings(self, mod: ModuleInfo):
        # decorated defs anywhere become jit roots
        for fi in mod.functions.values():
            info = self._jit_from_decorators(mod, fi.node)
            if info is not None:
                info.targets.add(fi.qualname)
                val = Value("jit", {fi.qualname}, info)
                if fi.class_name is None and fi.parent is None:
                    mod.symbols[fi.name] = val
                elif fi.parent:
                    parent = self.funcs.get(fi.parent)
                    if parent is not None:
                        parent.env[fi.name] = val
        for stmt in mod.tree.body:
            if not isinstance(stmt, ast.Assign):
                continue
            names = [t.id for t in stmt.targets if isinstance(t, ast.Name)]
            if not names:
                continue
            if isinstance(stmt.value, ast.Call):
                info = self.parse_jit_call(mod, stmt.value)
                if info is not None:
                    for n in names:
                        mod.symbols[n] = Value("jit", set(info.targets), info)
                    continue
            if isinstance(stmt.value, ast.Dict):
                targets: Set[str] = set()
                ok = True
                for v in stmt.value.values:
                    c = dotted(v)
                    val = self.resolve_value(mod, c) if c else None
                    if val and val.kind in ("func", "jit"):
                        targets |= val.targets
                    else:
                        ok = False
                if ok and targets:
                    for n in names:
                        mod.symbols[n] = Value("set", targets)
        # Pallas kernel roots: first argument of every pallas_call
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                chain = call_name(node)
                if chain and self.is_pallas_call(mod, chain):
                    self._mark_kernel_root(mod, node)

    def _mark_kernel_root(self, mod: ModuleInfo, call: ast.Call):
        if not call.args:
            return
        kern = call.args[0]
        if isinstance(kern, ast.Call):        # functools.partial(kernel, ..)
            if kern.args:
                kern = kern.args[0]
        chain = dotted(kern)
        if not chain:
            return
        val = self.resolve_value(mod, chain)
        if val is None and len(chain) == 1:
            # kernel bodies are usually module-private defs
            q = f"{mod.modname}::{chain[0]}"
            if q in self.funcs:
                val = Value("func", {q})
        if val:
            mod.kernel_roots |= val.targets

    # -- local envs / factories ------------------------------------------------

    def _build_envs(self) -> bool:
        changed = False
        for fi in self.funcs.values():
            mod = fi.module
            for stmt in ast.walk(fi.node):
                if not isinstance(stmt, ast.Assign):
                    continue
                names = [t.id for t in stmt.targets
                         if isinstance(t, ast.Name)]
                if not names:
                    continue
                val = self._value_of_expr(mod, fi, stmt.value)
                if val is None:
                    continue
                for n in names:
                    old = fi.env.get(n)
                    if old is None or old.kind != val.kind or \
                            old.targets != val.targets:
                        fi.env[n] = val
                        changed = True
        # recompute factory set
        for fi in self.funcs.values():
            if fi.qualname in self._factories:
                continue
            for sub in ast.walk(fi.node):
                if isinstance(sub, ast.Return) and sub.value is not None:
                    val = self._value_of_expr(fi.module, fi, sub.value)
                    if val is not None and val.kind == "jit":
                        self._factories[fi.qualname] = val.jit or JitInfo()
                        changed = True
                        break
        return changed

    def _value_of_expr(self, mod: ModuleInfo, fi: Optional[FuncInfo],
                       expr: ast.AST) -> Optional[Value]:
        if isinstance(expr, ast.Call):
            info = self.parse_jit_call(mod, expr, fi)
            if info is not None:
                return Value("jit", set(info.targets), info)
            chain = call_name(expr)
            if chain:
                val = self.resolve_value(mod, chain, fi)
                if val and val.kind == "func":
                    merged: Optional[JitInfo] = None
                    for q in val.targets:
                        if q in self._factories:
                            merged = (self._factories[q] if merged is None
                                      else merged.merge(self._factories[q]))
                    if merged is not None:
                        return Value("jit", set(merged.targets), merged)
            return None
        if isinstance(expr, ast.Subscript):
            chain = dotted(expr.value)
            if chain:
                val = self.resolve_value(mod, chain, fi)
                if val and val.kind == "set":
                    return val
            return None
        chain = dotted(expr)
        if chain:
            val = self.resolve_value(mod, chain, fi)
            if val and val.kind in ("func", "jit", "set"):
                return val
        return None

    def _collect_self_attrs(self, mod: ModuleInfo):
        by_class: Dict[str, Dict[str, Value]] = {}
        for fi in mod.functions.values():
            if not fi.class_name:
                continue
            attrs = by_class.setdefault(fi.class_name, {})
            for stmt in ast.walk(fi.node):
                if not isinstance(stmt, ast.Assign):
                    continue
                for t in stmt.targets:
                    c = dotted(t)
                    if not c or len(c) != 2 or c[0] != "self":
                        continue
                    val = self._value_of_expr(mod, fi, stmt.value)
                    if val is not None:
                        old = attrs.get(c[1])
                        if old is not None:
                            val = Value(old.kind if old.kind == val.kind
                                        else "set",
                                        old.targets | val.targets,
                                        old.jit or val.jit)
                        attrs[c[1]] = val
        mod.self_attrs = by_class

    # -- call classification ---------------------------------------------------

    def classify_call(self, fi: FuncInfo, call: ast.Call) -> CallClass:
        mod = fi.module
        if isinstance(call.func, ast.Call):
            inner = self._value_of_expr(mod, fi, call.func)
            if inner is not None and inner.kind == "jit":
                return CallClass("jit", inner.jit or JitInfo(),
                                 set(inner.targets))
            return CallClass("unknown")
        chain = call_name(call)
        if chain is None:
            return CallClass("unknown")
        if self.is_pallas_call(mod, chain):
            return CallClass("pallas")
        val = self.resolve_value(mod, chain, fi)
        if val is not None:
            if val.kind == "jit":
                return CallClass("jit", val.jit or JitInfo(),
                                 set(val.targets))
            return CallClass("func", None, set(val.targets))
        canon = self.canonical(mod, chain)
        if canon:
            return CallClass("extern", extern=canon)
        return CallClass("unknown")

    def _collect_edges(self):
        for fi in self.funcs.values():
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                cc = self.classify_call(fi, node)
                if cc.kind == "jit":
                    fi.jit_sites.append((node, cc.jit or JitInfo()))
                    fi.callees |= cc.targets
                elif cc.kind == "pallas":
                    fi.has_pallas = True
                elif cc.kind == "func":
                    fi.callees |= cc.targets
            # nested defs call-contain their parents' reachability
            if fi.parent:
                parent = self.funcs.get(fi.parent)
                if parent is not None:
                    parent.callees.add(fi.qualname)

    def _compute_closures(self):
        # DEVICE: downward closure from jit inner targets + kernel roots
        seeds: Set[str] = set()
        for mod in self.modules.values():
            seeds |= mod.kernel_roots
            for val in mod.symbols.values():
                if val.kind == "jit":
                    seeds |= val.targets
        for fi in self.funcs.values():
            for _, info in fi.jit_sites:
                seeds |= info.targets
            for val in fi.env.values():
                if val.kind == "jit":
                    seeds |= val.targets
        frontier = set(seeds)
        device = set(seeds)
        while frontier:
            nxt: Set[str] = set()
            for q in frontier:
                fn = self.funcs.get(q)
                if fn is None:
                    continue
                for c in fn.callees:
                    if c not in device:
                        device.add(c)
                        nxt.add(c)
            frontier = nxt
        self.device_funcs = device
        # DISPATCHERS: upward closure from direct jit/pallas call sites
        rev: Dict[str, Set[str]] = {}
        for fi in self.funcs.values():
            for c in fi.callees:
                rev.setdefault(c, set()).add(fi.qualname)
        base = {fi.qualname for fi in self.funcs.values()
                if (fi.jit_sites or fi.has_pallas)
                and fi.qualname not in device}
        disp = set(base)
        frontier = set(base)
        while frontier:
            nxt = set()
            for q in frontier:
                for caller in rev.get(q, ()):
                    if caller not in disp and caller not in device:
                        disp.add(caller)
                        nxt.add(caller)
            frontier = nxt
        self.dispatchers = disp

    # -- device-returning fixpoint ---------------------------------------------

    def call_returns_device(self, fi: FuncInfo, call: ast.Call) -> bool:
        cc = self.classify_call(fi, call)
        if cc.kind in ("jit", "pallas"):
            return True
        if cc.kind == "func":
            return bool(cc.targets & self.device_returning)
        if cc.kind == "extern" and cc.extern:
            if cc.extern in _JAX_HOST_EXTERNS:
                return False
            return cc.extern.startswith("jax.")
        return False

    def expr_is_coercion(self, fi: FuncInfo, expr: ast.AST) -> bool:
        """True for calls whose result is host data even if the inputs
        are device buffers: explicit coercions/fetches plus the AOT
        introspection methods (taint stops there)."""
        if not isinstance(expr, ast.Call):
            return False
        if isinstance(expr.func, ast.Attribute) and \
                expr.func.attr in HOST_RESULT_ATTRS:
            return True
        chain = call_name(expr)
        if chain is None:
            return False
        canon = self.canonical(fi.module, chain)
        return canon in HOST_COERCIONS

    def taint_stops(self, fi: FuncInfo, expr: ast.AST) -> Set[int]:
        """Node ids of subtrees under taint-stopping calls inside
        ``expr`` — loads and device-calls there don't taint the result."""
        skip: Set[int] = set()
        for sub in ast.walk(expr):
            if id(sub) in skip:
                continue
            if isinstance(sub, ast.Call) and self.expr_is_coercion(fi, sub):
                for inner in ast.walk(sub):
                    skip.add(id(inner))
        return skip

    def _returns_device(self, fi: FuncInfo) -> bool:
        tainted: Set[Chain] = set()

        def expr_tainted(expr: ast.AST) -> bool:
            if self.expr_is_coercion(fi, expr):
                return False
            skip = self.taint_stops(fi, expr)
            for sub in ast.walk(expr):
                if id(sub) in skip:
                    continue
                if isinstance(sub, ast.Call) and \
                        self.call_returns_device(fi, sub):
                    return True
            for chain, node in loads_in(expr):
                if id(node) in skip:
                    continue
                for t in tainted:
                    if chain[:len(t)] == t:
                        return True
            return False

        hit = False
        for stmt in ast.walk(fi.node):
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = stmt.value
                if value is not None and expr_tainted(value):
                    for c in assign_target_chains(stmt):
                        tainted.add(c)
        for stmt in ast.walk(fi.node):
            if isinstance(stmt, ast.Return) and stmt.value is not None:
                if expr_tainted(stmt.value):
                    hit = True
        return hit

    def _compute_device_returning(self):
        changed = True
        rounds = 0
        while changed and rounds < 6:
            changed = False
            rounds += 1
            for fi in self.funcs.values():
                if fi.qualname in self.device_returning:
                    continue
                # NB: membership in the DEVICE closure alone does not
                # imply device-returning — config/shape helpers called
                # under trace return plain Python data.  Only the
                # structural check (returns something built from jit/
                # pallas/jnp calls) marks a function.
                if self._returns_device(fi):
                    self.device_returning.add(fi.qualname)
                    changed = True
