"""Finding records and text/JSON report rendering."""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import List, Optional


@dataclass
class Finding:
    """One rule violation at a source location.

    ``suppressed`` findings passed an inline ``# focuslint: disable=``
    with a justification; they are reported (under ``--show-suppressed``)
    but never fail the run.
    """
    rule: str
    path: str
    line: int
    message: str
    col: int = 0
    suppressed: bool = False
    justification: Optional[str] = None

    def key(self):
        return (self.path, self.line, self.rule, self.message)


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)
    n_files: int = 0
    n_functions: int = 0

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> List[Finding]:
        return [f for f in self.findings if f.suppressed]

    def extend(self, findings):
        self.findings.extend(findings)

    def sort(self):
        self.findings.sort(key=lambda f: (f.path, f.line, f.rule))

    # -- rendering -------------------------------------------------------------

    def to_json(self, show_suppressed: bool = False) -> str:
        doc = {
            "version": 1,
            "n_files": self.n_files,
            "n_functions": self.n_functions,
            "n_findings": len(self.active),
            "n_suppressed": len(self.suppressed),
            "findings": [asdict(f) for f in self.active],
        }
        if show_suppressed:
            doc["suppressed"] = [asdict(f) for f in self.suppressed]
        return json.dumps(doc, indent=2)

    def to_text(self, show_suppressed: bool = False) -> str:
        lines = []
        for f in self.active:
            lines.append(f"{f.path}:{f.line}:{f.col}: [{f.rule}] "
                         f"{f.message}")
        if show_suppressed:
            for f in self.suppressed:
                why = f" ({f.justification})" if f.justification else ""
                lines.append(f"{f.path}:{f.line}:{f.col}: [{f.rule}] "
                             f"suppressed: {f.message}{why}")
        lines.append(
            f"focuslint: {len(self.active)} finding(s), "
            f"{len(self.suppressed)} suppressed, {self.n_files} file(s) "
            f"scanned")
        return "\n".join(lines)
