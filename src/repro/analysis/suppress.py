"""Inline suppression comments.

Syntax (same line, the line above, or the enclosing ``def`` line for
function scope)::

    x = int(state.n)  # focuslint: disable=host-sync -- bound-gated, once per epoch
    # focuslint: disable=host-sync,retrace-hazard -- staged sync boundary
    # focuslint: disable-file=cache-version -- fixture file

``disable-file`` applies to the whole module.  A ``disable`` without a
``-- justification`` is itself reported (rule ``bare-suppression``): the
point of the annotation is the recorded reason.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.report import Finding

_PAT = re.compile(
    r"#\s*focuslint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_,\s-]+?)"
    r"(?:\s*--\s*(.*?))?\s*$")

ALL = "all"


@dataclass
class _Entry:
    rules: Set[str]
    reason: Optional[str]
    line: int
    file_scope: bool = False


@dataclass
class FileSuppressions:
    """Parsed suppressions for one source file."""
    path: str
    by_line: Dict[int, List[_Entry]] = field(default_factory=dict)
    file_wide: List[_Entry] = field(default_factory=list)

    def lookup(self, rule: str, line: int,
               def_lines: Tuple[int, ...] = ()) -> Optional[_Entry]:
        """Match a finding at ``line`` (inside defs starting at
        ``def_lines``) against: same line, previous line, any enclosing
        def line (or its preceding line), then file-wide entries."""
        candidates = [line, line - 1]
        for d in def_lines:
            candidates += [d, d - 1]
        for ln in candidates:
            for e in self.by_line.get(ln, ()):  # pragma: no branch
                if rule in e.rules or ALL in e.rules:
                    return e
        for e in self.file_wide:
            if rule in e.rules or ALL in e.rules:
                return e
        return None

    def bare_findings(self) -> List[Finding]:
        out = []
        for entries in list(self.by_line.values()) + [self.file_wide]:
            for e in entries:
                if not e.reason:
                    out.append(Finding(
                        rule="bare-suppression", path=self.path,
                        line=e.line,
                        message="suppression without a '-- justification'; "
                                "record why the finding is intentional"))
        return out


def parse_file(path: str, source: str) -> FileSuppressions:
    sup = FileSuppressions(path=path)
    lines = source.splitlines()
    for i, text in enumerate(lines, start=1):
        if "focuslint" not in text:
            continue
        m = _PAT.search(text)
        if not m:
            continue
        kind, rules_raw, reason = m.groups()
        rules = {r.strip() for r in rules_raw.split(",") if r.strip()}
        if not rules:
            continue
        # a comment-only directive may wrap its justification over
        # following comment lines; fold those into the reason and attach
        # the entry to the next code line as well
        attach = [i]
        stripped = text.lstrip()
        if stripped.startswith("#"):
            reason_parts = [reason] if reason else []
            j = i
            while j < len(lines):
                nxt = lines[j].strip()
                if nxt.startswith("#"):
                    if reason_parts:
                        reason_parts.append(nxt.lstrip("# "))
                    j += 1
                elif not nxt:
                    j += 1
                else:
                    attach.append(j + 1)
                    break
            reason = " ".join(p for p in reason_parts if p) or reason
        entry = _Entry(rules=rules, reason=(reason or None), line=i,
                       file_scope=(kind == "disable-file"))
        if entry.file_scope:
            sup.file_wide.append(entry)
        else:
            for ln in attach:
                sup.by_line.setdefault(ln, []).append(entry)
    return sup
