"""``python -m repro.analysis`` — the focuslint CLI."""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.analysis.rules import RULES
from repro.analysis.runner import run_analysis

_EPILOG = """\
rules:
""" + "\n".join(f"  {rid:<24}{desc.splitlines()[0]}"
                for rid, desc in sorted(RULES.items())) + """

suppressing a finding:
  append (or put on the line above, or on the enclosing def line):
      # focuslint: disable=<rule>[,<rule>] -- <one-line justification>
  whole-file scope:
      # focuslint: disable-file=<rule> -- <justification>
  a suppression without the '-- justification' is itself a finding
  (bare-suppression): the recorded reason is the point.

exit status: 0 clean, 1 unsuppressed findings, 2 usage error.

CI runs:  PYTHONPATH=src python -m repro.analysis src benchmarks tests
"""


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="focuslint: static invariant checks for the "
                    "jit/Pallas hot paths (host syncs, donated-buffer "
                    "reads, the kernel==oracle contract, cache-version "
                    "discipline). AST-only: nothing is imported or run.",
        epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("paths", nargs="*", default=["src", "benchmarks",
                                                "tests"],
                   help="files or directories to scan (default: "
                        "src benchmarks tests)")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   help="report format (default: text)")
    p.add_argument("--select", metavar="RULES",
                   help="comma-separated rule ids to report (default: all)")
    p.add_argument("--show-suppressed", action="store_true",
                   help="include suppressed findings in the report")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    p.add_argument("-o", "--output", metavar="FILE",
                   help="write the report to FILE instead of stdout")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rid, desc in sorted(RULES.items()):
            print(f"{rid:<24}{desc}")
        return 0
    select = None
    if args.select:
        select = [r.strip() for r in args.select.split(",") if r.strip()]
        unknown = [r for r in select if r not in RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2
    report = run_analysis(args.paths, select=select)
    text = (report.to_json(args.show_suppressed) if args.format == "json"
            else report.to_text(args.show_suppressed))
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
    else:
        print(text)
    return 1 if report.active else 0
