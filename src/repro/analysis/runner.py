"""File collection, rule dispatch, and suppression application."""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis import suppress
from repro.analysis.callgraph import ProjectIndex
from repro.analysis.report import Finding, Report
from repro.analysis.rules import RULES
from repro.analysis.rules import (cache_version, donation, host_sync,
                                  kernel_contract)

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules"}


def collect_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
                for n in sorted(names):
                    if n.endswith(".py"):
                        out.append(os.path.join(root, n))
    seen, uniq = set(), []
    for p in out:
        key = os.path.normpath(p)
        if key not in seen:
            seen.add(key)
            uniq.append(os.path.normpath(p))
    return uniq


def run_analysis(paths: Sequence[str],
                 select: Optional[Iterable[str]] = None) -> Report:
    files = collect_files(paths)
    sources: List[Tuple[str, str]] = []
    report = Report()
    for path in files:
        try:
            with open(path, "r", encoding="utf-8") as fh:
                sources.append((path, fh.read()))
        except OSError as e:
            report.findings.append(Finding(
                rule="parse-error", path=path, line=1,
                message=f"unreadable: {e}"))
    project = ProjectIndex(sources)
    report.n_files = len(sources)
    report.n_functions = len(project.funcs)
    for path, err in project.parse_errors:
        report.findings.append(Finding(rule="parse-error", path=path,
                                       line=1, message=err))

    findings: List[Finding] = []
    for mod in project.modules.values():
        findings.extend(host_sync.check_module(project, mod))
        findings.extend(donation.check_module(project, mod))
        findings.extend(cache_version.check_module(project, mod))
    findings.extend(kernel_contract.check_project(project))

    sups: Dict[str, suppress.FileSuppressions] = {
        path: suppress.parse_file(path, src) for path, src in sources}
    for f in findings:
        sup = sups.get(f.path)
        if sup is None:
            continue
        entry = sup.lookup(f.rule, f.line, getattr(f, "_def_lines", ()))
        if entry is not None:
            f.suppressed = True
            f.justification = entry.reason
    for sup in sups.values():
        findings.extend(sup.bare_findings())

    if select:
        wanted = set(select)
        findings = [f for f in findings if f.rule in wanted]
    # dedupe (nested walks can revisit a node)
    seen = set()
    for f in findings:
        if f.key() not in seen:
            seen.add(f.key())
            report.findings.append(f)
    report.sort()
    return report


__all__ = ["run_analysis", "collect_files", "RULES"]
