"""Architecture configs (one module per assigned arch) and the registry."""
from repro.configs.registry import ARCH_IDS, get_arch, get_shapes  # noqa: F401
