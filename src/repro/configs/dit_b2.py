"""dit-b2 [diffusion]: img_res=256 patch=2 12L d_model=768 12H.
[arXiv:2212.09748; paper]"""
from repro.common.config import DiTConfig

ARCH = DiTConfig(
    name="dit-b2",
    img_res=256,
    patch=2,
    n_layers=12,
    d_model=768,
    n_heads=12,
)
