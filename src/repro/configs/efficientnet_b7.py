"""efficientnet-b7 [vision]: native img_res=600, width_mult=2.0,
depth_mult=3.1. [arXiv:1905.11946; paper]"""
from repro.common.config import EffNetConfig

ARCH = EffNetConfig(
    name="efficientnet-b7",
    img_res=600,
    width_mult=2.0,
    depth_mult=3.1,
)
