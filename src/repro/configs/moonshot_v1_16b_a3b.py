"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.common.config import LMConfig

ARCH = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=True,
    n_experts=64,
    moe_top_k=6,
    moe_group_size=1024,
    norm="rmsnorm",
    mlp_act="swiglu",
    train_microbatches=4,
)
