"""deit-b [vision]: img_res=224 patch=16 12L d_model=768 12H d_ff=3072,
distillation token. [arXiv:2012.12877; paper]"""
from repro.common.config import ViTConfig

ARCH = ViTConfig(
    name="deit-b",
    img_res=224,
    patch=16,
    n_layers=12,
    d_model=768,
    n_heads=12,
    d_ff=3072,
    distill_token=True,
)
