"""vit-s16 [vision]: img_res=224 patch=16 12L d_model=384 6H d_ff=1536.
Base of the Focus cheap ingest-CNN search space. [arXiv:2010.11929; paper]"""
from repro.common.config import ViTConfig

ARCH = ViTConfig(
    name="vit-s16",
    img_res=224,
    patch=16,
    n_layers=12,
    d_model=384,
    n_heads=6,
    d_ff=1536,
)
