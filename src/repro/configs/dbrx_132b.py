"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base; unverified]"""
from repro.common.config import LMConfig

ARCH = LMConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe=True,
    n_experts=16,
    moe_top_k=4,
    moe_group_size=256,   # §Perf iter 6: dispatch bytes/FLOPs scale with C
    norm="layernorm",
    mlp_act="swiglu",
    train_microbatches=8,
)
