"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (OLMo's signature). [arXiv:2402.00838; hf]"""
from repro.common.config import LMConfig

ARCH = LMConfig(
    name="olmo-1b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric_ln",
    mlp_act="swiglu",
    tie_embeddings=True,     # OLMo-1B ties input/output embeddings
)
