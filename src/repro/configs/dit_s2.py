"""dit-s2 [diffusion]: img_res=256 patch=2 12L d_model=384 6H.
[arXiv:2212.09748; paper]"""
from repro.common.config import DiTConfig

ARCH = DiTConfig(
    name="dit-s2",
    img_res=256,
    patch=2,
    n_layers=12,
    d_model=384,
    n_heads=6,
)
