"""Registry mapping --arch ids to their config modules."""
from __future__ import annotations

import importlib

from repro.common.config import shapes_for

ARCH_IDS = [
    "dbrx-132b",
    "moonshot-v1-16b-a3b",
    "olmo-1b",
    "granite-34b",
    "dit-b2",
    "dit-s2",
    "vit-l16",
    "deit-b",
    "efficientnet-b7",
    "vit-s16",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_") for a in ARCH_IDS}


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.ARCH


def get_shapes(arch_id: str):
    return shapes_for(get_arch(arch_id))
