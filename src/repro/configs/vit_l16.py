"""vit-l16 [vision]: img_res=224 patch=16 24L d_model=1024 16H d_ff=4096.
Default Focus GT-CNN. [arXiv:2010.11929; paper]"""
from repro.common.config import ViTConfig

ARCH = ViTConfig(
    name="vit-l16",
    img_res=224,
    patch=16,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    d_ff=4096,
)
