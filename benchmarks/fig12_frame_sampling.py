"""Fig. 12/13: sensitivity to frame sampling rate (30/10/5/1 fps analog:
frame_stride 1/3/6/30 over the 30fps-equivalent stream).

Two sections:

  * analytic — the paper-trend policy ratios (I/Q vs GT) at each stride,
    unchanged from the original bench;
  * measured — the real redundancy gate + frame stride running against a
    static-camera synthetic stream through a jitted CheapCNN, reporting
    objects/sec, skip-rate, and recall vs *ungated* ingest at each
    stride into the BENCH_sampling.json trajectory.

The measured stream is adversarial for the §4.2 consecutive-frame
tracker and ideal for the gate: objects blink with period 3 (visible on
frames where ``(f + k) % 3 == 0``), so the tracker never matches them
but the gate's ring bridges the gaps. Ungated ingest therefore pays the
CNN for every arrival; gated ingest pays it once per distinct object.

Recall is reported two ways: ``recall_frames`` (returned-frame overlap
vs ungated — drops with stride, the Fig. 12 trade-off) and
``recall_objects`` (distinct ground-truth objects still reachable — the
pinned bound; stays 1.0 on a static camera while objects/sec multiplies).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import append_trajectory, emit, policy_ratios

STREAMS = ("auburn_c", "lausanne")
FPS_STRIDES = {30: 1, 10: 3, 5: 6, 1: 30}

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_sampling.json")

N_FRAMES = 600
N_BASE = 12                   # distinct ground-truth objects on the camera
RES = 32
N_CLASSES = 16
BATCH = 64
STRIDES = (1, 2, 5, 10)
RECALL_BOUND = 0.97           # pinned object-recall bound (CI gate)


def _make_static_stream(seed: int = 0):
    """Static camera, blinking objects: object k is visible on frames
    with ``(f + k) % 3 == 0`` as an EXACT copy of its base crop
    (threshold-safe for the gate), never on consecutive frames."""
    r = np.random.default_rng(seed)
    base = r.random((N_BASE, RES, RES, 3)).astype(np.float32)
    cls = (np.arange(N_BASE) % N_CLASSES).astype(np.int64)
    base[:, 0, 0, 0] = cls / N_CLASSES        # class encoded in one pixel
    crops, frames, owner = [], [], []
    for f in range(N_FRAMES):
        for k in range(N_BASE):
            if (f + k) % 3 == 0:
                crops.append(base[k].copy())
                frames.append(f)
                owner.append(k)
    return (np.stack(crops), np.array(frames, np.int64),
            np.array(owner, np.int64), cls)


def _real_cnn():
    """Jitted random-weight CheapCNN with a fixed padded batch shape (one
    compile, warmed before timing) — the CNN cost being gated away is a
    real conv forward pass, not a numpy stub."""
    import jax

    from repro.common.config import CheapCNNConfig
    from repro.models import cnn

    cfg = CheapCNNConfig("fig12", input_res=RES, n_blocks=4, width=32,
                         n_classes=N_CLASSES, feature_dim=64)
    params = cnn.init(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def fwd(x):
        logits, feats = cnn.forward(params, x, cfg)
        return jax.nn.softmax(logits, axis=-1), feats

    def apply_fn(batch):
        n = len(batch)
        if n < BATCH:
            batch = np.concatenate(
                [batch, np.zeros((BATCH - n,) + batch.shape[1:],
                                 batch.dtype)])
        probs, feats = fwd(batch)
        # focuslint: disable=host-sync -- bench apply contract returns
        # host arrays; the per-batch sync is part of the measured cost
        return np.asarray(probs)[:n], np.asarray(feats)[:n]

    apply_fn(np.zeros((BATCH, RES, RES, 3), np.float32))   # warm the jit
    return apply_fn, float(cfg.flops_per_image())


def _class_frames(index):
    return {c: set(np.asarray(index.frames_of(index.lookup(c))).tolist())
            for c in range(N_CLASSES)}


def _object_hits(by_class, owner, frames, cls):
    """Distinct ground-truth objects reachable through the index: object
    k is found when any frame it appears in is returned for its class."""
    found = set()
    for k in range(N_BASE):
        mine = set(frames[owner == k].tolist())
        if mine & by_class.get(int(cls[k]), set()):
            found.add(k)
    return found


def run_measured():
    from repro.core.ingest import IngestConfig, ingest

    crops, frames, owner, cls = _make_static_stream()
    apply_fn, flops = _real_cnn()
    n_total = len(crops)

    def run_cfg(gate: bool, stride: int):
        cfg = IngestConfig(K=4, threshold=0.5, max_clusters=256,
                           batch_size=BATCH, gate=gate,
                           gate_threshold=0.01, frame_stride=stride)
        t0 = time.perf_counter()
        index, stats = ingest(crops, frames, apply_fn, flops, cfg,
                              n_local_classes=N_CLASSES)
        wall = time.perf_counter() - t0
        return index, stats, wall

    idx_un, st_un, wall_un = run_cfg(gate=False, stride=1)
    ref = _class_frames(idx_un)
    ref_objects = _object_hits(ref, owner, frames, cls)
    un_ops = n_total / wall_un

    configs = []
    for stride in STRIDES:
        index, stats, wall = run_cfg(gate=True, stride=stride)
        got = _class_frames(index)
        n_ref_frames = sum(len(v) for v in ref.values())
        n_hit_frames = sum(len(got[c] & ref[c]) for c in ref)
        found = _object_hits(got, owner, frames, cls)
        skipped = (stats.n_pixel_dedup + stats.n_gate_skipped
                   + stats.n_sampled_out)
        configs.append({
            "stride": stride,
            "objects_per_sec": round(n_total / wall, 1),
            "wall_s": round(wall, 4),
            "n_cnn_invocations": int(stats.n_cnn_invocations),
            "skip_rate": round(skipped / n_total, 4),
            "cnn_frac": round(stats.n_cnn_invocations / n_total, 4),
            "recall_frames": round(n_hit_frames / max(1, n_ref_frames), 4),
            "recall_objects": round(len(found & ref_objects)
                                    / max(1, len(ref_objects)), 4),
            "speedup": round((n_total / wall) / un_ops, 2),
        })
        emit(f"fig12.gated.stride_{stride}", wall * 1e6,
             f"objs_per_s={n_total / wall:.0f}"
             f"|skip_rate={skipped / n_total:.3f}"
             f"|recall_obj={configs[-1]['recall_objects']:.3f}"
             f"|speedup={configs[-1]['speedup']:.2f}x")

    within = [c for c in configs if c["recall_objects"] >= RECALL_BOUND]
    best = max(within, key=lambda c: c["objects_per_sec"]) if within else None
    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n_objects": n_total,
        "recall_bound": RECALL_BOUND,
        "ungated": {
            "objects_per_sec": round(un_ops, 1),
            "wall_s": round(wall_un, 4),
            "n_cnn_invocations": int(st_un.n_cnn_invocations),
        },
        "configs": configs,
        "best_within_bound": best,
    }
    append_trajectory(BENCH_PATH, record)
    emit("fig12.ungated", wall_un * 1e6, f"objs_per_s={un_ops:.0f}"
         f"|cnn={st_un.n_cnn_invocations}")
    assert best is not None, \
        f"no gated config meets object recall >= {RECALL_BOUND}"
    assert best["speedup"] >= 2.0, \
        f"gated ingest under 2x at recall bound: {best}"
    g1 = configs[0]
    assert g1["recall_frames"] >= 0.999, \
        f"stride-1 gate changed returned frames: {g1}"
    assert g1["objects_per_sec"] >= un_ops, \
        f"stride-1 gated slower than ungated: {g1} vs {un_ops:.0f}"


def run():
    for fps_label, stride in FPS_STRIDES.items():
        Is, Qs = [], []
        for s in STREAMS:
            r = policy_ratios(s, "balance", fps=30, frame_stride=stride)
            Is.append(r["I"])
            Qs.append(r["Q"])
        emit(f"fig12.fps_{fps_label}", 0.0,
             f"I_avg={np.mean(Is):.0f}x|Q_avg={np.mean(Qs):.0f}x"
             f"|paper_trend=I~const(58-64x),Q_drops_at_low_fps")
    run_measured()


if __name__ == "__main__":
    run()
