"""Fig. 12/13: sensitivity to frame sampling rate (30/10/5/1 fps analog:
frame_stride 1/3/6/30 over the 30fps-equivalent stream)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, policy_ratios

STREAMS = ("auburn_c", "lausanne")
STRIDES = {30: 1, 10: 3, 5: 6, 1: 30}


def run():
    for fps_label, stride in STRIDES.items():
        Is, Qs = [], []
        for s in STREAMS:
            r = policy_ratios(s, "balance", fps=30, frame_stride=stride)
            Is.append(r["I"])
            Qs.append(r["Q"])
        emit(f"fig12.fps_{fps_label}", 0.0,
             f"I_avg={np.mean(Is):.0f}x|Q_avg={np.mean(Qs):.0f}x"
             f"|paper_trend=I~const(58-64x),Q_drops_at_low_fps")


if __name__ == "__main__":
    run()
