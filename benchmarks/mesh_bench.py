"""Sharded multi-stream ingest scaling (DESIGN.md §13) -> BENCH_mesh.json.

Eight camera streams ingest through ONE ``ShardedIngestPipeline`` over a
1/2/4/8-device ``("data",)`` mesh (simulated host devices — this bench
exports ``--xla_force_host_platform_device_count=8`` before the first
jax import) and are compared against the pre-refactor multi-stream
deployment (PR 3): a staged ``MultiStreamRunner`` stacking ready batches
through one shared cheap-CNN executable with host-side clustering per
per-stream batch. A second reference row runs each stream's own fused
``IngestPipeline`` chain back to back (the PR-5 single-stream path).

Reported per row: objects/sec, device dispatches per per-stream batch,
and stacked steps. Honest scaling note: the container's forced host
devices share one CPU core, so the sharded rows' win over the baseline
is DISPATCH AMORTIZATION — S streams advance per stacked megastep
(1-2 dispatches, one (j, matched) fetch) instead of S separate
host-staged cluster folds — not hardware parallelism; on real
multi-chip meshes the same layout adds per-device compute overlap on
top.

Gates (CI):
  * identity: every sharded row saves byte-identical per-stream indexes
    (and equal eviction counts) to the single-device references;
  * speedup: sharded @ 4 devices >= 1.5x the pre-refactor staged
    baseline's objects/sec.

One record per run is appended to the BENCH_mesh.json trajectory.
"""
from __future__ import annotations

import os
import sys

# the bench is its own entry point: force 8 host devices BEFORE jax loads
if "jax" not in sys.modules:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np

import jax

from benchmarks.common import append_trajectory, emit
from repro.core.ingest import IngestConfig
from repro.core.pipeline import IngestPipeline, staged_cheap_apply
from repro.core.streaming import (MultiStreamRunner, StreamingIngestor,
                                  make_sharded_runner)
from repro.launch.mesh import make_ingest_mesh

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_mesh.json")

N_STREAMS = 8
N_OBJECTS = 1024              # per stream
CHUNK = 256                   # objects fed per stream per round
BATCH = 64
FEAT_DIM = 48
N_CLASSES = 12
DEVICE_COUNTS = (1, 2, 4, 8)
REPS = 3

CFG = IngestConfig(K=2, threshold=1.2, max_clusters=128, batch_size=BATCH,
                   high_water=0.9, evict_frac=0.25)


def _cheap_fn(crops):
    """Jax-traceable per-example-pure cheap-CNN stand-in."""
    flat = crops.reshape(crops.shape[0], -1)
    feats = flat[:, :FEAT_DIM] * 8.0
    probs = jax.nn.softmax(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES] * 4.0,
                           axis=-1)
    return probs, feats


def _make_stream(seed: int):
    r = np.random.default_rng(seed)
    modes = r.random((40, 8, 8, 3)).astype(np.float32)
    pick = r.integers(0, 40, N_OBJECTS)
    crops = np.clip(modes[pick] + r.normal(0, 0.03,
                                           (N_OBJECTS, 8, 8, 3)),
                    0, 1).astype(np.float32)
    frames = np.sort(r.integers(0, N_OBJECTS // 6, N_OBJECTS))
    return crops, frames


def _rounds(streams):
    """Interleaved rounds of CHUNK objects per stream (same schedule for
    every row, so wall clocks compare like for like)."""
    for lo in range(0, N_OBJECTS, CHUNK):
        yield {nm: (c[lo:lo + CHUNK], f[lo:lo + CHUNK])
               for nm, (c, f) in streams.items()}


def run_staged_baseline(streams):
    """Pre-refactor multi-stream deployment (PR 3): staged runner, one
    stacked cheap-CNN pass per step, host clustering per stream batch."""
    ings = {nm: StreamingIngestor(None, 1e9, CFG) for nm in streams}
    runner = MultiStreamRunner(ings,
                               cheap_apply=staged_cheap_apply(_cheap_fn,
                                                              CFG),
                               batch_pad=BATCH)
    t0 = time.perf_counter()
    for feeds in _rounds(streams):
        runner.feed(feeds)
    out = runner.finish()
    wall = time.perf_counter() - t0
    n_batch = sum(ing.stats.n_cnn_invocations // BATCH
                  for ing in ings.values())
    return out, wall, n_batch


def run_per_stream_pipeline(streams):
    """PR-5 single-stream fused path, streams run round-robin on one
    device: S separate dispatch chains."""
    ings = {nm: StreamingIngestor(None, 1e9, CFG,
                                  pipeline=IngestPipeline(_cheap_fn, CFG))
            for nm in streams}
    t0 = time.perf_counter()
    for feeds in _rounds(streams):
        for nm, (c, f) in feeds.items():
            ings[nm].feed(c, f)
    out = {nm: ing.finish() for nm, ing in ings.items()}
    wall = time.perf_counter() - t0
    n_disp = sum(ing.pipeline.stats.n_dispatches for ing in ings.values())
    n_batch = sum(ing.pipeline.stats.n_batches for ing in ings.values())
    return out, wall, n_disp / max(n_batch, 1)


def run_sharded(streams, mesh):
    runner = make_sharded_runner(_cheap_fn, mesh, list(streams), cfg=CFG,
                                 cheap_flops_per_image=1e9)
    t0 = time.perf_counter()
    for feeds in _rounds(streams):
        runner.feed(feeds)
    out = runner.finish()
    wall = time.perf_counter() - t0
    st = runner.pipeline.stats
    return out, wall, st.n_dispatches / max(st.n_batches, 1), st.n_steps


def run():
    avail = jax.device_count()
    streams = {f"cam{i}": _make_stream(100 + i) for i in range(N_STREAMS)}
    total = N_STREAMS * N_OBJECTS

    record = {"ts": time.time(), "n_streams": N_STREAMS,
              "objects_per_stream": N_OBJECTS, "batch_size": BATCH,
              "devices_visible": avail, "rows": []}

    # pre-refactor staged baseline (the speedup reference)
    walls = []
    for _ in range(REPS):
        ref_out, wall, n_batch = run_staged_baseline(streams)
        walls.append(wall)
    wall = float(np.median(walls))
    base_rate = total / wall
    emit("mesh.baseline_staged_1dev", wall * 1e6 / max(n_batch, 1),
         f"objs_per_s={base_rate:.0f}|mode=pre_refactor_staged_runner")
    record["rows"].append({"mode": "staged_baseline", "devices": 1,
                           "objs_per_s": base_rate})

    # PR-5 per-stream fused chains (identity reference + context row)
    walls = []
    for _ in range(REPS):
        pipe_out, wall, dpb = run_per_stream_pipeline(streams)
        walls.append(wall)
    wall = float(np.median(walls))
    pipe_rate = total / wall
    for nm in streams:
        assert pipe_out[nm][0].save_bytes() == \
            ref_out[nm][0].save_bytes(), f"pipeline vs staged: {nm}"
    emit("mesh.per_stream_pipeline_1dev", 0.0,
         f"objs_per_s={pipe_rate:.0f}|dispatches_per_batch={dpb:.2f}"
         f"|per_stream_chains={N_STREAMS}|identical=True")
    record["rows"].append({"mode": "per_stream_pipeline", "devices": 1,
                           "objs_per_s": pipe_rate,
                           "dispatches_per_batch": dpb,
                           "identical": True})

    rates = {}
    for ndev in DEVICE_COUNTS:
        if ndev > avail:
            emit(f"mesh.sharded_{ndev}dev", 0.0,
                 f"skipped|only_{avail}_devices_visible")
            continue
        mesh = make_ingest_mesh(ndev)
        walls, out = [], None
        for _ in range(REPS):
            out, wall, dpb, n_steps = run_sharded(streams, mesh)
            walls.append(wall)
        wall = float(np.median(walls))
        rate = total / wall
        rates[ndev] = rate

        # identity gate: byte-identical per stream to the baseline
        identical = all(
            out[nm][0].save_bytes() == ref_out[nm][0].save_bytes()
            and out[nm][1].n_evictions == ref_out[nm][1].n_evictions
            for nm in streams)
        assert identical, f"sharded@{ndev}dev diverged from baseline"
        emit(f"mesh.sharded_{ndev}dev", wall * 1e6 / max(n_steps, 1),
             f"objs_per_s={rate:.0f}|dispatches_per_batch={dpb:.2f}"
             f"|stacked_steps={n_steps}|speedup_vs_baseline="
             f"{rate / base_rate:.2f}x|identical=True")
        record["rows"].append({"mode": "sharded", "devices": ndev,
                               "objs_per_s": rate,
                               "dispatches_per_batch": dpb,
                               "stacked_steps": n_steps,
                               "speedup_vs_baseline": rate / base_rate,
                               "identical": True})

    # speedup gate: the acceptance bar for the refactor
    if 4 in rates:
        speedup = rates[4] / base_rate
        assert speedup >= 1.5, (
            f"sharded@4dev only {speedup:.2f}x the single-device baseline "
            f"(gate: >= 1.5x)")
        record["gate_speedup_4dev"] = speedup
    append_trajectory(BENCH_PATH, record)


if __name__ == "__main__":
    run()
