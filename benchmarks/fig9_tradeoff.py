"""Fig. 9 (and Fig. 1 zoom): Opt-Ingest vs Opt-Query (I, Q) per stream."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, policy_ratios

STREAMS = ("auburn_c", "auburn_r", "jacksonh", "lausanne", "cnn")


def run():
    agg = {"opt_ingest": ([], []), "opt_query": ([], [])}
    for s in STREAMS:
        for policy in ("opt_ingest", "opt_query"):
            r = policy_ratios(s, policy)
            agg[policy][0].append(r["I"])
            agg[policy][1].append(r["Q"])
            emit(f"fig9.{policy}.{s}", 0.0,
                 f"I={r['I']:.0f}x|Q={r['Q']:.0f}x"
                 f"|P={r['precision']:.3f}|R={r['recall']:.3f}")
    for policy, (Is, Qs) in agg.items():
        emit(f"fig9.{policy}.average", 0.0,
             f"I_avg={np.mean(Is):.0f}x|Q_avg={np.mean(Qs):.0f}x"
             f"|paper_optI=I95x,Q35x|paper_optQ=I15x,Q49x")


if __name__ == "__main__":
    run()
