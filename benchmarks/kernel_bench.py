"""Kernel micro-benchmarks: wall-time of the Pallas kernels (interpret mode
on CPU — structural validation) vs the pure-jnp reference, plus the
clustering throughput of all three implementations (scan / batched /
fused)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import clustering as C
from repro.kernels import ops, ref


def _time(fn, *args, n=5):
    fn(*args)                       # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)

    f = jax.random.normal(k1, (512, 128))
    c = jax.random.normal(k2, (1024, 128))
    us_k = _time(lambda a, b: ops.centroid_assign(a, b)[0], f, c)
    us_r = _time(lambda a, b: ref.centroid_assign_ref(a, b)[0], f, c)
    emit("kernel.centroid_assign.512x1024x128", us_k,
         f"ref_us={us_r:.0f}|interpret_overhead={us_k/us_r:.1f}x")

    lg = jax.random.normal(k3, (256, 1000))
    us_k = _time(lambda a: ops.topk(a, 20)[0], lg)
    us_r = _time(lambda a: ref.topk_ref(a, 20)[0], lg)
    emit("kernel.topk.256x1000.k20", us_k, f"ref_us={us_r:.0f}")

    q = jax.random.normal(k1, (2, 256, 4, 64))
    kk = jax.random.normal(k2, (2, 256, 4, 64))
    v = jax.random.normal(k3, (2, 256, 4, 64))
    us_k = _time(lambda a, b, cc: ops.flash_attention(a, b, cc), q, kk, v)
    us_r = _time(lambda a, b, cc: ref.flash_attention_ref(a, b, cc), q, kk, v)
    emit("kernel.flash_attention.2x256x4x64", us_k, f"ref_us={us_r:.0f}")

    # clustering throughput: scan vs batched vs fused on a video-shaped
    # workload (mode-based features: most objects rejoin existing clusters,
    # as with consecutive frames of the same object) against the production
    # table size (M=2048, the max_clusters used by the stream sweeps). All
    # three are timed with a warmup call so compile time is excluded — the
    # same contract as _time() above.
    r = np.random.default_rng(0)
    modes = r.normal(0, 8.0, (60, 128))
    pick = r.integers(0, 60, 2048 + 256)
    feats_all = (modes[pick] + r.normal(0, 0.02, (2048 + 256, 128))) \
        .astype(np.float32)
    warm, feats = feats_all[:256], feats_all[256:]
    st0 = C.init_state(2048, 128)
    st0, _ = C.cluster_scan(st0, warm, 1.0)     # pre-populate the table
    us = {name: _time(lambda a, b, fn=fn: fn(a, b, 1.0)[1], st0, feats, n=3)
          for name, fn in C.CLUSTER_FNS.items()}
    emit("cluster.scan_vs_batched.2048x128", us["batched"],
         f"scan_us={us['scan']:.0f}|speedup={us['scan']/us['batched']:.2f}x")
    emit("cluster.fused.2048x128", us["fused"],
         f"scan_us={us['scan']:.0f}|speedup={us['scan']/us['fused']:.2f}x")


if __name__ == "__main__":
    run()
