"""Fig. 10/11: sensitivity to the accuracy target (95/97/98/99%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, policy_ratios

STREAMS = ("auburn_c", "lausanne", "cnn")
TARGETS = (0.95, 0.97, 0.98, 0.99)


def run():
    for tgt in TARGETS:
        Is, Qs = [], []
        for s in STREAMS:
            r = policy_ratios(s, "balance", precision_target=tgt,
                              recall_target=tgt)
            Is.append(r["I"])
            Qs.append(r["Q"])
        emit(f"fig10.target_{int(tgt*100)}", 0.0,
             f"I_avg={np.mean(Is):.0f}x|Q_avg={np.mean(Qs):.0f}x"
             f"|paper_trend=I~const,Q:37->8x")


if __name__ == "__main__":
    run()
