"""Ingest throughput: driver-only (clustering variants) and end-to-end.

Two sections, one BENCH_ingest.json record:

* ``variants`` — the ``ingest()`` driver hot path (clustering, slot ->
  cid bookkeeping, SoA ClusterStore updates, eviction) with a precomputed
  cheap-CNN stub, isolating the driver from CNN compute exactly as the
  paper pipelines clustering (CPU) behind the CNN (GPU) in §6.3.
* ``e2e`` — crops in -> index rows out with a REAL cheap CNN, comparing
  the host-staged path (jitted forward, numpy round-trips between CNN /
  top-K / clustering) against the fused ``IngestPipeline`` megastep
  (DESIGN.md §9). Reports objects/sec for both, the fused path's device
  dispatches per batch, its compile-cache hit/miss counts, and whether
  the two paths saved byte-identical indexes — all gated in CI.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core.ingest import IngestConfig, ingest

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_ingest.json")

N_OBJECTS = 8192
FEAT_DIM = 128
N_CLASSES = 32
N_MODES = 120
MAX_CLUSTERS = 1024

E2E_OBJECTS = 2048
E2E_RES = 16
E2E_BATCH = 256
E2E_REPS = 9


def _synthetic_stream(seed: int = 0):
    """Video-shaped object stream: mode-based features (objects re-appear
    across consecutive frames), tiny crops, soft class probs per mode."""
    r = np.random.default_rng(seed)
    modes = r.normal(0, 8.0, (N_MODES, FEAT_DIM))
    mode_cls = r.integers(0, N_CLASSES, N_MODES)
    pick = r.integers(0, N_MODES, N_OBJECTS)
    feats = (modes[pick] + r.normal(0, 0.05, (N_OBJECTS, FEAT_DIM))
             ).astype(np.float32)
    probs = np.full((N_OBJECTS, N_CLASSES), 0.02, np.float32)
    probs[np.arange(N_OBJECTS), mode_cls[pick]] = 0.9
    probs /= probs.sum(1, keepdims=True)
    crops = r.normal(0, 1, (N_OBJECTS, 8, 8, 3)).astype(np.float32)
    frames = np.repeat(np.arange(N_OBJECTS // 8), 8)[:N_OBJECTS]
    return crops, frames, feats, probs


def run():
    crops, frames, feats, probs = _synthetic_stream()

    def make_apply():
        # precomputed CNN outputs served in stream order (the driver calls
        # in order over pixel-diff-unique objects; batches never overlap)
        cursor = [0]

        def apply_fn(batch):
            i = cursor[0]
            cursor[0] = i + len(batch)
            return probs[i:i + len(batch)], feats[i:i + len(batch)]
        return apply_fn

    record = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
              "n_objects": N_OBJECTS, "variants": {}}
    for variant in ("scan", "batched", "fused"):
        cfg = IngestConfig(K=4, threshold=1.0, max_clusters=MAX_CLUSTERS,
                           batch_size=2048, pixel_diff=False,
                           clustering=variant)
        # warmup run: compile everything, then measure a fresh run
        ingest(crops, frames, make_apply(), 1e9, cfg)
        t0 = time.perf_counter()
        index, stats = ingest(crops, frames, make_apply(), 1e9, cfg)
        wall = time.perf_counter() - t0
        objs_per_s = N_OBJECTS / wall
        record["variants"][variant] = {
            "objects_per_sec": round(objs_per_s, 1),
            "wall_s": round(wall, 4),
            "n_clusters": index.n_clusters,
        }
        emit(f"ingest.{variant}.{N_OBJECTS}x{FEAT_DIM}", wall * 1e6,
             f"objs_per_s={objs_per_s:.0f}|n_clusters={index.n_clusters}")
    record["e2e"] = run_e2e()
    append_trajectory(BENCH_PATH, record)


def _e2e_stream(seed: int = 1):
    """Video-shaped crop stream at full CNN input resolution."""
    r = np.random.default_rng(seed)
    modes = r.random((N_MODES, E2E_RES, E2E_RES, 3)).astype(np.float32)
    pick = r.integers(0, N_MODES, E2E_OBJECTS)
    crops = np.clip(modes[pick]
                    + r.normal(0, 0.03, (E2E_OBJECTS, E2E_RES, E2E_RES, 3)),
                    0, 1).astype(np.float32)
    frames = np.repeat(np.arange(E2E_OBJECTS // 8), 8)[:E2E_OBJECTS]
    return crops, frames


def run_e2e() -> dict:
    """Crops -> index rows, host-staged vs fused-megastep pipeline.

    Both paths produce the same artifacts: the saved index AND the
    per-object top-K classes (the staged path runs the top-K kernel as
    its own dispatch with a host round-trip, exactly the staging the
    megastep removes). Gated timings are the median over ``E2E_REPS``
    interleaved runs — wall noise in this container swamps a single
    measurement, and a min is hostage to one lucky rep of either path
    (``best_speedup`` reports the min-based ratio for reference).
    """
    import jax
    import jax.numpy as jnp

    from repro.common.config import CheapCNNConfig
    from repro.core.pipeline import IngestPipeline, staged_cheap_apply
    from repro.core.streaming import StreamingIngestor
    from repro.kernels import ops as kops
    from repro.models import cnn

    cnn_cfg = CheapCNNConfig("bench_e2e", input_res=E2E_RES, n_blocks=3,
                             width=24, n_classes=32, feature_dim=FEAT_DIM)
    params = cnn.init(jax.random.PRNGKey(0), cnn_cfg)

    def cheap_fn(crops):
        logits, feats = cnn.forward(params, crops, cnn_cfg)
        return jax.nn.softmax(logits, axis=-1), feats

    cfg = IngestConfig(K=4, threshold=1.0, max_clusters=MAX_CLUSTERS,
                       batch_size=E2E_BATCH, pixel_diff=False)
    flops = float(cnn.flops_per_image(cnn_cfg))
    crops, frames = _e2e_stream()

    def run_staged():
        base = staged_cheap_apply(cheap_fn, cfg)
        topk_out = []

        def apply(batch):
            probs, feats = base(batch)
            vals, idxs = kops.topk(jnp.asarray(probs), cfg.K)
            # focuslint: disable=host-sync -- bench records top-K on
            # host; the sync is the measured staged-path cost
            topk_out.append((np.asarray(vals), np.asarray(idxs)))
            return probs, feats

        ing = StreamingIngestor(apply, flops, cfg)
        for s in range(0, len(crops), 4 * E2E_BATCH):
            ing.feed(crops[s:s + 4 * E2E_BATCH],
                     frames[s:s + 4 * E2E_BATCH])
        return ing.finish()[0], None

    def run_pipeline():
        topk_out = []
        pipe = IngestPipeline(
            cheap_fn, cfg,
            topk_sink=lambda objs, vals, idxs: topk_out.append((vals, idxs)))
        ing = StreamingIngestor(None, flops, cfg, pipeline=pipe)
        for s in range(0, len(crops), 4 * E2E_BATCH):
            ing.feed(crops[s:s + 4 * E2E_BATCH],
                     frames[s:s + 4 * E2E_BATCH])
        return ing.finish()[0], pipe

    # warmup (compiles both paths' executables), then interleaved timing
    staged_index, _ = run_staged()
    pipe_index, _ = run_pipeline()
    identical = staged_index.save_bytes() == pipe_index.save_bytes()
    walls = {"staged": [], "pipeline": []}
    pipe = None
    for _ in range(E2E_REPS):
        for name, fn in (("staged", run_staged), ("pipeline", run_pipeline)):
            t0 = time.perf_counter()
            _, p = fn()
            walls[name].append(time.perf_counter() - t0)
            if p is not None:
                pipe = p
    # median over interleaved reps: robust to the one-off wall-clock
    # spikes this container produces (a min is hostage to a single lucky
    # rep of either path)
    staged_ops = E2E_OBJECTS / float(np.median(walls["staged"]))
    pipe_ops = E2E_OBJECTS / float(np.median(walls["pipeline"]))
    result = {
        "n_objects": E2E_OBJECTS,
        "input_res": E2E_RES,
        "staged_objs_per_sec": round(staged_ops, 1),
        "pipeline_objs_per_sec": round(pipe_ops, 1),
        "speedup": round(pipe_ops / staged_ops, 3),
        "best_speedup": round(min(walls["staged"]) / min(walls["pipeline"]),
                              3),
        "dispatches_per_batch": round(pipe.stats.dispatches_per_batch, 3),
        "compile_misses": pipe.stats.compile_misses,
        "compile_hits": pipe.stats.compile_hits,
        "tail_compile_misses": pipe.stats.tail_compile_misses,
        "tail_compile_hits": pipe.stats.tail_compile_hits,
        # real XLA trace-cache entries across the whole bench process —
        # a retrace (shape/dtype/weak-type drift) shows up here even when
        # the (bucket, res) key counters stay clean
        "megastep_jit_entries": pipe.jit_cache_entries()["megastep"],
        "tail_jit_entries": pipe.jit_cache_entries()["tail"],
        "identical": identical,
    }
    emit(f"ingest.e2e.staged.{E2E_OBJECTS}x{E2E_RES}px",
         float(np.median(walls["staged"])) * 1e6,
         f"objs_per_s={staged_ops:.0f}")
    emit(f"ingest.e2e.pipeline.{E2E_OBJECTS}x{E2E_RES}px",
         float(np.median(walls["pipeline"])) * 1e6,
         f"objs_per_s={pipe_ops:.0f}|speedup={pipe_ops / staged_ops:.2f}"
         f"|dispatches_per_batch={pipe.stats.dispatches_per_batch:.2f}"
         f"|identical={identical}")
    return result


if __name__ == "__main__":
    run()
