"""End-to-end ingest driver throughput (objects/sec).

Measures the full ``ingest()`` hot path — clustering, slot -> cid
bookkeeping, SoA ClusterStore updates, eviction — with a precomputed
cheap-CNN stub, isolating the driver from CNN compute exactly as the paper
pipelines clustering (CPU) behind the CNN (GPU) in §6.3. One record per
clustering variant is appended to the BENCH_ingest.json trajectory so
future perf PRs are measured against this one.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core.ingest import IngestConfig, ingest

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_ingest.json")

N_OBJECTS = 8192
FEAT_DIM = 128
N_CLASSES = 32
N_MODES = 120
MAX_CLUSTERS = 1024


def _synthetic_stream(seed: int = 0):
    """Video-shaped object stream: mode-based features (objects re-appear
    across consecutive frames), tiny crops, soft class probs per mode."""
    r = np.random.default_rng(seed)
    modes = r.normal(0, 8.0, (N_MODES, FEAT_DIM))
    mode_cls = r.integers(0, N_CLASSES, N_MODES)
    pick = r.integers(0, N_MODES, N_OBJECTS)
    feats = (modes[pick] + r.normal(0, 0.05, (N_OBJECTS, FEAT_DIM))
             ).astype(np.float32)
    probs = np.full((N_OBJECTS, N_CLASSES), 0.02, np.float32)
    probs[np.arange(N_OBJECTS), mode_cls[pick]] = 0.9
    probs /= probs.sum(1, keepdims=True)
    crops = r.normal(0, 1, (N_OBJECTS, 8, 8, 3)).astype(np.float32)
    frames = np.repeat(np.arange(N_OBJECTS // 8), 8)[:N_OBJECTS]
    return crops, frames, feats, probs


def run():
    crops, frames, feats, probs = _synthetic_stream()

    def make_apply():
        # precomputed CNN outputs served in stream order (the driver calls
        # in order over pixel-diff-unique objects; batches never overlap)
        cursor = [0]

        def apply_fn(batch):
            i = cursor[0]
            cursor[0] = i + len(batch)
            return probs[i:i + len(batch)], feats[i:i + len(batch)]
        return apply_fn

    record = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
              "n_objects": N_OBJECTS, "variants": {}}
    for variant in ("scan", "batched", "fused"):
        cfg = IngestConfig(K=4, threshold=1.0, max_clusters=MAX_CLUSTERS,
                           batch_size=2048, pixel_diff=False,
                           clustering=variant)
        # warmup run: compile everything, then measure a fresh run
        ingest(crops, frames, make_apply(), 1e9, cfg)
        t0 = time.perf_counter()
        index, stats = ingest(crops, frames, make_apply(), 1e9, cfg)
        wall = time.perf_counter() - t0
        objs_per_s = N_OBJECTS / wall
        record["variants"][variant] = {
            "objects_per_sec": round(objs_per_s, 1),
            "wall_s": round(wall, 4),
            "n_clusters": index.n_clusters,
        }
        emit(f"ingest.{variant}.{N_OBJECTS}x{FEAT_DIM}", wall * 1e6,
             f"objs_per_s={objs_per_s:.0f}|n_clusters={index.n_clusters}")
    append_trajectory(BENCH_PATH, record)


if __name__ == "__main__":
    run()
