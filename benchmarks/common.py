"""Shared benchmark substrate: streams, model zoo, cost accounting.

Cost model (documented in EXPERIMENTS.md):
  * GT-CNN = vit-l16 classifying an object crop at its native 224px
    (2·N·tokens ≈ 1.2e11 FLOPs/object).
  * The cheap ingest CNNs are physically small convnets (this container's
    objects are 32px synthetic crops), but their ACCOUNTED cost is that of
    the compression family the paper used (ResNet18 with layers removed /
    inputs rescaled): GT/8, GT/30, GT/98 for the generic family and
    GT/20, GT/50, GT/98 for specialized ones (§6.3: specialized models are
    7x-71x cheaper than GT-CNN). Raw measured FLOPs are also reported.
  * All "cost" numbers are FLOPs; "latency" assumes the paper's 10-GPU
    cluster via core.query.gpu_seconds.

Trained models are cached under experiments/bench_cache/ so the whole
benchmark suite trains each stream's models once.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import time
from typing import Callable, Dict, Tuple

import numpy as np

from repro.common.config import CheapCNNConfig
from repro.configs import get_arch
from repro.core.index import ClassMap
from repro.core.specialize import SpecializedModel, specialize, train_generic
from repro.data import get_stream

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "experiments/bench_cache")

# GT-CNN: vit-l16 @ 224 (2*N*tokens fwd FLOPs per object crop)
_VIT_L = get_arch("vit-l16")
GT_FLOPS = 2.0 * _VIT_L.n_params() * _VIT_L.n_tokens()

# (config, accounted-cost divisor vs GT) — paper's compression family
GENERIC_FAMILY = {
    "cheap1": (CheapCNNConfig("cheap1", input_res=32, n_blocks=6, width=48,
                              n_classes=1000, feature_dim=128), 8.0),
    "cheap2": (CheapCNNConfig("cheap2", input_res=32, n_blocks=4, width=32,
                              n_classes=1000, feature_dim=128), 30.0),
    "cheap3": (CheapCNNConfig("cheap3", input_res=16, n_blocks=3, width=24,
                              n_classes=1000, feature_dim=128), 98.0),
}
SPECIALIZED_FAMILY = {
    "spec1": (CheapCNNConfig("spec1", input_res=32, n_blocks=4, width=32,
                             feature_dim=128), 20.0),
    "spec2": (CheapCNNConfig("spec2", input_res=16, n_blocks=3, width=24,
                             feature_dim=128), 50.0),
    "spec3": (CheapCNNConfig("spec3", input_res=16, n_blocks=2, width=16,
                             feature_dim=128), 98.0),
}
DEFAULT_LS = 8

# benchmark-scale streams (12h in the paper -> minutes here; same dynamics)
BENCH_DURATION_S = 90
BENCH_FPS = 10


def load_stream(name: str, duration_s: int = BENCH_DURATION_S,
                fps: int = BENCH_FPS, frame_stride: int = 1):
    vs = get_stream(name, duration_s=duration_s, fps=fps)
    crops, frames, tracks, labels = vs.objects_array(
        frame_stride=frame_stride)
    return vs, crops, frames, labels


def _resize(crops: np.ndarray, res: int) -> np.ndarray:
    if crops.shape[1] == res:
        return crops
    idx = (np.arange(res) * crops.shape[1] // res)
    return crops[:, idx][:, :, idx]


def _cache_path(stream: str, model_id: str, duration_s: int) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, f"{stream}_{model_id}_{duration_s}.pkl")


def get_model(stream_name: str, model_id: str,
              crops: np.ndarray, labels: np.ndarray,
              duration_s: int = BENCH_DURATION_S, steps: int = 200,
              Ls: int = DEFAULT_LS) -> Tuple[Callable, float, object]:
    """Returns (apply_fn, accounted_flops_per_image, class_map or None)."""
    path = _cache_path(stream_name, model_id, duration_s)
    specialized = model_id in SPECIALIZED_FAMILY
    cfg, divisor = (SPECIALIZED_FAMILY if specialized
                    else GENERIC_FAMILY)[model_id]
    crops_r = _resize(crops, cfg.input_res)

    if os.path.exists(path):
        with open(path, "rb") as f:
            params, ccfg, cmap_ids = pickle.load(f)
        cmap = ClassMap(np.array(cmap_ids)) if cmap_ids is not None else None
        sm = SpecializedModel(params, ccfg, cmap, [])
    else:
        if specialized:
            sm = specialize(crops_r, labels, Ls=Ls, base_cfg=cfg, steps=steps)
        else:
            sm = train_generic(crops_r, labels, base_cfg=cfg, steps=steps)
        with open(path, "wb") as f:
            pickle.dump((jax_to_np(sm.params), sm.cfg,
                         (sm.class_map.global_ids.tolist()
                          if sm.class_map else None)), f)

    inner = sm.make_apply()

    def apply_fn(batch):
        return inner(_resize(batch, cfg.input_res))

    # the jax-traceable core for fused/sharded pipelines (the host wrapper
    # above pads with numpy and cannot be traced); _resize is np fancy
    # indexing with static shapes, traceable on jax arrays as-is
    fwd = sm.make_traceable()
    apply_fn.traceable = lambda batch: fwd(_resize(batch, cfg.input_res))
    apply_fn.input_res = cfg.input_res
    return apply_fn, GT_FLOPS / divisor, sm.class_map


def jax_to_np(tree):
    import jax
    return jax.tree.map(lambda x: np.asarray(x), tree)


def gt_oracle(labels_all: np.ndarray):
    """GT-CNN oracle over crops (exact; keyed by nearest class prototype)."""
    from repro.data.video import _class_proto
    protos = {int(c): None for c in np.unique(labels_all)}

    def gt_apply(crops):
        out = np.empty(len(crops), np.int64)
        for i, c in enumerate(crops):
            best, bd = -1, 1e18
            for cls in protos:
                if protos[cls] is None:
                    protos[cls] = _class_proto(cls, c.shape[0])
                d = float(np.abs(c - protos[cls]).mean())
                if d < bd:
                    best, bd = cls, d
            out[i] = best
        return out

    return gt_apply


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(name: str, us_per_call: float, derived: str):
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def append_trajectory(path: str, record: dict):
    """Append one record to a BENCH_*.json trajectory file (a JSON list);
    a corrupt or non-list file is reset rather than crashing the bench."""
    import json
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
        if not isinstance(history, list):
            history = []
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)


# ---------------------------------------------------------------------------
# Shared Focus evaluation (used by fig1/6/7/8/9/10/12)
# ---------------------------------------------------------------------------

import functools

from repro.core.ingest import IngestConfig, ingest
from repro.core.params import select, sweep
from repro.core.query import dominant_classes, gt_frames_by_class, \
    precision_recall

SWEEP_KS = (1, 2, 4, 8)
SWEEP_TS = (0.5, 0.8, 1.2)


@functools.lru_cache(maxsize=64)
def stream_sweep(stream_name: str, duration_s: int = BENCH_DURATION_S,
                 fps: int = BENCH_FPS, frame_stride: int = 1,
                 precision_target: float = 0.95,
                 recall_target: float = 0.95,
                 family: str = "specialized"):
    """Full §4.4 sweep for one stream; returns (evals, n_objects)."""
    vs, crops, frames, labels = load_stream(stream_name, duration_s, fps,
                                            frame_stride)
    fam = SPECIALIZED_FAMILY if family == "specialized" else GENERIC_FAMILY
    models, cmaps = {}, {}
    for mid in fam:
        apply_fn, acc_flops, cmap = get_model(stream_name, mid, crops,
                                              labels, duration_s)
        models[mid] = (apply_fn, acc_flops)
        cmaps[mid] = cmap
    evals = sweep(crops, frames, labels, models, Ks=list(SWEEP_KS),
                  Ts=list(SWEEP_TS), gt_flops=GT_FLOPS,
                  precision_target=precision_target,
                  recall_target=recall_target, class_maps=cmaps,
                  max_clusters=2048, batch_size=512)
    return evals, len(crops)


def policy_ratios(stream_name: str, policy: str = "balance", **kw):
    """Paper headline metrics: (I, Q) = how many times cheaper than
    Ingest-all / faster than Query-all, plus achieved precision/recall."""
    evals, n_objects = stream_sweep(stream_name, **kw)
    choice = select(evals, policy)
    if choice is None:       # fall back: best-recall config
        choice = max(evals, key=lambda e: (e.recall, e.precision))
    ingest_all = n_objects * GT_FLOPS
    query_all = n_objects * GT_FLOPS
    I = ingest_all / max(choice.ingest_flops, 1.0)
    Q = query_all / max(choice.query_flops, 1.0)
    return {"I": I, "Q": Q, "precision": choice.precision,
            "recall": choice.recall, "choice": choice,
            "n_objects": n_objects}
