"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.emit)."""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "table1_streams",          # Table 1 / Fig. 3: stream characteristics
    "fig5_topk_recall",        # Fig. 5: recall vs K for cheap CNNs
    "fig6_pareto",             # Fig. 6: Pareto parameter selection
    "fig7_end_to_end",         # Fig. 7 / Fig. 1: end-to-end vs baselines
    "fig8_components",         # Fig. 8: component breakdown
    "fig9_tradeoff",           # Fig. 9: Opt-Ingest / Opt-Query
    "fig10_accuracy_target",   # Fig. 10/11: accuracy-target sensitivity
    "fig12_frame_sampling",    # Fig. 12/13: frame-rate sensitivity
    "sec67_query_rates",       # §6.7: extreme query rates
    "kernel_bench",            # Pallas kernels + clustering throughput
    "ingest_bench",            # end-to-end ingest driver objects/sec
    "query_bench",             # batched query engine vs sequential query()
]


def main() -> None:
    print("name,us_per_call,derived")
    only = sys.argv[1:] or None
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:
            failures += 1
            print(f"{name},0.0,ERROR:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
