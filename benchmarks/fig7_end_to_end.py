"""Fig. 7 / Fig. 1: end-to-end ingest cost (vs Ingest-all) and query latency
(vs Query-all) per stream, Balance policy, 95% precision+recall targets."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, policy_ratios
from repro.core.query import gpu_seconds

STREAMS = ("auburn_c", "auburn_r", "city_a_d", "bend", "jacksonh",
           "church_st", "lausanne", "sittard", "cnn")


def run():
    Is, Qs = [], []
    for s in STREAMS:
        with Timer() as t:
            r = policy_ratios(s, "balance")
        Is.append(r["I"])
        Qs.append(r["Q"])
        emit(f"fig7.balance.{s}", t.us,
             f"I={r['I']:.0f}x|Q={r['Q']:.0f}x|P={r['precision']:.3f}"
             f"|R={r['recall']:.3f}|objects={r['n_objects']}")
    emit("fig7.average", 0.0,
         f"I_avg={np.mean(Is):.0f}x|Q_avg={np.mean(Qs):.0f}x"
         f"|I_max={np.max(Is):.0f}x|Q_max={np.max(Qs):.0f}x"
         f"|paper=I58x,Q37x")
    return Is, Qs


if __name__ == "__main__":
    run()
