"""Multi-stream streaming ingest with query-while-ingest (paper §5 shape).

Two camera streams are fed chunk by chunk through a ``MultiStreamRunner``
(one shared stacked cheap-CNN executable); between chunks a long-lived
``QueryEngine`` per stream prefetches the flush delta and answers the
dominant-class workload warm. Reported per run:

  * interleaved multi-stream ingest throughput (objects/sec),
  * query freshness latency: wall time from "chunk fed" to "warm queries
    answered on the updated index" (flush + prefetch + query_many),
  * correctness gates: every interleaved round returns frames identical
    to a fresh (cache-less) engine on the same index snapshot, and the
    final per-stream index is byte-identical to a one-shot ``ingest()``
    of the same stream.

One record per run is appended to the BENCH_streaming.json trajectory so
future streaming-path PRs are measured against this one.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig, ingest
from repro.core.streaming import MultiStreamRunner, StreamingIngestor

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_streaming.json")

N_STREAMS = 2
N_OBJECTS = 6144              # per stream
FEAT_DIM = 64
N_CLASSES = 16
N_MODES = 200
CHUNK = 512                   # objects fed per stream per round
BATCH = 256                   # CNN batch size inside the ingestors
GT_FLOPS = 1.2e11


def _make_stream(seed: int):
    """Video-shaped stream whose crops *are* the model inputs: mode
    patterns + noise (so clustering groups them), true class encoded in
    pixel (0,0,0) for the exact GT stub, consecutive-frame duplicates for
    pixel differencing."""
    r = np.random.default_rng(seed)
    modes = r.random((N_MODES, 8, 8, 3)).astype(np.float32)
    mode_cls = r.integers(0, N_CLASSES, N_MODES)
    pick = r.integers(0, N_MODES, N_OBJECTS)
    crops = np.clip(modes[pick] + r.normal(0, 0.02, (N_OBJECTS, 8, 8, 3)),
                    0, 1).astype(np.float32)
    frames = np.sort(r.integers(0, N_OBJECTS // 6, N_OBJECTS))
    for i in range(1, N_OBJECTS):
        if frames[i] == frames[i - 1] + 1 and r.random() < 0.3:
            crops[i] = np.clip(crops[i - 1]
                               + r.normal(0, 5e-4, crops[i].shape),
                               0, 1).astype(np.float32)
    crops[:, 0, 0, 0] = mode_cls[pick] / N_CLASSES
    return crops, frames


def _cheap(batch):
    """Per-example-pure cheap-CNN stub (stacked and stream-private batches
    give identical per-object outputs, as a jitted inference CNN does)."""
    flat = batch.reshape(len(batch), -1)
    feats = (flat[:, :FEAT_DIM] * 8.0).astype(np.float32)
    probs = np.abs(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES]) + 1e-3
    probs[np.arange(len(batch)),
          np.rint(batch[:, 0, 0, 0] * N_CLASSES).astype(int) % N_CLASSES] += 2.0
    return (probs / probs.sum(1, keepdims=True)).astype(np.float32), feats


def _gt_apply(batch):
    return np.rint(batch[:, 0, 0, 0] * N_CLASSES).astype(np.int64) % N_CLASSES


def _bytes_of(index, tag):
    del tag
    return index.save_bytes()


def run():
    streams = {f"cam{i}": _make_stream(i) for i in range(N_STREAMS)}
    cfg = IngestConfig(K=4, threshold=1.0, max_clusters=512,
                       batch_size=BATCH, high_water=0.9, evict_frac=0.25)
    workload = list(range(N_CLASSES))

    runner = MultiStreamRunner(
        {nm: StreamingIngestor(None, 1e9, cfg, n_local_classes=N_CLASSES)
         for nm in streams}, _cheap)
    engines = {nm: QueryEngine(runner.ingestors[nm].index,
                               gt_apply=_gt_apply,
                               gt_flops_per_image=GT_FLOPS)
               for nm in streams}

    interleaved_identical = True
    fresh_ms, ingest_wall = [], 0.0
    warm_gt_per_round = []
    n_rounds = (N_OBJECTS + CHUNK - 1) // CHUNK
    for rnd in range(n_rounds):
        lo, hi = rnd * CHUNK, (rnd + 1) * CHUNK
        t0 = time.perf_counter()
        runner.feed({nm: (c[lo:hi], f[lo:hi])
                     for nm, (c, f) in streams.items()})
        ingest_wall += time.perf_counter() - t0

        # freshness: flush deltas -> prefetch -> warm queries
        t1 = time.perf_counter()
        deltas = runner.flush()
        gt_round = 0
        per_stream = {}
        for nm, eng in engines.items():
            gt_round += eng.prefetch(deltas[nm].touched_cids)
            results, batch = eng.query_many(workload)
            gt_round += batch.n_gt_invocations
            per_stream[nm] = results
        fresh_ms.append((time.perf_counter() - t1) * 1e3)
        warm_gt_per_round.append(gt_round)

        # gate: identical to a cache-less engine on the same snapshot
        for nm, results in per_stream.items():
            cold = QueryEngine(runner.ingestors[nm].index,
                               gt_apply=_gt_apply,
                               gt_flops_per_image=GT_FLOPS)
            cold_results, _ = cold.query_many(workload)
            for a, b in zip(results, cold_results):
                if not np.array_equal(a.frames, b.frames):
                    interleaved_identical = False

    t0 = time.perf_counter()
    finished = runner.finish()
    ingest_wall += time.perf_counter() - t0

    # gate: byte-identical to sequential one-shot ingest-then-query
    oneshot_identical = True
    posthoc_identical = True
    for nm, (c, f) in streams.items():
        idx, stats = finished[nm]
        one_index, _ = ingest(c, f, _cheap, 1e9, cfg,
                              n_local_classes=N_CLASSES)
        if _bytes_of(idx, nm) != _bytes_of(one_index, nm + "_one"):
            oneshot_identical = False
        # interleaved final answers == post-hoc answers on the final index
        eng = engines[nm]
        eng.prefetch(runner.ingestors[nm].flush().touched_cids)
        final, _ = eng.query_many(workload)
        posthoc = QueryEngine(one_index, gt_apply=_gt_apply,
                              gt_flops_per_image=GT_FLOPS)
        posthoc_results, _ = posthoc.query_many(workload)
        for a, b in zip(final, posthoc_results):
            if not np.array_equal(a.frames, b.frames):
                posthoc_identical = False

    total_objects = N_STREAMS * N_OBJECTS
    objs_per_s = total_objects / max(ingest_wall, 1e-9)
    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n_streams": N_STREAMS,
        "n_objects_total": total_objects,
        "n_rounds": n_rounds,
        "objects_per_sec": round(objs_per_s, 1),
        "ingest_wall_s": round(ingest_wall, 4),
        "freshness_ms_mean": round(float(np.mean(fresh_ms)), 2),
        "freshness_ms_p90": round(float(np.percentile(fresh_ms, 90)), 2),
        "warm_gt_per_round_mean": round(float(np.mean(warm_gt_per_round)), 1),
        "n_clusters": {nm: finished[nm][0].n_clusters for nm in streams},
        "interleaved_identical": bool(interleaved_identical),
        "oneshot_identical": bool(oneshot_identical),
        "posthoc_identical": bool(posthoc_identical),
    }
    append_trajectory(BENCH_PATH, record)
    emit(f"streaming.ingest.{N_STREAMS}x{N_OBJECTS}", ingest_wall * 1e6,
         f"objs_per_s={objs_per_s:.0f}")
    emit(f"streaming.freshness.{len(workload)}q",
         float(np.mean(fresh_ms)) * 1e3,
         f"p90_ms={np.percentile(fresh_ms, 90):.1f}"
         f"|warm_gt={np.mean(warm_gt_per_round):.1f}")
    emit("streaming.equivalence", 0.0,
         f"interleaved={interleaved_identical}|oneshot={oneshot_identical}"
         f"|posthoc={posthoc_identical}")
    assert interleaved_identical, \
        "interleaved warm queries diverge from a fresh engine"
    assert oneshot_identical, \
        "streamed index differs from one-shot ingest (save bytes)"
    assert posthoc_identical, \
        "final interleaved answers differ from post-hoc queries"


if __name__ == "__main__":
    run()
