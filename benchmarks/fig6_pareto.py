"""Fig. 6: parameter selection — ingest cost vs query latency Pareto
boundary on auburn_c, with Balance / Opt-Ingest / Opt-Query choices."""
from __future__ import annotations

from benchmarks.common import GT_FLOPS, Timer, emit, stream_sweep
from repro.core.params import pareto_boundary, select


def run(stream="auburn_c"):
    with Timer() as t:
        evals, n_objects = stream_sweep(stream)
    front = pareto_boundary(evals)
    ingest_all = n_objects * GT_FLOPS
    pts = ";".join(
        f"({ingest_all/e.ingest_flops:.0f}x,{ingest_all/max(e.query_flops,1):.0f}x)"
        for e in front[:8])
    emit(f"fig6.pareto.{stream}", t.us, f"n_viable={sum(e.viable for e in evals)}"
         f"|n_front={len(front)}|front={pts}")
    for policy in ("balance", "opt_ingest", "opt_query"):
        c = select(evals, policy)
        if c is None:
            emit(f"fig6.{policy}.{stream}", 0.0, "no-viable-config")
            continue
        emit(f"fig6.{policy}.{stream}", 0.0,
             f"model={c.candidate.model_id}|K={c.candidate.K}"
             f"|T={c.candidate.T}|P={c.precision:.3f}|R={c.recall:.3f}")
    return front


if __name__ == "__main__":
    run()
