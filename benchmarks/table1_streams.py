"""Table 1 / Fig. 3: stream characteristics — classes present, frequency
skew (fraction of classes covering >=95% of objects), empty-frame rate."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, load_stream
from repro.data.video import STREAM_ZOO


def run():
    for sc in STREAM_ZOO:
        vs, crops, frames, labels = load_stream(sc.name)
        if len(labels) == 0:
            emit(f"table1.{sc.name}", 0.0, "empty")
            continue
        n_frames_total = vs.cfg.n_frames
        occupied = len(np.unique(frames))
        vals, counts = np.unique(labels, return_counts=True)
        order = np.argsort(-counts)
        cum = np.cumsum(counts[order]) / counts.sum()
        n95 = int(np.searchsorted(cum, 0.95)) + 1
        emit(f"table1.{sc.name}", 0.0,
             f"objects={len(labels)}|classes={len(vals)}"
             f"|classes_for_95pct={n95}"
             f"|frac_frames_with_objects={occupied/n_frames_total:.2f}"
             f"|paper=3-10pct_classes_cover_95pct")


if __name__ == "__main__":
    run()
