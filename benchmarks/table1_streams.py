"""Table 1 / Fig. 3: stream characteristics — classes present, frequency
skew (fraction of classes covering >=95% of objects), empty-frame rate.

Also emits a 10x-stream-count aggregate row (the paper's multi-stream
deployment scenario: Focus targets thousands of concurrent feeds, §7):
the zoo replicated 10x, so the per-deployment object volume and class
skew that sizing decisions (mesh width, cluster budgets) read from this
table are tracked numbers rather than prose."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, load_stream
from repro.data.video import STREAM_ZOO

STREAM_REPLICAS = 10           # the "10x stream count" deployment row


def _skew95(counts: np.ndarray) -> int:
    order = np.argsort(-counts)
    cum = np.cumsum(counts[order]) / counts.sum()
    return int(np.searchsorted(cum, 0.95)) + 1


def run():
    agg_labels, agg_occupied, agg_frames = [], 0, 0
    for sc in STREAM_ZOO:
        vs, crops, frames, labels = load_stream(sc.name)
        agg_frames += vs.cfg.n_frames
        if len(labels) == 0:
            emit(f"table1.{sc.name}", 0.0, "empty")
            continue
        n_frames_total = vs.cfg.n_frames
        occupied = len(np.unique(frames))
        agg_labels.append(labels)
        agg_occupied += occupied
        vals, counts = np.unique(labels, return_counts=True)
        n95 = _skew95(counts)
        emit(f"table1.{sc.name}", 0.0,
             f"objects={len(labels)}|classes={len(vals)}"
             f"|classes_for_95pct={n95}"
             f"|frac_frames_with_objects={occupied/n_frames_total:.2f}"
             f"|paper=3-10pct_classes_cover_95pct")

    # 10x-stream-count deployment row: every zoo stream runs REPLICAS
    # times concurrently (replicas share dynamics, so aggregate skew is
    # exact without re-rendering 10x the video)
    labels_all = np.concatenate(agg_labels)
    vals, counts = np.unique(labels_all, return_counts=True)
    n_streams = len(STREAM_ZOO) * STREAM_REPLICAS
    emit("table1.multi_stream_10x", 0.0,
         f"streams={n_streams}|replicas={STREAM_REPLICAS}"
         f"|objects={len(labels_all) * STREAM_REPLICAS}"
         f"|classes={len(vals)}|classes_for_95pct={_skew95(counts)}"
         f"|frac_frames_with_objects={agg_occupied/max(agg_frames, 1):.2f}"
         f"|ingest_path=sharded_mesh_see_BENCH_mesh")


if __name__ == "__main__":
    run()
