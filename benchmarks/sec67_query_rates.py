"""§6.7: applicability under extreme query rates.

Case A: every dominant class queried once -> Focus total cost vs Ingest-all
        (paper: still 4x cheaper on average, because GT-CNN runs once per
        *cluster*, not per object).
Case B: ingest-nothing variant — run all Focus techniques at query time
        (paper: still 22x faster than Query-all).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (GT_FLOPS, Timer, emit, get_model,
                               load_stream)
from repro.core.ingest import IngestConfig, ingest
from repro.core.query import dominant_classes

STREAMS = ("auburn_c", "lausanne", "cnn")


def run():
    for stream in STREAMS:
        vs, crops, frames, labels = load_stream(stream)
        apply_s, flops_s, cmap = get_model(stream, "spec2", crops, labels)
        index, stats = ingest(crops, frames, apply_s, flops_s,
                              IngestConfig(K=2, threshold=0.8,
                                           max_clusters=2048),
                              class_map=cmap)
        dom = dominant_classes(labels)
        ingest_all = len(crops) * GT_FLOPS

        # Case A: all dominant classes queried; clusters classified once.
        clusters_touched = set()
        for x in dom:
            clusters_touched.update(index.lookup(x))
        focus_total = stats.cheap_flops + len(clusters_touched) * GT_FLOPS
        emit(f"sec67.all_queried.{stream}", 0.0,
             f"focus_vs_ingest_all={ingest_all/focus_total:.1f}x"
             f"|paper=4x_avg")

        # Case B: do everything at query time (cheap CNN + cluster + GT on
        # centroids, all charged to the query).
        query_all = len(crops) * GT_FLOPS
        lazy_cost = stats.cheap_flops + index.n_clusters * GT_FLOPS
        emit(f"sec67.lazy_focus.{stream}", 0.0,
             f"lazy_vs_query_all={query_all/lazy_cost:.1f}x|paper=22x_avg")


if __name__ == "__main__":
    run()
