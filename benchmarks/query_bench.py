"""Query-path throughput: sequential per-class ``query()`` vs the batched
``QueryEngine`` (union + GT-label cache) on a synthetic video-shaped index.

The headline number is GT-CNN invocations for the dominant-class workload:
sequential querying re-classifies shared candidate centroids per class and
re-pays everything on every round, while the engine verifies each centroid
at most once across all queries and rounds. One record per run is appended
to the BENCH_query.json trajectory so future query-path PRs are measured
against this one.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core.engine import QueryEngine
from repro.core.index import TopKIndex
from repro.core.query import query

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_query.json")

N_OBJECTS = 8192
FEAT_DIM = 64
N_CLASSES = 24
N_MODES = 400
K = 4
GT_FLOPS = 1.2e11
WARM_ROUNDS = 5


def _synthetic_index(seed: int = 0):
    """Index over a mode-based stream; crops encode the mode's true class
    in pixel (0, 0, 0) so the GT-CNN stub is exact and order-free."""
    r = np.random.default_rng(seed)
    mode_cls = r.integers(0, N_CLASSES, N_MODES)
    pick = r.integers(0, N_MODES, N_OBJECTS)
    feats = r.normal(0, 1, (N_OBJECTS, FEAT_DIM)).astype(np.float32)
    # soft probs: true class strong, a few confusable classes in the top-K
    # tail so candidate sets overlap across concurrent queries
    probs = r.random((N_OBJECTS, N_CLASSES)).astype(np.float32) * 0.3
    probs[np.arange(N_OBJECTS), mode_cls[pick]] += 1.0
    probs[np.arange(N_OBJECTS), (mode_cls[pick] + 1) % N_CLASSES] += 0.5
    probs /= probs.sum(1, keepdims=True)
    crops = r.random((N_OBJECTS, 8, 8, 3)).astype(np.float32)
    crops[:, 0, 0, 0] = mode_cls[pick].astype(np.float32)
    frames = np.repeat(np.arange(N_OBJECTS // 8), 8)[:N_OBJECTS]

    index = TopKIndex(K=K, n_local_classes=N_CLASSES)
    for start in range(0, N_OBJECTS, 512):
        sl = slice(start, start + 512)
        index.add_batch(pick[sl], feats[sl], probs[sl],
                        np.arange(N_OBJECTS)[sl], frames[sl],
                        crops=crops[sl])
    return index


def _gt_apply(batch):
    return np.rint(batch[:, 0, 0, 0]).astype(np.int64)


def run():
    index = _synthetic_index()
    workload = list(range(N_CLASSES))

    # sequential baseline: per-class query(), re-paying shared centroids
    t0 = time.perf_counter()
    seq_results = [query(index, x, _gt_apply, GT_FLOPS) for x in workload]
    seq_wall = time.perf_counter() - t0
    seq_gt = sum(r.n_gt_invocations for r in seq_results)

    # engine: one union + one bucketed GT pass, verdict cache across rounds
    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    cold_results, cold = engine.query_many(workload)
    warm_walls, warm_gt = [], 0
    for _ in range(WARM_ROUNDS):
        _, warm = engine.query_many(workload)
        warm_walls.append(warm.wall_s)
        warm_gt += warm.n_gt_invocations

    frames_identical = all(
        np.array_equal(s.frames, e.frames)
        for s, e in zip(seq_results, cold_results))
    seq_per_round = seq_gt            # what query() pays on EVERY round
    cold_ratio = seq_gt / max(cold.n_gt_invocations, 1)
    warm_ratio = seq_per_round / max(warm_gt / WARM_ROUNDS, 1)
    qps_warm = len(workload) / max(np.mean(warm_walls), 1e-9)

    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n_objects": N_OBJECTS, "n_clusters": index.n_clusters,
        "n_queries": len(workload),
        "seq_gt_invocations": int(seq_gt),
        "cold_gt_invocations": int(cold.n_gt_invocations),
        "warm_gt_invocations_per_round": warm_gt / WARM_ROUNDS,
        "cold_ratio": round(cold_ratio, 2),
        "warm_ratio": round(min(warm_ratio, 1e6), 2),
        "frames_identical": bool(frames_identical),
        "seq_wall_s": round(seq_wall, 4),
        "cold_wall_s": round(cold.wall_s, 4),
        "warm_qps": round(qps_warm, 1),
    }
    append_trajectory(BENCH_PATH, record)
    emit(f"query.seq.{len(workload)}q", seq_wall * 1e6,
         f"gt_calls={seq_gt}")
    emit(f"query.engine_cold.{len(workload)}q", cold.wall_s * 1e6,
         f"gt_calls={cold.n_gt_invocations}|ratio={cold_ratio:.1f}x")
    emit(f"query.engine_warm.{len(workload)}q",
         float(np.mean(warm_walls)) * 1e6,
         f"gt_calls_per_round={warm_gt / WARM_ROUNDS:.1f}"
         f"|qps={qps_warm:.0f}|identical={frames_identical}")
    assert frames_identical, "engine frames diverge from sequential query()"
    assert warm_ratio >= 5.0, (
        f"warm-cache GT reduction {warm_ratio:.1f}x < 5x acceptance gate")


if __name__ == "__main__":
    run()
