"""Fig. 5: effect of K on recall for three generic cheap CNNs.

Uses the busiest stream (most classes) and deliberately UNDER-trained
generic models: the paper's cheap CNNs are imperfect top-1 classifiers on
1000 classes, which is exactly the regime where the top-K index earns its
recall (Fig. 5's phenomenon). Fully-trained models on the synthetic
streams saturate recall at K=1 (see EXPERIMENTS.md caveat)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (GENERIC_FAMILY, GT_FLOPS, Timer, emit,
                               _resize, load_stream)
from repro.core.ingest import IngestConfig, ingest
from repro.core.query import dominant_classes, gt_frames_by_class, \
    precision_recall
from repro.core.specialize import train_generic

KS = (1, 2, 5, 10, 20, 50)
WEAK_STEPS = {"cheap1": 70, "cheap2": 55, "cheap3": 48}


def run(stream="msnbc"):
    vs, crops, frames, labels = load_stream(stream)
    dom = dominant_classes(labels)
    gtf = gt_frames_by_class(labels, frames)
    rows = []
    for mid in GENERIC_FAMILY:
        cfg, divisor = GENERIC_FAMILY[mid]
        sm = train_generic(_resize(crops, cfg.input_res), labels, cfg,
                           steps=WEAK_STEPS[mid], seed=5)
        inner = sm.make_apply()
        apply_fn = lambda b, _c=cfg: inner(_resize(b, _c.input_res))
        acc_flops = GT_FLOPS / divisor
        with Timer() as t:
            # singleton clusters: Fig. 5 isolates the top-K INDEX recall
            # (clustering effects are Fig. 8's subject)
            index, stats = ingest(
                crops, frames, apply_fn, acc_flops,
                IngestConfig(K=max(KS), threshold=1e-6, pixel_diff=False,
                             max_clusters=8192))
        recalls = {}
        for K in KS:
            rs = []
            for x in dom:
                cids = index.lookup(x, K)
                matched = [c for c, fm in
                           zip(cids, index.first_members(cids))
                           if labels[fm] == x]
                _, r = precision_recall(index.frames_of(matched),
                                        gtf.get(x, np.array([])))
                rs.append(r)
            recalls[K] = float(np.mean(rs))
        k90 = next((K for K in KS if recalls[K] >= 0.9), ">50")
        curve = ";".join(f"K{k}={recalls[k]:.3f}" for k in KS)
        emit(f"fig5.recall_vs_K.{mid}",
             t.us / max(len(crops), 1),
             f"K@90%recall={k90}|{curve}")
        rows.append((mid, recalls))
    return rows


if __name__ == "__main__":
    run()
