"""Multi-tenant serving benchmark: ``QueryService`` continuous batching
vs per-tenant sequential serving, plus sustained mixed query+ingest load.

Two sections, one record per run appended to BENCH_serve.json:

* **equivalence / GT ratio** — N tenants with overlapping dominant-class
  workloads served through one ``QueryService`` (shared engine, merged
  ``query_many`` per cycle) vs the same requests replayed sequentially on
  per-tenant engines. Gates: byte-identical frames per request, and the
  shared engine pays strictly fewer GT-CNN invocations (cross-tenant
  candidate dedup + one shared label cache vs one cache per tenant).
* **mixed load** — a streaming ingestor attached to the service; every
  round offers one ingest chunk and one request per tenant, under both
  backpressure policies. Reports sustained QPS, per-tenant p50/p99
  latency, deadline misses, and the deferred/shed ingest counters that
  show the policy actually arbitrating.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig, ingest
from repro.core.streaming import StreamingIngestor
from repro.serve import QueryService, ServiceConfig

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")

FEAT_DIM = 32
N_CLASSES = 12
N_OBJECTS = 4096
N_TENANTS = 4
REQS_PER_TENANT = 6
N_CHUNKS = 8
SLO_MS = 250.0
CFG = IngestConfig(K=3, threshold=1.2, max_clusters=512, batch_size=256)
GT_FLOPS = 1.2e11


def _cheap(batch):
    flat = batch.reshape(len(batch), -1)
    feats = (flat[:, :FEAT_DIM] * 10.0).astype(np.float32)
    probs = np.abs(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES]) + 1e-3
    return (probs / probs.sum(1, keepdims=True)).astype(np.float32), feats


def _gt_apply(batch):
    return np.rint(batch[:, 0, 0, 2] * 20).astype(np.int64) % N_CLASSES


def _stream(seed=0, n=N_OBJECTS):
    r = np.random.default_rng(seed)
    modes = r.random((40, 6, 6, 3)).astype(np.float32)
    pick = r.integers(0, 40, n)
    crops = np.clip(modes[pick] + r.normal(0, 0.05, (n, 6, 6, 3)), 0, 1
                    ).astype(np.float32)
    frames = np.sort(r.integers(0, n // 4, n))
    return crops, frames


def _tenant_workloads():
    """Overlapping per-tenant class subsets (rotated windows over the
    class space): the overlap is what continuous batching dedupes."""
    span = max(N_CLASSES // 2, 1)
    return {f"tenant{t}": [(t * 2 + i) % N_CLASSES for i in range(span)]
            for t in range(N_TENANTS)}


# ---------------------------------------------------------------------------
# section 1: equivalence + batched-vs-sequential GT ratio
# ---------------------------------------------------------------------------

def run_equivalence():
    crops, frames = _stream()
    index, _ = ingest(crops, frames, _cheap, 1.0, CFG,
                      n_local_classes=N_CLASSES)
    workloads = _tenant_workloads()

    engine = QueryEngine(index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    service = QueryService(engine)
    t0 = time.perf_counter()
    for _ in range(REQS_PER_TENANT):
        for tenant, classes in workloads.items():
            service.submit(tenant, classes)
    responses = service.run_until_idle()
    batched_wall = time.perf_counter() - t0
    gt_batched = engine.stats.n_gt_invocations

    # sequential baseline: each tenant serves its own requests on its own
    # engine (its own GT-label cache) — no cross-tenant sharing
    ref_engines = {t: QueryEngine(index, gt_apply=_gt_apply,
                                  gt_flops_per_image=GT_FLOPS)
                   for t in workloads}
    t0 = time.perf_counter()
    ref_results = []
    for _ in range(REQS_PER_TENANT):
        for tenant, classes in workloads.items():
            results, _ = ref_engines[tenant].query_many(classes)
            ref_results.append(results)
    seq_wall = time.perf_counter() - t0
    gt_sequential = sum(e.stats.n_gt_invocations
                        for e in ref_engines.values())

    frames_identical = len(responses) == len(ref_results) and all(
        np.array_equal(got.frames, want.frames)
        and got.queried_class == want.queried_class
        for resp, wants in zip(responses, ref_results)
        for got, want in zip(resp.results, wants))
    return {
        "n_tenants": N_TENANTS,
        "n_requests": len(responses),
        "frames_identical": bool(frames_identical),
        "gt_batched": int(gt_batched),
        "gt_sequential": int(gt_sequential),
        "gt_ratio": round(gt_sequential / max(gt_batched, 1), 2),
        "merged_calls": int(service.stats.n_merged_calls),
        "shared_pairs": int(service.stats.n_shared_queries),
        "batched_wall_s": round(batched_wall, 4),
        "seq_wall_s": round(seq_wall, 4),
    }


# ---------------------------------------------------------------------------
# section 2: sustained mixed query+ingest load, both policies
# ---------------------------------------------------------------------------

def run_mixed(policy: str):
    crops, frames = _stream(seed=1)
    bounds = np.linspace(0, len(crops), N_CHUNKS + 1).astype(int)
    workloads = _tenant_workloads()

    ing = StreamingIngestor(_cheap, 1.0, CFG, n_local_classes=N_CLASSES)
    engine = QueryEngine(ing.index, gt_apply=_gt_apply,
                         gt_flops_per_image=GT_FLOPS)
    service = QueryService(
        engine,
        ServiceConfig(policy=policy, max_ingest_backlog=N_CHUNKS),
        ingestor=ing)

    t0 = time.perf_counter()
    for lo, hi in zip(bounds, bounds[1:]):
        service.offer_ingest(crops[lo:hi], frames[lo:hi])
        for tenant, classes in workloads.items():
            service.submit(tenant, classes, deadline_s=SLO_MS / 1e3)
        service.step()          # query cycle (ingest-first under "ingest")
        service.step()          # idle cycle: deferred ingest catches up
    service.run_until_idle()
    wall = time.perf_counter() - t0

    slo = service.slo
    n_completed = service.stats.n_completed
    missed = sum(ts.n_deadline_missed for ts in slo)
    return {
        "policy": policy,
        "n_requests": int(n_completed),
        "qps": round(n_completed / max(wall, 1e-9), 1),
        "p50_ms": round(slo.percentile_s(50.0) * 1e3, 3),
        "p99_ms": round(slo.percentile_s(99.0) * 1e3, 3),
        "deadline_missed": int(missed),
        "ingest_chunks": int(service.stats.n_ingest_chunks),
        "ingest_deferred": int(service.stats.n_ingest_deferred),
        "ingest_shed_chunks": int(service.stats.n_ingest_shed_chunks),
        "merged_calls": int(service.stats.n_merged_calls),
        "wall_s": round(wall, 4),
        "tenants": slo.summary(),
    }


def run():
    eq = run_equivalence()
    mixed = {p: run_mixed(p) for p in ("query", "ingest")}
    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n_objects": N_OBJECTS,
        **eq,
        "mixed": mixed,
    }
    append_trajectory(BENCH_PATH, record)
    emit(f"serve.batched.{eq['n_requests']}req",
         eq["batched_wall_s"] * 1e6,
         f"gt_calls={eq['gt_batched']}|merged_calls={eq['merged_calls']}")
    emit(f"serve.sequential.{eq['n_requests']}req",
         eq["seq_wall_s"] * 1e6,
         f"gt_calls={eq['gt_sequential']}"
         f"|ratio={eq['gt_ratio']:.1f}x|identical={eq['frames_identical']}")
    for p, m in mixed.items():
        emit(f"serve.mixed.{p}", m["wall_s"] * 1e6,
             f"qps={m['qps']}|p50={m['p50_ms']}ms|p99={m['p99_ms']}ms"
             f"|missed={m['deadline_missed']}"
             f"|deferred={m['ingest_deferred']}")

    assert eq["frames_identical"], \
        "batched service diverged from per-tenant sequential serving"
    assert eq["gt_batched"] < eq["gt_sequential"], (
        f"continuous batching must pay strictly fewer GT calls: "
        f"{eq['gt_batched']} vs {eq['gt_sequential']}")
    for p, m in mixed.items():
        assert m["n_requests"] == N_TENANTS * N_CHUNKS, m
        assert m["ingest_chunks"] == N_CHUNKS, m
    # the policies must actually arbitrate differently: query priority
    # defers chunks behind queries, ingest priority never does
    assert mixed["query"]["ingest_deferred"] > 0, mixed["query"]
    assert mixed["ingest"]["ingest_deferred"] == 0, mixed["ingest"]
    return record


if __name__ == "__main__":
    run()
