"""Time-sharded archive: cross-shard query fan-out + format-tier benchmark.

One synthetic multi-day-shaped stream is ingested through a
``StreamingIngestor`` with shard rollover at several shard counts; an
``ArchiveQueryEngine`` then serves the dominant-class workload against the
sealed archive. Reported per shard count:

  * cold / warm query latency and GT-CNN invocations,
  * GT-CNN *launches* on the cold pass (the fan-out must union uncached
    rep crops across all shards and all queries into one bucket-padded
    pass — not one pass per shard),
  * shard-loader behaviour under a capacity smaller than the shard count
    (loads / evictions per query round), plus heap residency / hit rate.

A second tier compares the quantized lazy/mmap v4 shard format against
the fp32 npz v3 baseline over the *identical* stream and rollover:

  * bytes/object on disk (gate: v4 >= 3x smaller),
  * cold query wall time — manifest open + column load + rank path +
    frame gather, measured engine-level with oracle labels so the crop
    column is never touched (gate: v4 >= 2x faster),
  * lossless-path identity: every v4 shard served lazily (mmap +
    in-kernel dequant rank) answers lookup/frames byte-identically to
    the same shard eagerly dequantized to fp32 (gate: exact),
  * quantized-crop recall: GT-pass answers on uint8 rep-crops vs the
    fp32 crops of the v3 archive (gate: >= 0.99).

Correctness gates (asserted here and in CI):
  * archive answers equal the union of per-shard ``QueryEngine`` answers,
  * a warm archive query issues zero GT-CNN invocations,
  * the cold pass runs ``ceil(misses / batch_size)`` GT launches total,
    independent of the shard count,
  * the four format-tier gates above.

One record per run is appended to the BENCH_archive.json trajectory.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core.archive import (ArchiveQueryEngine, ShardCatalog,
                                ShardLoader)
from repro.core.engine import QueryEngine
from repro.core.ingest import IngestConfig
from repro.core.streaming import StreamingIngestor

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_archive.json")

N_OBJECTS = 8192
FEAT_DIM = 64
N_CLASSES = 16
N_MODES = 200
BATCH = 256                   # CNN batch size inside the ingestor
GT_BATCH = 256                # GT-CNN batch size inside the engines
SHARD_COUNTS = (1, 4, 8)
LRU_CAPACITY = 2              # < max(SHARD_COUNTS): forces evictions
GT_FLOPS = 1.2e11
FMT_SHARDS = 8                # shard count for the v3-vs-v4 format tier
COLD_REPS = 3                 # cold-load reps per format (min reported)


def _make_stream(seed: int):
    """Video-shaped stream: mode patterns + noise, true class encoded in
    pixel (0,0,0), consecutive-frame duplicates for pixel differencing."""
    r = np.random.default_rng(seed)
    modes = r.random((N_MODES, 8, 8, 3)).astype(np.float32)
    mode_cls = r.integers(0, N_CLASSES, N_MODES)
    pick = r.integers(0, N_MODES, N_OBJECTS)
    crops = np.clip(modes[pick] + r.normal(0, 0.02, (N_OBJECTS, 8, 8, 3)),
                    0, 1).astype(np.float32)
    frames = np.sort(r.integers(0, N_OBJECTS // 6, N_OBJECTS))
    for i in range(1, N_OBJECTS):
        if frames[i] == frames[i - 1] + 1 and r.random() < 0.3:
            crops[i] = np.clip(crops[i - 1]
                               + r.normal(0, 5e-4, crops[i].shape),
                               0, 1).astype(np.float32)
    crops[:, 0, 0, 0] = mode_cls[pick] / N_CLASSES
    return crops, frames


def _cheap(batch):
    flat = batch.reshape(len(batch), -1)
    feats = (flat[:, :FEAT_DIM] * 8.0).astype(np.float32)
    probs = np.abs(flat[:, FEAT_DIM:FEAT_DIM + N_CLASSES]) + 1e-3
    probs[np.arange(len(batch)),
          np.rint(batch[:, 0, 0, 0] * N_CLASSES).astype(int) % N_CLASSES] += 2.0
    return (probs / probs.sum(1, keepdims=True)).astype(np.float32), feats


class _CountingGT:
    """GT-CNN stub counting launches (the one-pass gate)."""

    def __init__(self):
        self.n_calls = 0

    def __call__(self, batch):
        self.n_calls += 1
        return np.rint(batch[:, 0, 0, 0] * N_CLASSES).astype(np.int64) \
            % N_CLASSES


def _build_archive(root, crops, frames, cfg, shard_format):
    """Ingest the stream into ``root`` with rollover at FMT_SHARDS."""
    catalog = ShardCatalog.open(root)
    ing = StreamingIngestor(_cheap, 1e9, cfg, catalog=catalog,
                            shard_objects=-(-N_OBJECTS // FMT_SHARDS),
                            shard_format=shard_format)
    for lo in range(0, N_OBJECTS, 1024):
        ing.feed(crops[lo:lo + 1024], frames[lo:lo + 1024])
    ing.finish()
    assert len(catalog) == FMT_SHARDS
    return catalog


def _cold_load_ms(catalog, workload):
    """Wall time of the cold load+rank path over every shard: fresh
    loader, ``get`` + one ``lookup`` per class. v3 pays the full npz
    decode of every column here; v4 opens the manifest, mmaps the prob
    column and ranks in-kernel — the crop/log columns are never read."""
    best = float("inf")
    for _ in range(COLD_REPS):
        loader = ShardLoader(catalog)
        t0 = time.perf_counter()
        for m in catalog:
            idx = loader.get(m.shard_id)
            for cls in workload:
                idx.lookup(cls)
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def _cold_query_ms(catalog, labels, workload):
    """Wall time of one fully cold archive query round: fresh engine +
    loader, oracle labels (the crop column is never read). Includes the
    per-candidate frame gather, which is format-independent — reported
    for context, not gated."""
    best = float("inf")
    for _ in range(COLD_REPS):
        engine = ArchiveQueryEngine(catalog, oracle_labels=labels,
                                    batch_size=GT_BATCH)
        t0 = time.perf_counter()
        engine.query_many(workload)
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def _format_tier(crops, frames, cfg, workload):
    """v3 (fp32 npz) vs v4 (quantized lazy/mmap) over the same stream."""
    labels = np.rint(crops[:, 0, 0, 0] * N_CLASSES).astype(np.int64) \
        % N_CLASSES
    out = {}
    with tempfile.TemporaryDirectory() as d3, \
            tempfile.TemporaryDirectory() as d4:
        cat3 = _build_archive(d3, crops, frames, cfg, shard_format=3)
        cat4 = _build_archive(d4, crops, frames, cfg, shard_format=None)

        # --- bytes/object (seal-time accounting, satellite: n_bytes)
        b3 = sum(m.n_bytes for m in cat3)
        b4 = sum(m.n_bytes for m in cat4)
        out["bytes_per_object_v3"] = round(b3 / N_OBJECTS, 1)
        out["bytes_per_object_v4"] = round(b4 / N_OBJECTS, 1)
        out["bytes_ratio"] = round(b3 / b4, 2)

        # --- cold load latency (warm the dequant kernel's jit at every
        # shard shape first so v4 is not billed for tracing)
        warm = ArchiveQueryEngine(cat4, oracle_labels=labels,
                                  batch_size=GT_BATCH)
        warm.query_many(workload)
        out["cold_load_ms_v3"] = round(_cold_load_ms(cat3, workload), 2)
        out["cold_load_ms_v4"] = round(_cold_load_ms(cat4, workload), 2)
        out["cold_load_ratio"] = round(out["cold_load_ms_v3"]
                                       / out["cold_load_ms_v4"], 2)
        out["cold_query_ms_v3"] = round(_cold_query_ms(cat3, labels,
                                                       workload), 2)
        out["cold_query_ms_v4"] = round(_cold_query_ms(cat4, labels,
                                                       workload), 2)

        # --- lossless path: lazy (mmap + in-kernel dequant rank) answers
        # byte-identical to the eagerly dequantized fp32 load of the SAME
        # v4 files, for every shard / class / Kx
        lossless = True
        loader = ShardLoader(cat4)
        for m in cat4:
            lazy = loader.get(m.shard_id)
            eager = cat4.load_shard(m.shard_id)
            for cls in range(N_CLASSES):
                for kx in range(1, cfg.K + 1):
                    a = lazy.lookup(cls, Kx=kx)
                    b = eager.lookup(cls, Kx=kx)
                    if a != b or not np.array_equal(lazy.frames_of(a),
                                                    eager.frames_of(b)):
                        lossless = False
        out["lossless_identical"] = bool(lossless)

        # --- quantized-crop recall: GT pass reads uint8 crops (v4) vs
        # fp32 crops (v3); answers compared frame-for-frame
        e3 = ArchiveQueryEngine(cat3, gt_apply=_CountingGT(),
                                gt_flops_per_image=GT_FLOPS,
                                batch_size=GT_BATCH)
        e4 = ArchiveQueryEngine(cat4, gt_apply=_CountingGT(),
                                gt_flops_per_image=GT_FLOPS,
                                batch_size=GT_BATCH)
        r3, _ = e3.query_many(workload)
        r4, _ = e4.query_many(workload)
        want = got = 0
        for a, b in zip(r3, r4):
            want += len(a.frames)
            got += len(np.intersect1d(a.frames, b.frames))
        out["crop_recall"] = round(got / want, 4) if want else 1.0
        out["quantized_identical"] = bool(
            all(np.array_equal(a.frames, b.frames)
                for a, b in zip(r3, r4)))
    return out


def run():
    crops, frames = _make_stream(0)
    cfg = IngestConfig(K=4, threshold=1.0, max_clusters=512,
                       batch_size=BATCH, high_water=0.9, evict_frac=0.25)
    workload = list(range(N_CLASSES))

    per_shard_count = []
    equals_union = True
    single_gt_pass = True
    warm_zero = True
    for n_shards in SHARD_COUNTS:
        with tempfile.TemporaryDirectory() as d:
            catalog = ShardCatalog.open(d)
            shard_objects = -(-N_OBJECTS // n_shards)
            t0 = time.perf_counter()
            ing = StreamingIngestor(_cheap, 1e9, cfg, catalog=catalog,
                                    shard_objects=shard_objects)
            for lo in range(0, N_OBJECTS, 1024):
                ing.feed(crops[lo:lo + 1024], frames[lo:lo + 1024])
            ing.finish()
            ingest_s = time.perf_counter() - t0
            assert len(catalog) == n_shards, (len(catalog), n_shards)

            gt = _CountingGT()
            engine = ArchiveQueryEngine(catalog, gt_apply=gt,
                                        gt_flops_per_image=GT_FLOPS,
                                        batch_size=GT_BATCH,
                                        capacity=LRU_CAPACITY)
            t0 = time.perf_counter()
            cold_results, cold = engine.query_many(workload)
            cold_ms = (time.perf_counter() - t0) * 1e3
            expect_launches = -(-cold.n_gt_invocations // GT_BATCH)
            if gt.n_calls != expect_launches or \
                    cold.n_gt_batches != expect_launches:
                single_gt_pass = False

            t0 = time.perf_counter()
            warm_results, warm = engine.query_many(workload)
            warm_ms = (time.perf_counter() - t0) * 1e3
            if warm.n_gt_invocations != 0:
                warm_zero = False
            for a, b in zip(cold_results, warm_results):
                if not np.array_equal(a.frames, b.frames):
                    equals_union = False

            # gate: archive answers == union of per-shard engine answers
            union = {cls: [] for cls in workload}
            for m in catalog:
                shard_engine = QueryEngine(
                    catalog.load_shard(m.shard_id), gt_apply=gt,
                    gt_flops_per_image=GT_FLOPS, batch_size=GT_BATCH)
                shard_results, _ = shard_engine.query_many(workload)
                for cls, res in zip(workload, shard_results):
                    union[cls].append(res.frames)
            for cls, res in zip(workload, cold_results):
                want = (np.unique(np.concatenate(union[cls]))
                        if union[cls] else np.array([], np.int64))
                if not np.array_equal(res.frames, want):
                    equals_union = False

            per_shard_count.append({
                "n_shards": n_shards,
                "ingest_s": round(ingest_s, 3),
                "cold_ms": round(cold_ms, 2),
                "warm_ms": round(warm_ms, 2),
                "cold_gt_invocations": cold.n_gt_invocations,
                "cold_gt_batches": cold.n_gt_batches,
                "warm_gt_invocations": warm.n_gt_invocations,
                "unique_candidates": cold.n_unique_candidates,
                "shard_loads_cold": cold.n_shard_loads,
                "shard_evictions_cold": cold.n_shard_evictions,
                "shard_loads_warm": warm.n_shard_loads,
                "shard_evictions_warm": warm.n_shard_evictions,
                "resident_bytes": engine.stats.resident_bytes,
                "shard_hit_rate": round(engine.stats.shard_hit_rate, 3),
            })

    fmt = _format_tier(crops, frames, cfg, workload)
    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n_objects": N_OBJECTS,
        "n_queries": len(workload),
        "lru_capacity": LRU_CAPACITY,
        "per_shard_count": per_shard_count,
        "archive_equals_union": bool(equals_union),
        "single_gt_pass": bool(single_gt_pass),
        "warm_gt_invocations": 0 if warm_zero else
            max(r["warm_gt_invocations"] for r in per_shard_count),
        **fmt,
    }
    append_trajectory(BENCH_PATH, record)
    for r in per_shard_count:
        emit(f"archive.query.{r['n_shards']}shards", r["cold_ms"] * 1e3,
             f"warm_ms={r['warm_ms']}|gt={r['cold_gt_invocations']}"
             f"|gt_batches={r['cold_gt_batches']}"
             f"|evictions={r['shard_evictions_cold']}")
    emit("archive.equivalence", 0.0,
         f"union={equals_union}|one_pass={single_gt_pass}"
         f"|warm_zero={warm_zero}")
    emit("archive.format.bytes_per_object", fmt["bytes_per_object_v4"],
         f"v3={fmt['bytes_per_object_v3']}|ratio={fmt['bytes_ratio']}x")
    emit("archive.format.cold_load", fmt["cold_load_ms_v4"] * 1e3,
         f"v3_ms={fmt['cold_load_ms_v3']}|ratio={fmt['cold_load_ratio']}x"
         f"|lossless={fmt['lossless_identical']}"
         f"|recall={fmt['crop_recall']}")
    assert equals_union, \
        "archive answers diverge from the per-shard QueryEngine union"
    assert single_gt_pass, \
        "cold fan-out ran more GT launches than one unioned pass"
    assert warm_zero, "warm archive query issued GT invocations"
    assert fmt["bytes_ratio"] >= 3.0, \
        f"v4 bytes/object only {fmt['bytes_ratio']}x below v3 (need >=3x)"
    assert fmt["cold_load_ratio"] >= 2.0, \
        f"v4 cold load only {fmt['cold_load_ratio']}x faster (need >=2x)"
    assert fmt["lossless_identical"], \
        "lazy v4 answers diverge from eager fp32 dequant of the same files"
    assert fmt["crop_recall"] >= 0.99, \
        f"quantized-crop recall {fmt['crop_recall']} < 0.99"


if __name__ == "__main__":
    run()
