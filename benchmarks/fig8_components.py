"""Fig. 8: component breakdown — (1) compressed generic model,
(2) + specialization, (3) + clustering. Same 95% accuracy target."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (GT_FLOPS, Timer, emit, get_model,
                               load_stream)
from repro.core.ingest import IngestConfig, ingest
from repro.core.query import dominant_classes, gt_frames_by_class, \
    precision_recall

STREAMS = ("auburn_c", "lausanne", "cnn")


def _eval(index, labels, frames, K):
    dom = dominant_classes(labels)
    gtf = gt_frames_by_class(labels, frames)
    ps, rs, cost = [], [], []
    for x in dom:
        cids = index.lookup(x, K)
        matched = [c for c, fm in zip(cids, index.first_members(cids))
                   if labels[fm] == x]
        p, r = precision_recall(index.frames_of(matched),
                                gtf.get(x, np.array([])))
        ps.append(p)
        rs.append(r)
        cost.append(len(cids) * GT_FLOPS)
    return np.mean(ps), np.mean(rs), np.mean(cost)


def run():
    for stream in STREAMS:
        vs, crops, frames, labels = load_stream(stream)
        ingest_all = len(crops) * GT_FLOPS
        query_all = len(crops) * GT_FLOPS

        # (1) generic compressed model, no clustering (T=0 -> singletons)
        apply_g, flops_g, _ = get_model(stream, "cheap2", crops, labels)
        idx1, st1 = ingest(crops, frames, apply_g, flops_g,
                           IngestConfig(K=8, threshold=1e-6,
                                        max_clusters=4096, pixel_diff=False))
        p1, r1, q1 = _eval(idx1, labels, frames, K=8)

        # (2) + specialization (still no clustering)
        apply_s, flops_s, cmap = get_model(stream, "spec2", crops, labels)
        idx2, st2 = ingest(crops, frames, apply_s, flops_s,
                           IngestConfig(K=2, threshold=1e-6,
                                        max_clusters=4096, pixel_diff=False),
                           class_map=cmap)
        p2, r2, q2 = _eval(idx2, labels, frames, K=2)

        # (3) + clustering
        idx3, st3 = ingest(crops, frames, apply_s, flops_s,
                           IngestConfig(K=2, threshold=0.8,
                                        max_clusters=2048),
                           class_map=cmap)
        p3, r3, q3 = _eval(idx3, labels, frames, K=2)

        for tag, st_, q, p, r in (("compressed", st1, q1, p1, r1),
                                  ("comp+spec", st2, q2, p2, r2),
                                  ("comp+spec+cluster", st3, q3, p3, r3)):
            emit(f"fig8.{stream}.{tag}", 0.0,
                 f"I={ingest_all/max(st_.cheap_flops,1):.0f}x"
                 f"|Q={query_all/max(q,1):.0f}x|P={p:.3f}|R={r:.3f}")


if __name__ == "__main__":
    run()
